"""jit'd public wrapper for the fault-masked matmul kernel.

Handles arbitrary leading batch dims, pads non-aligned shapes up to block
multiples (via the shared kernel-runtime helpers), and falls back to the
jnp reference on non-TPU backends (unless ``interpret=True`` is forced,
e.g. in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import (
    choose_block,
    is_tpu_backend,
    pad_axes_to,
    pad_to_multiple,
    tuned_block,
)
from repro.kernels.masked_matmul.masked_matmul import masked_matmul_pallas
from repro.kernels.masked_matmul.ref import masked_matmul_ref


def masked_matmul(
    x: jax.Array,
    w: jax.Array,
    ok: jax.Array,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """y = x @ (w * periodic_mask(ok)); x: (..., K), w: (K, N), ok: (R, C).

    Block sizes default to the tuning cache's winner for this launch when
    one exists, else the 512 heuristics (``tuned_block`` seam); an explicit
    ``bm``/``bn``/``bk`` always wins."""
    if interpret is None:
        if not is_tpu_backend():
            return masked_matmul_ref(x, w, ok)
        interpret = False

    lead = x.shape[:-1]
    kdim, n = w.shape
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, kdim)

    r, c = ok.shape
    blocks = tuned_block(
        "masked_matmul",
        dict(m=m, k=kdim, n=n, r=r, c=c),
        x.dtype,
        interpret=interpret,
        defaults=dict(bm=512, bn=512, bk=512),
        overrides=dict(bm=bm, bn=bn, bk=bk),
    )
    bm, bn, bk = blocks["bm"], blocks["bn"], blocks["bk"]
    # block sizes must stay compatible with the mask period
    bm_ = choose_block(m, bm)
    bn_ = choose_block(n, bn, multiple_of=c)
    bk_ = choose_block(kdim, bk, multiple_of=r)
    mp, np_ = pad_to_multiple(m, bm_), pad_to_multiple(n, bn_)
    # padding K must preserve mask-period alignment: choose_block guarantees
    # bk_ divides r or is a multiple of it, so lcm(bk_, r) == max(bk_, r)
    kp = kdim if kdim % bk_ == 0 else pad_to_multiple(kdim, max(bk_, r))
    xp = pad_axes_to(x2, {0: mp, 1: kp})
    wp = pad_axes_to(w, {0: kp, 1: np_})

    # NOTE: zero-padded K rows multiply healthy/faulty mask entries of the
    # wrapped period — harmless because the padded x columns are zero.
    y = masked_matmul_pallas(
        xp, wp, ok, bm=bm_, bn=bn_, bk=bk_, out_dtype=x.dtype, interpret=interpret
    )
    y = y[:m, :n]
    return y.reshape(*lead, n)


def masked_matmul_checksummed(
    x: jax.Array,
    w: jax.Array,
    ok: jax.Array,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """ABFT-augmented masked GEMM (Zhang et al., arxiv 1802.04657): append
    the column-checksum row ``1^T x`` to the input and push the augmented
    batch through the SAME masked path, so the checksum row experiences the
    same silicon (mask) as the payload rows. Returns ``(y, check_row)``
    where on consistent hardware ``check_row[b] == sum_m y[m, b]`` up to
    float reassociation; a permanent fault in PE column ``b % C`` perturbs
    both through the identical mask, which is what lets
    ``repro.obs.abft`` fold the check-row syndrome back onto PE columns."""
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    x2 = x.reshape(-1, kdim)
    xa = jnp.concatenate(
        [x2, x2.sum(axis=0, keepdims=True).astype(x2.dtype)], axis=0
    )
    ya = masked_matmul(xa, w, ok, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return ya[:-1].reshape(*lead, w.shape[1]), ya[-1]
