"""jit'd public wrapper for the fault-masked matmul kernel.

Handles arbitrary leading batch dims, pads non-aligned shapes up to block
multiples, and falls back to the jnp reference on non-TPU backends (unless
``interpret=True`` is forced, e.g. in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.masked_matmul.masked_matmul import masked_matmul_pallas
from repro.kernels.masked_matmul.ref import masked_matmul_ref


def _pad_to(v: int, b: int) -> int:
    return (v + b - 1) // b * b


def masked_matmul(
    x: jax.Array,
    w: jax.Array,
    ok: jax.Array,
    *,
    bm: int = 512,
    bn: int = 512,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """y = x @ (w * periodic_mask(ok)); x: (..., K), w: (K, N), ok: (R, C)."""
    if interpret is None:
        if jax.default_backend() != "tpu":
            return masked_matmul_ref(x, w, ok)
        interpret = False

    lead = x.shape[:-1]
    kdim, n = w.shape
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, kdim)

    r, c = ok.shape
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, kdim)
    # block sizes must stay compatible with the mask period
    if bk_ < r and r % bk_:
        bk_ = r
    if bn_ < c and c % bn_:
        bn_ = c
    mp, np_, kp = _pad_to(m, bm_), _pad_to(n, bn_), _pad_to(kdim, bk_)
    # padding K breaks the mask period alignment; pad K only in multiples of r
    if kp != kdim:
        kp = _pad_to(kdim, max(bk_, r) if bk_ % r == 0 or r % bk_ == 0 else bk_ * r)
    xp = jnp.pad(x2, ((0, mp - m), (0, kp - kdim))) if (mp != m or kp != kdim) else x2
    wp = jnp.pad(w, ((0, kp - kdim), (0, np_ - n))) if (kp != kdim or np_ != n) else w

    # NOTE: zero-padded K rows multiply healthy/faulty mask entries of the
    # wrapped period — harmless because the padded x columns are zero.
    y = masked_matmul_pallas(
        xp, wp, ok, bm=bm_, bn=bn_, bk=bk_, out_dtype=x.dtype, interpret=interpret
    )
    y = y[:m, :n]
    return y.reshape(*lead, n)
