"""Pallas TPU kernel: fault-masked matmul (the FAP operator, fused).

TPU-native design (DESIGN.md S2/S6): the (R, C) healthy mask is periodic
over the weight, so one small VMEM-resident block serves EVERY weight tile.
The mask multiply happens in VMEM between the weight DMA and the MXU feed —
no masked weight copy is ever materialized in HBM, unlike the naive
``(w * mask) @ x`` which costs an extra full-weight HBM read + write.

Blocking: grid (M/bm, N/bn, K/bk) with K innermost (reduction, 'arbitrary'
semantics); fp32 accumulator in VMEM scratch; block shapes multiples of the
(8/16, 128) tile and sized so x, w, mask, acc fit VMEM comfortably
(default 512x512x512 blocks: 512*512*4B * 4 buffers ~ 4 MiB << 16 MiB VMEM).

Mask block resolution (rows; cols symmetric):
  bk <= R  -> mask block rows = bk, periodic index_map k % (R/bk)
  bk >  R  -> mask block rows = R, index 0, in-kernel tile by bk/R
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import grid_for, resolve_interpret, tpu_compiler_params


def _mask_axis_plan(block: int, period: int):
    """Returns (mask_block, index_fn, tile_factor) for one axis."""
    if block <= period:
        if period % block:
            raise ValueError(f"array period {period} must be a multiple of block {block}")
        n = period // block
        return block, (lambda g: g % n), 1
    if block % period:
        raise ValueError(f"block {block} must be a multiple of array period {period}")
    return period, (lambda g: 0), block // period


def _kernel(x_ref, w_ref, ok_ref, o_ref, acc_ref, *, nk: int, tile_r: int, tile_c: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mask = ok_ref[...]
    if tile_r > 1 or tile_c > 1:
        mask = jnp.tile(mask, (tile_r, tile_c))
    w = w_ref[...] * mask.astype(w_ref.dtype)
    acc_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"),
)
def masked_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    ok: jax.Array,
    *,
    bm: int = 512,
    bn: int = 512,
    bk: int = 512,
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """y[M, N] = x[M, K] @ (w[K, N] * periodic(ok[R, C])).

    Shapes must be multiples of the block sizes (ops.py pads otherwise).
    ``interpret=None`` autodetects the backend (interpret mode off-TPU).
    """
    interpret = resolve_interpret(interpret)
    (m, kdim), (k2, n) = x.shape, w.shape
    assert kdim == k2, (x.shape, w.shape)
    r, c = ok.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    out_dtype = out_dtype or x.dtype

    mask_br, row_idx, tile_r = _mask_axis_plan(bk, r)
    mask_bc, col_idx, tile_c = _mask_axis_plan(bn, c)

    grid = grid_for((m, n, kdim), (bm, bn, bk))
    kernel = functools.partial(
        _kernel, nk=grid[2], tile_r=tile_r, tile_c=tile_c
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((mask_br, mask_bc), lambda i, j, k: (row_idx(k), col_idx(j))),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w, ok)
