"""Pure-jnp oracle for the fault-masked matmul.

y = x @ (w * periodic_mask(ok))  — the FAP operator (paper SII, [8]) with
the (R, C) systolic fault mask tiled periodically over the weight.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.mapping import periodic_mask


def masked_matmul_ref(x, w, ok, *, out_dtype=None):
    """x: (..., K); w: (K, N); ok: (R, C) 1/0 healthy mask."""
    out_dtype = out_dtype or x.dtype
    mask = periodic_mask(w.shape, ok, dtype=jnp.float32)
    wm = (w.astype(jnp.float32) * mask).astype(w.dtype)
    y = jnp.matmul(x.astype(jnp.float32), wm.astype(jnp.float32))
    return y.astype(out_dtype)
