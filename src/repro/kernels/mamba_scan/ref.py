"""Pure-jnp oracle for the Mamba-1 selective scan.

State recurrence per (batch b, channel d):
    h_t = exp(dt_t * A_d) * h_{t-1} + (dt_t * u_t) * B_t
    y_t = <C_t, h_t> + D_d * u_t
with h in R^N, A_d in R^N (negative), B_t/C_t in R^N shared across channels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(u, dt, a, b, c, d, h0=None):
    """u, dt: (B, L, D); a: (D, N); b, c: (B, L, N); d: (D,).

    Returns (y: (B, L, D), h_final: (B, D, N)). Computed in fp32.
    """
    bsz, length, dim = u.shape
    n = a.shape[1]
    u32, dt32 = u.astype(jnp.float32), dt.astype(jnp.float32)
    a32, b32, c32 = a.astype(jnp.float32), b.astype(jnp.float32), c.astype(jnp.float32)
    h = jnp.zeros((bsz, dim, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp  # (B,D) (B,D) (B,N) (B,N)
        da = jnp.exp(dt_t[..., None] * a32[None])  # (B, D, N)
        h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        jnp.moveaxis(u32, 1, 0),
        jnp.moveaxis(dt32, 1, 0),
        jnp.moveaxis(b32, 1, 0),
        jnp.moveaxis(c32, 1, 0),
    )
    h, ys = jax.lax.scan(step, h, xs)
    y = jnp.moveaxis(ys, 0, 1) + u32 * d.astype(jnp.float32)[None, None, :]
    return y.astype(u.dtype), h


def selective_step_ref(h, u_t, dt_t, a, b_t, c_t, d):
    """One decode step. h: (B, D, N); u_t, dt_t: (B, D); b_t, c_t: (B, N)."""
    da = jnp.exp(dt_t.astype(jnp.float32)[..., None] * a.astype(jnp.float32)[None])
    h = da * h + (dt_t * u_t).astype(jnp.float32)[..., None] * b_t.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32)) + u_t * d[None, :]
    return y.astype(u_t.dtype), h
