"""Pallas TPU kernel: chunked Mamba-1 selective scan.

Naive XLA lowering either materializes (B, L, D, N) intermediates (HBM
disaster) or runs an L-step scan with per-step HBM round-trips. The TPU
rethink: grid (B, D/bd, L/bl) with L innermost; the running state h (bd, N)
lives in VMEM scratch across the whole L sweep, each grid step streams one
(bl, bd) chunk of u/dt and (bl, N) of B/C through VMEM, runs the recurrence
sequentially in-register (VPU), and writes the (bl, bd) output chunk. HBM
traffic is exactly one read of the inputs + one write of y — the roofline
floor for this bandwidth-bound op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import grid_for, resolve_interpret, tpu_compiler_params


def _kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hlast_ref, h_ref, *, bl: int, nl: int):
    il = pl.program_id(2)

    @pl.when(il == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)  # (bd, N)
    dskip = d_ref[...].astype(jnp.float32)  # (1, bd)

    def step(t, h):
        u_t = u_ref[0, t].astype(jnp.float32)  # (bd,)
        dt_t = dt_ref[0, t].astype(jnp.float32)  # (bd,)
        b_t = b_ref[0, t].astype(jnp.float32)  # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)  # (N,)
        da = jnp.exp(dt_t[:, None] * a)  # (bd, N)
        h = da * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1) + dskip[0] * u_t
        y_ref[0, t] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bl, step, h_ref[...])
    h_ref[...] = h

    @pl.when(il == nl - 1)
    def _store_final():
        hlast_ref[0] = h


@functools.partial(jax.jit, static_argnames=("bd", "bl", "interpret"))
def selective_scan_pallas(
    u: jax.Array,  # (B, L, D)
    dt: jax.Array,  # (B, L, D)
    a: jax.Array,  # (D, N)
    b: jax.Array,  # (B, L, N)
    c: jax.Array,  # (B, L, N)
    d: jax.Array,  # (D,)
    *,
    bd: int = 256,
    bl: int = 128,
    interpret: bool | None = None,
):
    """Returns (y (B, L, D), h_final (B, D, N))."""
    interpret = resolve_interpret(interpret)
    bsz, length, dim = u.shape
    n = a.shape[1]
    bd = min(bd, dim)
    bl = min(bl, length)
    (nd, nl) = grid_for((dim, length), (bd, bl))
    grid = (bsz, nd, nl)
    d2 = d.reshape(1, dim)

    kernel = functools.partial(_kernel, bl=bl, nl=nl)
    y, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bl, bd), lambda ib, id_, il: (ib, il, id_)),  # u
            pl.BlockSpec((1, bl, bd), lambda ib, id_, il: (ib, il, id_)),  # dt
            pl.BlockSpec((bd, n), lambda ib, id_, il: (id_, 0)),  # a
            pl.BlockSpec((1, bl, n), lambda ib, id_, il: (ib, il, 0)),  # b
            pl.BlockSpec((1, bl, n), lambda ib, id_, il: (ib, il, 0)),  # c
            pl.BlockSpec((1, bd), lambda ib, id_, il: (0, id_)),  # d skip
        ],
        out_specs=[
            pl.BlockSpec((1, bl, bd), lambda ib, id_, il: (ib, il, id_)),
            pl.BlockSpec((1, bd, n), lambda ib, id_, il: (ib, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, length, dim), u.dtype),
            jax.ShapeDtypeStruct((bsz, dim, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(u, dt, a, b, c, d2)
    return y, hlast
