"""jit'd public wrapper for the selective scan kernel; falls back to the
lax.scan reference off-TPU. The model layer calls this for train/prefill and
``selective_step_ref`` for single-token decode."""
from __future__ import annotations

import jax

from repro.kernels.mamba_scan.mamba_scan import selective_scan_pallas
from repro.kernels.mamba_scan.ref import selective_scan_ref, selective_step_ref


def selective_scan(u, dt, a, b, c, d, *, bd: int = 256, bl: int = 128, interpret=None):
    if interpret is None:
        if jax.default_backend() != "tpu":
            return selective_scan_ref(u, dt, a, b, c, d)
        interpret = False
    dim, length = u.shape[2], u.shape[1]
    bd_ = bd if dim % bd == 0 else dim
    bl_ = bl if length % bl == 0 else length
    return selective_scan_pallas(u, dt, a, b, c, d, bd=bd_, bl=bl_, interpret=interpret)


selective_step = selective_step_ref
