"""jit'd public wrapper for the selective scan kernel; falls back to the
lax.scan reference off-TPU. The model layer calls this for train/prefill and
``selective_step_ref`` for single-token decode.

Non-block-multiple (L, D) shapes are zero-padded up to block multiples:
padded steps carry ``u = dt = 0`` so the recurrence is inert there
(``h <- exp(0 * A) * h + 0 = h``) and padded channels are sliced off the
outputs — the wrapper used to silently fall back to whole-axis blocks
instead, losing the chunked VMEM schedule."""
from __future__ import annotations


from repro.kernels.common import is_tpu_backend, pad_axes_to, pad_to_multiple, tuned_block
from repro.kernels.mamba_scan.mamba_scan import selective_scan_pallas
from repro.kernels.mamba_scan.ref import selective_scan_ref, selective_step_ref


def selective_scan(
    u, dt, a, b, c, d, *, bd: int | None = None, bl: int | None = None, interpret=None
):
    """``bd``/``bl`` default to the tuning cache's winner for this launch
    when one exists, else the 256/128 heuristics (``tuned_block`` seam)."""
    if interpret is None:
        if not is_tpu_backend():
            return selective_scan_ref(u, dt, a, b, c, d)
        interpret = False
    bsz, length, dim = u.shape
    blocks = tuned_block(
        "mamba_scan",
        dict(b=bsz, l=length, d=dim, n=a.shape[1]),
        u.dtype,
        interpret=interpret,
        defaults=dict(bd=256, bl=128),
        overrides=dict(bd=bd, bl=bl),
    )
    bd, bl = blocks["bd"], blocks["bl"]
    bd_ = min(bd, dim)
    bl_ = min(bl, length)
    dim_p = pad_to_multiple(dim, bd_)
    len_p = pad_to_multiple(length, bl_)
    up = pad_axes_to(u, {1: len_p, 2: dim_p})
    dtp = pad_axes_to(dt, {1: len_p, 2: dim_p})
    ap = pad_axes_to(a, {0: dim_p})
    bp = pad_axes_to(b, {1: len_p})
    cp = pad_axes_to(c, {1: len_p})
    dp = pad_axes_to(d, {0: dim_p})
    y, hlast = selective_scan_pallas(
        up, dtp, ap, bp, cp, dp, bd=bd_, bl=bl_, interpret=interpret
    )
    return y[:, :length, :dim], hlast[:, :dim]


selective_step = selective_step_ref
