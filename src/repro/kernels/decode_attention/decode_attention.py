"""Pallas TPU kernel: decode attention over an int8-quantized KV cache.

Decode is memory-bound on every assigned arch (EXPERIMENTS.md §Roofline):
the per-token cost is dominated by streaming the KV cache through HBM.
Storing KV as int8 with per-(head, position) scales halves that traffic —
but only if the dequantize happens in VMEM between the DMA and the MXU;
an XLA-level dequant materializes a bf16 copy and makes traffic WORSE
(int8 read + bf16 write + bf16 read). This kernel fuses it:

grid (B*Hq, S/bkv), kv innermost; each step DMAs an int8 (bkv, D) block +
its (bkv,) scales, dequantizes in VMEM, and runs the online-softmax
update. q (one token, padded to 8 sublanes) stays resident.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import grid_for, resolve_interpret, tpu_compiler_params

LANES = 128
NEG_INF = -1e30


def _kernel(
    len_ref,  # scalar prefetch: (1,) int32 valid kv length
    q_ref,  # (1, bq, d)
    k_ref,  # (1, bkv, d) int8
    ks_ref,  # (1, bkv) f32
    v_ref,
    vs_ref,
    o_ref,  # (1, bq, d)
    acc_ref,
    m_ref,
    l_ref,
    *,
    nk: int,
    bq: int,
    bkv: int,
    scale: float,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = len_ref[0]
    kv_start = ik * bkv

    @pl.when(kv_start < valid)
    def _update():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32) * ks_ref[0][:, None]  # dequant in VMEM
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bkv)
        cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(cols < valid, s, NEG_INF)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True), l_ref.shape
        )
        v = v_ref[0].astype(jnp.float32) * vs_ref[0][:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def _paged_kernel(
    tbl_ref,  # scalar prefetch: (B, maxp) int32 block tables
    len_ref,  # scalar prefetch: (B,) int32 valid kv lengths
    q_ref,  # (1, gq, d) — one kv-head's query group, padded to >= 8 sublanes
    k_ref,  # (1, 1, page, d) int8 — the page picked by the block table
    ks_ref,  # (1, 1, page) f32
    v_ref,
    vs_ref,
    o_ref,  # (1, gq, d)
    acc_ref,
    m_ref,
    l_ref,
    *,
    maxp: int,
    gq: int,
    page: int,
    hkv: int,
    scale: float,
):
    bh = pl.program_id(0)  # flattened (sequence, kv head)
    ip = pl.program_id(1)  # position in the page chain

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = len_ref[bh // hkv]
    kv_start = ip * page

    @pl.when(kv_start < valid)
    def _update():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]  # dequant in VMEM
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (gq, page)
        cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, (gq, page), 1)
        s = jnp.where(cols < valid, s, NEG_INF)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True), l_ref.shape
        )
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ip == maxp - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("hkv", "scale", "gq", "interpret"),
)
def paged_decode_attention_pallas(
    q: jax.Array,  # (B*Hkv, gq, D) — per-kv-head query groups, gq >= 8
    k_pages_i8: jax.Array,  # (Hkv, P, page, D) int8 page pool
    k_scale: jax.Array,  # (Hkv, P, page) f32
    v_pages_i8: jax.Array,
    v_scale: jax.Array,
    block_tables: jax.Array,  # (B, maxp) int32 page ids
    seq_lens: jax.Array,  # (B,) int32 valid kv lengths
    *,
    hkv: int,
    scale: Optional[float] = None,
    gq: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode attention straight off the paged pool: the block table rides
    ahead of the DMAs as a scalar-prefetch operand, so grid step (bh, i)
    fetches page ``block_tables[b, i]`` — the gather never materializes a
    dense per-sequence cache in HBM. Online softmax over the page chain;
    dequantization stays fused in VMEM like the dense kernel above."""
    interpret = resolve_interpret(interpret)
    bh, gq_, d = q.shape
    _, _, page, _ = k_pages_i8.shape
    maxp = block_tables.shape[1]
    assert gq_ == gq
    scale = scale if scale is not None else 1.0 / (d**0.5)
    grid = (bh, maxp)

    q_map = lambda bh_, i, tbl, lens: (bh_, 0, 0)
    kv_map = lambda bh_, i, tbl, lens: (bh_ % hkv, tbl[bh_ // hkv, i], 0, 0)
    s_map = lambda bh_, i, tbl, lens: (bh_ % hkv, tbl[bh_ // hkv, i], 0)

    kernel = functools.partial(
        _paged_kernel, maxp=maxp, gq=gq, page=page, hkv=hkv, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # block tables + lengths ride ahead
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, gq, d), q_map),
                pl.BlockSpec((1, 1, page, d), kv_map),
                pl.BlockSpec((1, 1, page), s_map),
                pl.BlockSpec((1, 1, page, d), kv_map),
                pl.BlockSpec((1, 1, page), s_map),
            ],
            out_specs=pl.BlockSpec((1, gq, d), q_map),
            scratch_shapes=[
                pltpu.VMEM((gq, d), jnp.float32),
                pltpu.VMEM((gq, LANES), jnp.float32),
                pltpu.VMEM((gq, LANES), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, gq, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables, seq_lens, q, k_pages_i8, k_scale, v_pages_i8, v_scale)


@functools.partial(
    jax.jit,
    static_argnames=("hq_per_kv", "scale", "bq", "bkv", "interpret"),
)
def decode_attention_pallas(
    q: jax.Array,  # (B*Hq, bq, D) — bq = padded single-token rows
    k_i8: jax.Array,  # (B*Hkv, S, D) int8
    k_scale: jax.Array,  # (B*Hkv, S) f32
    v_i8: jax.Array,
    v_scale: jax.Array,
    kv_valid_len: jax.Array,  # (1,) int32
    *,
    hq_per_kv: int,
    scale: Optional[float] = None,
    bq: int = 8,
    bkv: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    bh, bq_, d = q.shape
    skv = k_i8.shape[1]
    assert bq_ == bq
    scale = scale if scale is not None else 1.0 / (d**0.5)
    (nk,) = grid_for((skv,), (bkv,))
    grid = (bh, nk)

    # index maps receive the scalar-prefetch ref as a trailing argument
    q_map = lambda h, k_, len_ref: (h, 0, 0)
    kv_map = lambda h, k_, len_ref: (h // hq_per_kv, k_, 0)
    s_map = lambda h, k_, len_ref: (h // hq_per_kv, k_)

    kernel = functools.partial(_kernel, nk=nk, bq=bq, bkv=bkv, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,  # kv_valid_len rides ahead of the DMAs
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bq, d), q_map),
                pl.BlockSpec((1, bkv, d), kv_map),
                pl.BlockSpec((1, bkv), s_map),
                pl.BlockSpec((1, bkv, d), kv_map),
                pl.BlockSpec((1, bkv), s_map),
            ],
            out_specs=pl.BlockSpec((1, bq, d), q_map),
            scratch_shapes=[
                pltpu.VMEM((bq, d), jnp.float32),
                pltpu.VMEM((bq, LANES), jnp.float32),
                pltpu.VMEM((bq, LANES), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, bq, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(kv_valid_len, q, k_i8, k_scale, v_i8, v_scale)
