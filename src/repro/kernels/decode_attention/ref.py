"""Oracle for int8-KV decode attention: dequantize + full attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref


def quantize_kv_ref(k: jax.Array):
    """Per-(head, position) symmetric int8 quantization.

    k: (B, Hkv, S, D) -> (int8 values, f32 scales (B, Hkv, S))."""
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv_ref(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def gather_pages_ref(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Gather page chains into dense per-sequence KV.

    pages: (Hkv, P, page, D); block_tables: (B, maxp) int32 page ids.
    Returns (B, Hkv, maxp*page, D) — each sequence's chain concatenated in
    order (garbage past its valid length; callers mask with seq_lens)."""
    hkv, _, page, d = pages.shape
    b, maxp = block_tables.shape
    g = jnp.take(pages, block_tables, axis=1)  # (Hkv, B, maxp, page, D)
    return jnp.moveaxis(g, 0, 1).reshape(b, hkv, maxp * page, d)


def paged_decode_attention_ref(
    q, k_pages_i8, k_scale, v_pages_i8, v_scale, block_tables, seq_lens, *, scale=None
):
    """Paged oracle: gather chains, dequantize, per-sequence masked attention.

    q: (B, Hq, 1, D); pools: (Hkv, P, page, D) int8 + (Hkv, P, page) f32
    scales; block_tables: (B, maxp); seq_lens: (B,) valid tokens per seq."""
    b, hq, sq, d = q.shape
    hkv, _, page, _ = k_pages_i8.shape
    maxp = block_tables.shape[1]
    k = gather_pages_ref(dequantize_kv_ref(k_pages_i8, k_scale), block_tables)
    v = gather_pages_ref(dequantize_kv_ref(v_pages_i8, v_scale), block_tables)
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) * (
        scale if scale is not None else 1.0 / (d**0.5)
    )
    mask = jnp.arange(maxp * page)[None] < seq_lens[:, None]  # (B, skv)
    s = jnp.where(mask[:, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v)
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention_ref(
    q, k_i8, k_scale, v_i8, v_scale, *, kv_valid_len=None, scale=None
):
    """q: (B, Hq, 1, D) against an int8 KV cache. Non-causal over the valid
    prefix (decode semantics: every cached token is in the past)."""
    k = dequantize_kv_ref(k_i8, k_scale)
    v = dequantize_kv_ref(v_i8, v_scale)
    if kv_valid_len is not None:
        skv = k.shape[2]
        mask = jnp.arange(skv) < kv_valid_len
        # hide unwritten slots from the softmax by zeroing post-hoc: do it
        # with a large negative bias inside a dense attention
        b, hq, sq, d = q.shape
        hkv = k.shape[1]
        group = hq // hkv
        qg = q.reshape(b, hkv, group, sq, d).astype(jnp.float32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) * (
            scale if scale is not None else 1.0 / (d**0.5)
        )
        s = jnp.where(mask[None, None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v)
        return o.reshape(b, hq, sq, d).astype(q.dtype)
    return attention_ref(q, k, v, causal=False, window=None, scale=scale)
