"""jit'd wrapper: quantize a KV cache to int8 and run decode attention.

On non-TPU backends falls back to the dequantize+attend reference (whose
XLA lowering is exactly the materialized-dequant cost the kernel removes —
see the kernel docstring and EXPERIMENTS.md §Perf)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import is_tpu_backend, pad_amount, pad_axes_to, tuned_block
from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas,
    paged_decode_attention_pallas,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    paged_decode_attention_ref,
    dequantize_kv_ref,
    quantize_kv_ref,
)

quantize_kv = quantize_kv_ref
dequantize_kv = dequantize_kv_ref


def decode_attention(
    q: jax.Array,  # (B, Hq, 1, D)
    k_i8: jax.Array,  # (B, Hkv, S, D) int8
    k_scale: jax.Array,  # (B, Hkv, S)
    v_i8: jax.Array,
    v_scale: jax.Array,
    kv_valid_len,
    *,
    scale: Optional[float] = None,
    bkv: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """``bkv`` defaults to the tuning cache's winner for this launch when
    one exists, else the 128 heuristic (``tuned_block`` seam)."""
    if interpret is None:
        if not is_tpu_backend():
            return decode_attention_ref(
                q, k_i8, k_scale, v_i8, v_scale,
                kv_valid_len=kv_valid_len, scale=scale,
            )
        interpret = False

    b, hq, sq, d = q.shape
    hkv, skv = k_i8.shape[1], k_i8.shape[2]
    group = hq // hkv
    bkv = tuned_block(
        "decode_attention",
        dict(b=b, hq=hq, hkv=hkv, skv=skv, d=d),
        q.dtype,
        interpret=interpret,
        defaults=dict(bkv=128),
        overrides=dict(bkv=bkv),
    )["bkv"]
    bq = 8  # TPU sublane minimum; decode q is 1 row padded
    qf = pad_axes_to(q.reshape(b * hq, sq, d), {1: bq})
    skv_p = skv + pad_amount(skv, bkv)
    kf = pad_axes_to(k_i8.reshape(b * hkv, skv, d), {1: skv_p})
    vf = pad_axes_to(v_i8.reshape(b * hkv, skv, d), {1: skv_p})
    ksf = pad_axes_to(k_scale.reshape(b * hkv, skv), {1: skv_p})
    vsf = pad_axes_to(v_scale.reshape(b * hkv, skv), {1: skv_p})
    valid = jnp.asarray(kv_valid_len, jnp.int32).reshape(1)

    o = decode_attention_pallas(
        qf, kf, ksf, vf, vsf, valid,
        hq_per_kv=group, scale=scale, bq=bq, bkv=min(bkv, kf.shape[1]),
        interpret=interpret,
    )
    return o[:, :sq].reshape(b, hq, sq, d)


def paged_decode_attention(
    q: jax.Array,  # (B, Hq, 1, D)
    k_pages_i8: jax.Array,  # (Hkv, P, page, D) int8 page pool
    k_scale: jax.Array,  # (Hkv, P, page) f32
    v_pages_i8: jax.Array,
    v_scale: jax.Array,
    block_tables: jax.Array,  # (B, maxp) int32
    seq_lens: jax.Array,  # (B,) int32
    *,
    scale: Optional[float] = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode attention over an int8 *paged* KV pool (continuous batching).

    Each sequence attends over its own page chain at its own length — the
    ragged analog of :func:`decode_attention`. On non-TPU backends falls
    back to the gather + dequantize + attend reference (whose XLA lowering
    materializes the dense per-sequence cache the kernel's scalar-prefetch
    block-table indexing avoids)."""
    b, hq, sq, d = q.shape
    if sq != 1:
        raise ValueError(f"paged decode attention takes one query token, got sq={sq}")
    if interpret is None:
        if not is_tpu_backend():
            return paged_decode_attention_ref(
                q, k_pages_i8, k_scale, v_pages_i8, v_scale,
                block_tables, seq_lens, scale=scale,
            )
        interpret = False
    hkv = k_pages_i8.shape[0]
    group = hq // hkv
    gq = 8 * -(-group // 8)  # pad the query group to the TPU sublane minimum
    # head-major grouping: q heads [h*group, (h+1)*group) share kv head h
    qf = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    qf = pad_axes_to(qf, {1: gq})
    tables = jnp.asarray(block_tables, jnp.int32)
    lens = jnp.asarray(seq_lens, jnp.int32).reshape(b)

    o = paged_decode_attention_pallas(
        qf, k_pages_i8, k_scale, v_pages_i8, v_scale, tables, lens,
        hkv=hkv, scale=scale, gq=gq, interpret=interpret,
    )
    return o[:, :group].reshape(b, hkv, group, d).reshape(b, hq, 1, d)
