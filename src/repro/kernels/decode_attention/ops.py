"""jit'd wrapper: quantize a KV cache to int8 and run decode attention.

On non-TPU backends falls back to the dequantize+attend reference (whose
XLA lowering is exactly the materialized-dequant cost the kernel removes —
see the kernel docstring and EXPERIMENTS.md §Perf)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_pallas
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    dequantize_kv_ref,
    quantize_kv_ref,
)

quantize_kv = quantize_kv_ref
dequantize_kv = dequantize_kv_ref


def decode_attention(
    q: jax.Array,  # (B, Hq, 1, D)
    k_i8: jax.Array,  # (B, Hkv, S, D) int8
    k_scale: jax.Array,  # (B, Hkv, S)
    v_i8: jax.Array,
    v_scale: jax.Array,
    kv_valid_len,
    *,
    scale: Optional[float] = None,
    bkv: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        if jax.default_backend() != "tpu":
            return decode_attention_ref(
                q, k_i8, k_scale, v_i8, v_scale,
                kv_valid_len=kv_valid_len, scale=scale,
            )
        interpret = False

    b, hq, sq, d = q.shape
    hkv, skv = k_i8.shape[1], k_i8.shape[2]
    group = hq // hkv
    bq = 8  # TPU sublane minimum; decode q is 1 row padded
    qf = jnp.pad(q.reshape(b * hq, sq, d), ((0, 0), (0, bq - sq), (0, 0)))
    pad_kv = (-skv) % bkv
    kf = jnp.pad(k_i8.reshape(b * hkv, skv, d), ((0, 0), (0, pad_kv), (0, 0)))
    vf = jnp.pad(v_i8.reshape(b * hkv, skv, d), ((0, 0), (0, pad_kv), (0, 0)))
    ksf = jnp.pad(k_scale.reshape(b * hkv, skv), ((0, 0), (0, pad_kv)))
    vsf = jnp.pad(v_scale.reshape(b * hkv, skv), ((0, 0), (0, pad_kv)))
    valid = jnp.asarray(kv_valid_len, jnp.int32).reshape(1)

    o = decode_attention_pallas(
        qf, kf, ksf, vf, vsf, valid,
        hq_per_kv=group, scale=scale, bq=bq, bkv=min(bkv, kf.shape[1]),
        interpret=interpret,
    )
    return o[:, :sq].reshape(b, hq, sq, d)
