"""jit'd wrapper: quantize a KV cache to int8 and run decode attention.

On non-TPU backends falls back to the dequantize+attend reference (whose
XLA lowering is exactly the materialized-dequant cost the kernel removes —
see the kernel docstring and EXPERIMENTS.md §Perf)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import is_tpu_backend, pad_amount, pad_axes_to
from repro.kernels.decode_attention.decode_attention import decode_attention_pallas
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    dequantize_kv_ref,
    quantize_kv_ref,
)

quantize_kv = quantize_kv_ref
dequantize_kv = dequantize_kv_ref


def decode_attention(
    q: jax.Array,  # (B, Hq, 1, D)
    k_i8: jax.Array,  # (B, Hkv, S, D) int8
    k_scale: jax.Array,  # (B, Hkv, S)
    v_i8: jax.Array,
    v_scale: jax.Array,
    kv_valid_len,
    *,
    scale: Optional[float] = None,
    bkv: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        if not is_tpu_backend():
            return decode_attention_ref(
                q, k_i8, k_scale, v_i8, v_scale,
                kv_valid_len=kv_valid_len, scale=scale,
            )
        interpret = False

    b, hq, sq, d = q.shape
    hkv, skv = k_i8.shape[1], k_i8.shape[2]
    group = hq // hkv
    bq = 8  # TPU sublane minimum; decode q is 1 row padded
    qf = pad_axes_to(q.reshape(b * hq, sq, d), {1: bq})
    skv_p = skv + pad_amount(skv, bkv)
    kf = pad_axes_to(k_i8.reshape(b * hkv, skv, d), {1: skv_p})
    vf = pad_axes_to(v_i8.reshape(b * hkv, skv, d), {1: skv_p})
    ksf = pad_axes_to(k_scale.reshape(b * hkv, skv), {1: skv_p})
    vsf = pad_axes_to(v_scale.reshape(b * hkv, skv), {1: skv_p})
    valid = jnp.asarray(kv_valid_len, jnp.int32).reshape(1)

    o = decode_attention_pallas(
        qf, kf, ksf, vf, vsf, valid,
        hq_per_kv=group, scale=scale, bq=bq, bkv=min(bkv, kf.shape[1]),
        interpret=interpret,
    )
    return o[:, :sq].reshape(b, hq, sq, d)
