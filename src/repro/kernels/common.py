"""Shared kernel-runtime layer for the Pallas TPU kernels.

Every kernel package (masked_matmul, flash_attention, decode_attention,
mamba_scan) builds on this module instead of re-implementing the same
plumbing four slightly-different ways:

* **JAX-version compatibility** — the TPU compiler-params class has been
  renamed across JAX releases (``pltpu.TPUCompilerParams`` in 0.4.x/0.5.x,
  ``pltpu.CompilerParams`` in newer releases; very old versions take a raw
  ``{"mosaic": {...}}`` dict).  :func:`tpu_compiler_params` is the single
  place in the repo that touches either spelling.
* **Backend autodetection** — :func:`resolve_interpret` turns
  ``interpret=None`` into ``True`` off-TPU so every kernel entry point runs
  on CPU (Pallas interpret mode) without the caller knowing the backend.
* **Block/grid geometry** — :func:`choose_block`, :func:`pad_to_multiple`,
  :func:`pad_axis_to`, :func:`pad_axes_to` and :func:`grid_for` replace the
  four divergent pad/block copies that used to live in the ``ops.py``
  wrappers (one of which silently rejected non-block-multiple shapes).
* **Numerical tolerances** — :func:`dtype_tol` / :func:`assert_close` give
  tests and benchmarks one per-dtype tolerance table instead of ad-hoc
  constants.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "tpu_compiler_params",
    "is_tpu_backend",
    "resolve_interpret",
    "backend_tag",
    "choose_block",
    "tuned_block",
    "pad_to_multiple",
    "pad_amount",
    "pad_axis_to",
    "pad_axes_to",
    "grid_for",
    "dtype_tol",
    "assert_close",
    "DEFAULT_TOLS",
    "VMEM_LIMIT_BYTES",
    "MAX_GRID_AXIS",
    "block_bytes",
    "vmem_footprint",
]


# ---------------------------------------------------------------------------
# JAX-version compatibility shim
# ---------------------------------------------------------------------------


def tpu_compiler_params(
    *,
    dimension_semantics: Optional[Sequence[str]] = None,
    **kwargs: Any,
):
    """Build the ``compiler_params`` argument for ``pl.pallas_call``.

    Resolves, at call time, whichever TPU compiler-params spelling the
    installed JAX provides:

    * ``pltpu.CompilerParams``    (newer JAX)
    * ``pltpu.TPUCompilerParams`` (JAX 0.4.x / 0.5.x)
    * a raw ``{"mosaic": {...}}`` dict (very old JAX)

    This is the only place in the repository allowed to reference either
    class name — kernels must call this instead.
    """
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams", None)
    params = dict(kwargs)
    if dimension_semantics is not None:
        params["dimension_semantics"] = tuple(dimension_semantics)
    if cls is None:  # pre-dataclass JAX: pallas_call takes a nested dict
        return {"mosaic": params}
    return cls(**params)


# ---------------------------------------------------------------------------
# Backend autodetection
# ---------------------------------------------------------------------------


def is_tpu_backend() -> bool:
    """True when the default JAX backend is a TPU."""
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve a tri-state ``interpret`` flag against the active backend.

    ``None`` means "autodetect": compiled on TPU, interpret mode everywhere
    else — so the same kernel call works on a CPU-only host (tests, CI)
    without the caller branching on the backend.
    """
    if interpret is None:
        return not is_tpu_backend()
    return bool(interpret)


def backend_tag(interpret: bool) -> str:
    """The backend component of a tuning-cache key: ``"interpret"`` for
    Pallas interpret mode (any host), else the JAX backend name ("tpu",
    "cpu", ...). Interpret-mode timings are a different machine from
    compiled Mosaic, so their tuned configs must never cross-pollinate."""
    return "interpret" if interpret else str(jax.default_backend())


# ---------------------------------------------------------------------------
# Block sizes, padding, grids
# ---------------------------------------------------------------------------


def choose_block(dim: int, requested: int, *, multiple_of: int = 1) -> int:
    """Clamp a requested block size to ``dim`` and keep it compatible with a
    required period (e.g. a mask period): the result always divides the
    period or is a multiple of it, so periodic index maps stay aligned.
    Blocks below the period that don't divide it snap up to the period;
    incompatible blocks above it are replaced by the period multiple that
    minimizes the padding of ``dim`` (largest such block on ties)."""
    dim, requested, period = int(dim), int(requested), int(multiple_of)
    b = max(1, min(requested, dim))
    if period > 1:
        if b < period:
            if period % b:
                b = period
        elif b % period:
            b = min(
                range(period, b + 1, period),
                key=lambda c: (pad_to_multiple(dim, c) - dim, -c),
            )
    return b


def tuned_block(
    kernel: str,
    shape: Mapping[str, int],
    dtype: Any,
    *,
    interpret: bool,
    defaults: Mapping[str, int],
    overrides: Optional[Mapping[str, Optional[int]]] = None,
) -> dict[str, int]:
    """THE seam between the ``ops.py`` wrappers and the tuning cache.

    Resolution order, per block parameter:

    1. an explicit caller value (``overrides`` entry that is not None) —
       callers who ask for a block get exactly that block, as before;
    2. the process-wide tuning cache (:mod:`repro.tune.cache`) under the
       canonical ``(kernel, shape, dtype, backend)`` key;
    3. the wrapper's heuristic ``defaults`` — so with an empty cache this
       function is an identity on today's behavior, bitwise.

    Returned blocks still flow through ``choose_block``/clamping in the
    wrapper, so even a stale cached config degrades to a *legal* launch
    (the ``kernel_bench.py --tune --check`` CI gate catches it turning
    stale before that). Lookups happen at trace time: a jitted caller
    bakes the blocks of its first trace into the compiled program.
    """
    blocks = {k: int(v) for k, v in defaults.items()}
    from repro.tune.cache import get_tuning_cache  # JAX-free, cycle-free

    hit = get_tuning_cache().lookup_blocks(
        kernel,
        shape,
        jnp.dtype(dtype).name,
        backend_tag(interpret),
    )
    if hit:
        for k in blocks:
            if k in hit:
                blocks[k] = int(hit[k])
    if overrides:
        for k, v in overrides.items():
            if v is not None:
                blocks[k] = int(v)
    return blocks


def pad_to_multiple(n: int, block: int) -> int:
    """Smallest multiple of ``block`` that is >= ``n``."""
    return -(-int(n) // int(block)) * int(block)


def pad_amount(n: int, block: int) -> int:
    """How many trailing elements must be added so ``block`` divides ``n``."""
    return (-int(n)) % int(block)


def pad_axis_to(x: jax.Array, axis: int, target: int, value: float = 0.0) -> jax.Array:
    """Zero-pad (by default) one axis of ``x`` up to ``target`` length."""
    cur = x.shape[axis]
    if cur == target:
        return x
    if cur > target:
        raise ValueError(f"axis {axis} of {x.shape} already exceeds target {target}")
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(x, widths, constant_values=value)


def pad_axes_to(x: jax.Array, targets: Mapping[int, int], value: float = 0.0) -> jax.Array:
    """Zero-pad several axes of ``x`` at once; no-op axes may be omitted."""
    widths = [(0, 0)] * x.ndim
    changed = False
    for axis, target in targets.items():
        cur = x.shape[axis]
        if cur > target:
            raise ValueError(f"axis {axis} of {x.shape} already exceeds target {target}")
        if cur != target:
            widths[axis] = (0, target - cur)
            changed = True
    return jnp.pad(x, widths, constant_values=value) if changed else x


def grid_for(dims: Sequence[int], blocks: Sequence[int]) -> tuple[int, ...]:
    """Grid extents for ``dims`` tiled by ``blocks`` (dims must divide)."""
    if len(dims) != len(blocks):
        raise ValueError(f"{len(dims)} dims vs {len(blocks)} blocks")
    out = []
    for d, b in zip(dims, blocks):
        if d % b:
            raise ValueError(f"dim {d} not divisible by block {b} ({dims} / {blocks})")
        out.append(d // b)
    return tuple(out)


# ---------------------------------------------------------------------------
# Analytic VMEM accounting (shared by the kernel-geometry lint)
# ---------------------------------------------------------------------------

# Per-core VMEM on current TPU generations (v4/v5: 16 MiB usable scratch).
# A kernel whose resident blocks exceed this fails at Mosaic compile/launch
# time — the geometry lint (repro.analysis.kernelgeom) checks it statically.
VMEM_LIMIT_BYTES = 16 * 1024 * 1024

# Mosaic grid extents are int32; practically, an axis near this bound means
# a degenerate blocking choice long before it overflows.
MAX_GRID_AXIS = 2**31 - 1


def block_bytes(shape: Sequence[int], dtype: Any) -> int:
    """Bytes of one VMEM-resident block of ``shape`` and ``dtype``."""
    n = 1
    for d in shape:
        n *= int(d)
    return n * jnp.dtype(dtype).itemsize


def vmem_footprint(
    blocks: Sequence[tuple], *, double_buffered: bool = False
) -> int:
    """Analytic VMEM footprint of a kernel invocation: the sum of its
    resident blocks — every ``in_specs``/``out_specs`` block plus scratch
    shapes. Entries are ``(shape, dtype)`` or ``(shape, dtype, is_io)``
    where ``is_io`` marks a gridded in/out block the Mosaic pipeline DMAs
    (True for 2-tuples — scratch accumulators should pass False).

    With ``double_buffered=False`` (the lint's historical model) each block
    counts once; ``double_buffered=True`` doubles the DMA'd ``is_io``
    blocks — the bound the autotuner uses, since the pipelined prefetch of
    the next grid step keeps two copies of every in/out block resident.
    """
    total = 0
    for entry in blocks:
        shape, dtype = entry[0], entry[1]
        is_io = bool(entry[2]) if len(entry) > 2 else True
        nbytes = block_bytes(shape, dtype)
        if double_buffered and is_io:
            nbytes *= 2
        total += nbytes
    return total


# ---------------------------------------------------------------------------
# Unified per-dtype tolerance defaults (tests + benchmarks)
# ---------------------------------------------------------------------------

DEFAULT_TOLS: dict[Any, float] = {
    jnp.dtype(jnp.bfloat16): 2e-2,
    jnp.dtype(jnp.float16): 1e-2,
    jnp.dtype(jnp.float32): 2e-5,
    jnp.dtype(jnp.float64): 1e-12,
}


def dtype_tol(dtype: Any, *, atol_scale: float = 10.0) -> tuple[float, float]:
    """(rtol, atol) defaults for comparing a kernel against its reference."""
    rtol = DEFAULT_TOLS.get(jnp.dtype(dtype), 2e-5)
    return rtol, rtol * atol_scale


def assert_close(actual, expected, dtype: Any = None, *, atol_scale: float = 10.0) -> None:
    """np.testing.assert_allclose with the shared per-dtype tolerances.

    Both arrays are compared in float32 so bfloat16 outputs don't lose
    precision a second time inside numpy."""
    if dtype is None:
        dtype = getattr(actual, "dtype", jnp.float32)
    rtol, atol = dtype_tol(dtype, atol_scale=atol_scale)
    np.testing.assert_allclose(
        np.asarray(actual, np.float32),
        np.asarray(expected, np.float32),
        rtol=rtol,
        atol=atol,
    )
