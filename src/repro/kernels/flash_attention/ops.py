"""jit'd public wrapper for the flash attention kernel.

Takes (B, H, S, D) layouts, flattens batch x heads for the kernel's
index-map GQA arithmetic, pads q rows for short decode queries, and falls
back to the jnp reference on non-TPU backends (unless interpret is forced).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.common import is_tpu_backend, pad_amount, pad_axes_to, tuned_block
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    bq: int | None = None,
    bkv: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """``bq``/``bkv`` default to the tuning cache's winner for this launch
    when one exists, else the 128 heuristics (``tuned_block`` seam)."""
    if interpret is None:
        if not is_tpu_backend():
            return attention_ref(
                q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale
            )
        interpret = False

    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    blocks = tuned_block(
        "flash_attention",
        dict(b=b, hq=hq, hkv=hkv, sq=sq, skv=skv, d=d, causal=int(causal)),
        q.dtype,
        interpret=interpret,
        defaults=dict(bq=128, bkv=128),
        overrides=dict(bq=bq, bkv=bkv),
    )
    bq, bkv = blocks["bq"], blocks["bkv"]

    bq_ = min(bq, sq)
    pad_q = pad_amount(sq, max(bq_, 8))
    bq_ = min(max(bq_, 8), sq + pad_q)
    bkv_ = min(bkv, skv)
    pad_kv = pad_amount(skv, bkv_)

    qf = pad_axes_to(q.reshape(b * hq, sq, d), {1: sq + pad_q})
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    if pad_kv:
        # pad keys at the END; causal masking vs real rows keeps them dead
        # only when padded cols are masked -> extend window mask via NEG_INF
        # by flagging them with q_offset arithmetic is not possible, so we
        # instead mask by making padded keys unreachable: they sit at
        # positions >= skv and every real row r has r < skv, so causal
        # masking kills them. Non-causal callers must pass aligned skv.
        assert causal, "non-causal attention requires skv % bkv == 0"
        kf = pad_axes_to(kf, {1: skv + pad_kv})
        vf = pad_axes_to(vf, {1: skv + pad_kv})

    o = flash_attention_pallas(
        qf,
        kf,
        vf,
        hq_per_kv=group,
        causal=causal,
        window=window,
        q_offset=q_offset,
        scale=scale,
        bq=bq_,
        bkv=bkv_,
        interpret=interpret,
    )
    if pad_q:
        o = o[:, :sq]
    return o.reshape(b, hq, sq, d)
