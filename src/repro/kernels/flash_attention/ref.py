"""Pure-jnp oracle for blocked attention: GQA + causal + sliding window."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(
    q,  # (B, Hq, Sq, D)
    k,  # (B, Hkv, Skv, D)
    v,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
):
    """Full-materialization attention. ``q_offset`` is the absolute position
    of q[0] (decode: q_offset = kv_len - q_len)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)) * scale
    rows = jnp.arange(sq)[:, None] + q_offset
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)
