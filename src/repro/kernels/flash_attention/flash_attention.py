"""Pallas TPU kernel: blocked online-softmax attention (FlashAttention-style,
adapted to the TPU memory hierarchy) with causal + sliding-window masking
and GQA via index-map head arithmetic (no materialized KV repeat).

Grid: (B*Hq, Sq/bq, Skv/bkv), kv innermost ('arbitrary'). Running max and
denominator live in VMEM scratch as (bq, LANES) broadcasts; the output
accumulator is fp32 VMEM. Sliding-window and causal constraints are applied
per-element inside the block and the fully-masked blocks are skipped with
pl.when (the DMAs still occur with static BlockSpecs — the §Perf pass
over-approximates this; on-TPU one would use a kv-start scalar prefetch).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import grid_for, resolve_interpret, tpu_compiler_params

LANES = 128
NEG_INF = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    nk: int,
    bq: int,
    bkv: int,
    scale: float,
    causal: bool,
    window: Optional[int],
    q_offset: int,
):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq + q_offset
    kv_start = ik * bkv

    # block-level reachability (static shapes, dynamic predicate)
    live = jnp.bool_(True)
    if causal:
        live &= kv_start <= q_start + bq - 1
    if window is not None:
        live &= kv_start + bkv - 1 > q_start - window

    @pl.when(live)
    def _update():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bkv)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), dtype=jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (bq, bkv)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)

        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p,
            v_ref[0].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "scale", "bq", "bkv", "interpret", "hq_per_kv"
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # (B*Hq, Sq, D)
    k: jax.Array,  # (B*Hkv, Skv, D)
    v: jax.Array,
    *,
    hq_per_kv: int,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    bh, sq, d = q.shape
    bhkv, skv, _ = k.shape
    scale = scale if scale is not None else 1.0 / (d**0.5)
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    (nq, nk) = grid_for((sq, skv), (bq, bkv))
    grid = (bh, nq, nk)

    # q index bhq -> kv index: with q laid out as (B, Hkv, group) flattened,
    # kv row = bhq // hq_per_kv
    def q_map(h, i, k_):
        return (h, i, 0)

    def kv_map(h, i, k_):
        return (h // hq_per_kv, k_, 0)

    kernel = functools.partial(
        _kernel,
        nk=nk,
        bq=bq,
        bkv=bkv,
        scale=scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bkv, d), kv_map),
            pl.BlockSpec((1, bkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
