"""Pallas TPU kernels for the perf-critical hot spots.

masked_matmul   — the paper's FAP operator fused into the MXU feed
flash_attention — blocked online-softmax attention (causal/SWA/GQA)
mamba_scan      — chunked selective scan with VMEM-resident state

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper w/ CPU fallback), ref.py (pure-jnp oracle used by tests).
"""
