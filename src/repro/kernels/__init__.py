"""Pallas TPU kernels for the perf-critical hot spots.

masked_matmul    — the paper's FAP operator fused into the MXU feed
flash_attention  — blocked online-softmax attention (causal/SWA/GQA)
decode_attention — int8-KV decode attention with in-VMEM dequant, plus a
                   paged variant whose scalar-prefetch block tables read
                   straight off the serve-side page pool
mamba_scan       — chunked selective scan with VMEM-resident state

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper w/ CPU fallback), ref.py (pure-jnp oracle used by tests).

``common.py`` is the shared kernel-runtime layer all four build on: the
JAX-version compiler-params shim, backend autodetection (interpret mode
off-TPU), block/pad/grid helpers, and per-dtype tolerance defaults.
See README.md in this directory for the API and the compatibility story.
"""
