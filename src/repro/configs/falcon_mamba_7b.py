"""falcon-mamba-7b — pure Mamba-1 SSM LM (attention-free).

64L d_model=4096 d_ff=0 vocab=65024 ssm_state=16. [arXiv:2410.05355; unverified]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        d_ff=0,
        vocab_size=65024,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        tie_embeddings=False,
        source="arXiv:2410.05355",
    )
)
