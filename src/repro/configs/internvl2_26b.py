"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.

48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92553. [arXiv:2404.16821; hf]
The vision frontend is a stub: input_specs() provides precomputed patch
embeddings (a prefix of ``frontend_tokens`` dense vectors) per the assignment.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=92553,
        modality="vision",
        frontend_tokens=256,  # one 448x448 tile -> 256 patch embeddings
        activation="swiglu",
        source="arXiv:2404.16821",
    )
)
