"""smollm-135m — llama-arch small dense decoder.

30L d_model=576 9H (kv=3) d_ff=1536 vocab=49152. [hf:HuggingFaceTB/SmolLM-135M; hf]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="smollm-135m",
        family="dense",
        num_layers=30,
        d_model=576,
        num_heads=9,
        num_kv_heads=3,
        d_ff=1536,
        vocab_size=49152,
        tie_embeddings=True,
        activation="swiglu",
        source="hf:HuggingFaceTB/SmolLM-135M",
    )
)
