"""hubert-xlarge — encoder-only audio transformer (wav2vec2-style backbone).

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-unit prediction
targets). [arXiv:2106.07447; unverified]
The conv waveform frontend is a stub: input_specs() provides precomputed
frame embeddings. Encoder-only => no decode shapes.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        is_encoder=True,
        modality="audio",
        activation="gelu",
        source="arXiv:2106.07447",
    )
)
