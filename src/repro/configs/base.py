"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; input shapes are
``ShapeConfig``s. The registry maps ``--arch <id>`` strings to configs and
knows which (arch x shape) cells are runnable (sub-quadratic rules etc.).
"""
from __future__ import annotations

import importlib
import math
from dataclasses import dataclass, replace
from typing import Optional

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell.

    kind: 'train' lowers train_step; 'prefill' lowers prefill; 'decode'
    lowers serve_step (one new token against a KV cache of ``seq_len``).
    """

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """A complete model architecture description.

    This single dataclass spans all assigned families: dense / moe / ssm /
    hybrid / vlm / audio. Family-specific fields are zero/None when unused.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # SWA window size (tokens)
    rope_theta: float = 10_000.0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0

    # --- SSM (Mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    # --- structure ---
    is_encoder: bool = False  # encoder-only (no causal mask, no decode)
    modality: str = "text"  # text | vision | audio (vision/audio: stub frontend)
    frontend_tokens: int = 0  # stub prefix tokens for vlm (image patches)
    activation: str = "swiglu"  # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- fault/accelerator model (paper SIV-A: 256x256 systolic array) ---
    array_rows: int = 256
    array_cols: int = 256

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # free-form citation string
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def resolved_dt_rank(self) -> int:
        if self.ssm_dt_rank:
            return self.ssm_dt_rank
        return math.ceil(self.d_model / 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def has_attention(self) -> bool:
        return self.family in ("dense", "moe", "vlm", "audio", "hybrid")

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when a 500k-token decode has bounded state (SSM / SWA)."""
        if self.family == "ssm":
            return True
        return self.sliding_window is not None

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for roofline 6ND and FSDP policy)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        per_layer = 0
        if self.has_attention:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            per_layer += q + kv + o
        if self.has_ssm:
            di, n, r = self.d_inner, self.ssm_state, self.resolved_dt_rank
            per_layer += d * 2 * di  # in_proj (x and z branches)
            per_layer += di * self.ssm_conv  # depthwise conv
            per_layer += di * (r + 2 * n)  # x_proj -> dt, B, C
            per_layer += r * di + di  # dt_proj
            per_layer += di * n + di  # A_log, D
            per_layer += di * d  # out_proj
        if self.has_moe:
            per_layer += d * self.num_experts  # router
            per_layer += self.num_experts * 3 * d * f  # gate/up/down per expert
        elif f > 0:
            n_mats = 3 if self.activation == "swiglu" else 2
            per_layer += n_mats * d * f
        per_layer += 2 * d  # two norms
        emb = v * d
        head = 0 if self.tie_embeddings else v * d
        return L * per_layer + emb + head + d  # final norm

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.has_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive = self.num_layers * (self.num_experts - self.experts_per_token) * 3 * d * f
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_MODULES = [
    "falcon_mamba_7b",
    "phi3_mini_3_8b",
    "qwen3_0_6b",
    "llama3_405b",
    "smollm_135m",
    "llama4_maverick_400b_a17b",
    "mixtral_8x22b",
    "internvl2_26b",
    "hubert_xlarge",
    "hymba_1_5b",
    "paper_mlp",
]


def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_").lower()


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[_norm(cfg.name)] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    key = _norm(name)
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def list_archs(include_paper: bool = False) -> list[str]:
    _ensure_loaded()
    out = sorted(_REGISTRY)
    if not include_paper:
        out = [a for a in out if a != "paper_mlp"]
    return out


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


# ---------------------------------------------------------------------------
# Cell validity (which arch x shape pairs are runnable)
# ---------------------------------------------------------------------------


def cell_skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    """None if runnable, else a human-readable skip reason."""
    if cfg.is_encoder and shape.kind == "decode":
        return "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: 500k decode needs sub-quadratic attention"
    return None


def valid_cells(arch_names: Optional[list[str]] = None) -> list[tuple[str, str]]:
    _ensure_loaded()
    names = arch_names or list_archs()
    cells = []
    for a in names:
        cfg = get_arch(a)
        for s in SHAPES.values():
            if cell_skip_reason(cfg, s) is None:
                cells.append((a, s.name))
    return cells


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Family-preserving tiny version of ``cfg`` for CPU smoke tests."""
    changes: dict = dict(
        num_layers=2,
        d_model=64,
        vocab_size=97 if cfg.vocab_size else 0,
        norm_eps=cfg.norm_eps,
        array_rows=16,
        array_cols=16,
        dtype="float32",
        param_dtype="float32",
        frontend_tokens=min(cfg.frontend_tokens, 4) if cfg.frontend_tokens else 0,
    )
    if cfg.has_attention and cfg.num_heads:
        ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        kv = min(2, cfg.num_kv_heads)
        changes.update(
            num_heads=kv * min(ratio, 2),
            num_kv_heads=kv,
            head_dim=16,
        )
    if cfg.d_ff:
        changes["d_ff"] = 128
    if cfg.has_moe:
        changes.update(num_experts=4, experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.has_ssm:
        changes.update(ssm_state=8, ssm_conv=4, ssm_expand=2, ssm_dt_rank=8)
    if cfg.sliding_window:
        changes["sliding_window"] = 32
    return replace(cfg, **changes)


__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "register",
    "get_arch",
    "list_archs",
    "valid_cells",
    "cell_skip_reason",
    "reduce_config",
]
