"""llama4-maverick-400b-a17b — MoE decoder, 128 experts top-1.

48L d_model=5120 40H (kv=8) d_ff=8192 vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        num_experts=128,
        experts_per_token=1,
        rope_theta=500_000.0,
        activation="swiglu",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
)
