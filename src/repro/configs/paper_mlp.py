"""paper-mlp — the paper-faithful CPU-scale classifier used for the eFAT
resilience/grouping experiments (stands in for VGG11-CIFAR10 etc., which need
offline datasets/GPUs; the eFAT machinery is identical).

A small MLP classifier whose hidden matmuls run through the systolic
fault-mapping, trained on a synthetic cluster-classification task where
steps-to-accuracy is measurable in seconds on CPU.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="paper-mlp",
        family="classifier",
        num_layers=4,
        d_model=128,  # input dim = d_model // 4
        d_ff=48,  # narrow+deep => fault-fragile like the paper's Fig. 2 regime
        vocab_size=16,  # num classes
        array_rows=32,
        array_cols=32,
        dtype="float32",
        param_dtype="float32",
        activation="gelu",
        source="paper SIV (VGG11/ResNet18/MobileNetV2 stand-in)",
    )
)
