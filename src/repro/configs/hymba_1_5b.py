"""hymba-1.5b — hybrid decoder: parallel attention + mamba heads per block.

32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001 ssm_state=16.
[arXiv:2411.13676; hf]
Sliding-window attention on the attention branch (hymba uses SWA on most
layers) + O(1) SSM state => long_500k decode is runnable.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        sliding_window=1024,
        activation="swiglu",
        source="arXiv:2411.13676",
    )
)
