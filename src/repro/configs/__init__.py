from repro.configs.base import (
    SHAPES,
    ArchConfig,
    ShapeConfig,
    cell_skip_reason,
    get_arch,
    list_archs,
    reduce_config,
    register,
    valid_cells,
)

__all__ = [
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "cell_skip_reason",
    "get_arch",
    "list_archs",
    "reduce_config",
    "register",
    "valid_cells",
]
