"""qwen3-0.6b — dense decoder with qk_norm and GQA.

28L d_model=1024 16H (kv=8) d_ff=3072 vocab=151936, head_dim=128.
[hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen3-0.6b",
        family="dense",
        num_layers=28,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,  # qwen3 uses explicit head_dim != d_model/heads
        d_ff=3072,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        activation="swiglu",
        source="hf:Qwen/Qwen3-8B",
    )
)
