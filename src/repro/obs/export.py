"""Exporters: JSON-lines event log and Chrome trace-event format.

Two on-disk forms of one recording:

* **JSONL** (:func:`write_jsonl` / :func:`read_jsonl`) — the lossless form:
  a ``meta`` header line, one line per event (oldest first), then one line
  per metric aggregate. Greppable, streamable, and re-exportable — the
  ``repro.launch.obs`` CLI converts a saved JSONL log to a Chrome trace
  without re-running anything.
* **Chrome trace-event JSON** (:func:`write_chrome_trace`) — the viewable
  form: load it in ``chrome://tracing`` or https://ui.perfetto.dev. Spans
  become complete events (``ph: "X"``), instants ``ph: "i"``, samples
  counter tracks (``ph: "C"``); each recorder ``proc`` maps to a pid and
  each ``track`` to a tid, with metadata events naming both, so Perfetto
  draws one swimlane per slot/chip/engine track. Timestamps are
  microseconds relative to the recorder's epoch.

:func:`validate_chrome_trace` is the schema check CI runs against exported
traces (non-empty, named processes/threads, numeric non-negative ts/dur);
it returns a list of problems, empty when valid.
"""
from __future__ import annotations

import json
import warnings
from typing import Iterable, Optional, Sequence, Union

from repro.obs.recorder import JSONL_VERSION, Event, Recorder

__all__ = [
    "chrome_trace",
    "write_chrome_trace",
    "validate_chrome_trace",
    "write_jsonl",
    "read_jsonl",
    "jsonl_to_chrome",
]

RecorderOrEvents = Union[Recorder, Iterable[Event]]


def _events_of(src: RecorderOrEvents) -> list[Event]:
    if isinstance(src, Recorder):
        return src.event_list()
    return list(src)


def chrome_trace(sources: Union[RecorderOrEvents, Sequence[RecorderOrEvents]],
                 *, events_dropped: Optional[int] = None) -> dict:
    """Build the Chrome trace-event object from one or several recorders
    (or raw event lists — e.g. re-read from a JSONL log). Multiple sources
    merge into one trace; their ``proc`` names keep them on separate
    process lanes.

    A trace built from a ring that overwrote events is INCOMPLETE — its
    oldest events are gone. The drop count (summed off Recorder sources,
    or passed explicitly via ``events_dropped`` when re-exporting a JSONL
    log) is embedded as ``otherData.events_dropped`` so
    :func:`validate_chrome_trace` can warn downstream."""
    if isinstance(sources, Recorder) or not isinstance(sources, (list, tuple)):
        sources = [sources]  # a single recorder / event iterable
    elif sources and all(isinstance(s, Event) for s in sources):
        sources = [sources]  # a bare list of events IS one source
    events: list[Event] = []
    dropped = 0
    for s in sources:
        if isinstance(s, Recorder):
            dropped += s.events.dropped
        events.extend(_events_of(s))
    if events_dropped is not None:
        dropped = int(events_dropped)

    pids: dict[str, int] = {}
    tids: dict[tuple, int] = {}
    out: list[dict] = []
    for ev in events:
        pid = pids.get(ev.proc)
        if pid is None:
            pid = pids[ev.proc] = len(pids) + 1
            out.append(dict(ph="M", name="process_name", pid=pid, tid=0,
                            args=dict(name=ev.proc)))
        tkey = (ev.proc, ev.track)
        tid = tids.get(tkey)
        if tid is None:
            tid = tids[tkey] = sum(1 for p, _ in tids if p == ev.proc) + 1
            out.append(dict(ph="M", name="thread_name", pid=pid, tid=tid,
                            args=dict(name=ev.track)))
        ts = ev.ts * 1e6  # µs
        if ev.kind == "span":
            out.append(dict(ph="X", name=ev.name, cat=ev.proc, pid=pid, tid=tid,
                            ts=ts, dur=(ev.dur or 0.0) * 1e6,
                            args=ev.args or {}))
        elif ev.kind == "instant":
            out.append(dict(ph="i", s="t", name=ev.name, cat=ev.proc, pid=pid,
                            tid=tid, ts=ts, args=ev.args or {}))
        elif ev.kind == "sample":
            out.append(dict(ph="C", name=ev.name, pid=pid, tid=tid, ts=ts,
                            args=dict(value=ev.value)))
        else:
            raise ValueError(f"unknown event kind {ev.kind!r}")
    trace = dict(traceEvents=out, displayTimeUnit="ms")
    if dropped:
        trace["otherData"] = dict(events_dropped=dropped)
    return trace


def write_chrome_trace(path: str,
                       sources: Union[RecorderOrEvents, Sequence[RecorderOrEvents]],
                       ) -> dict:
    trace = chrome_trace(sources)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def validate_chrome_trace(trace: Union[str, dict]) -> list[str]:
    """Schema check; returns problems (empty list == valid). Accepts the
    trace object or a path to one.

    A schema-valid trace can still be *incomplete*: when it was built from
    a ring that overwrote events (``otherData.events_dropped`` embedded by
    :func:`chrome_trace`), this emits a ``UserWarning`` — dropped history
    is not a schema error, but it must not pass silently."""
    if isinstance(trace, str):
        try:
            with open(trace) as f:
                trace = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            return [f"unreadable trace: {e}"]
    problems: list[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top level must be an object with a traceEvents list"]
    dropped = 0
    other = trace.get("otherData")
    if isinstance(other, dict):
        d = other.get("events_dropped")
        if isinstance(d, (int, float)):
            dropped = int(d)
    if dropped:
        warnings.warn(
            f"trace was built from a ring that overwrote {dropped} event(s); "
            "the oldest events are missing (grow Recorder(capacity=...))",
            UserWarning, stacklevel=2,
        )
    events = trace["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["traceEvents must be a non-empty list"]
    named_pids, named_tids = set(), set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev:
            problems.append(f"event {i}: not an object with a ph")
            continue
        ph = ev["ph"]
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            problems.append(f"event {i} ({ph}): pid/tid must be ints")
            continue
        if ph == "M":
            if ev.get("name") == "process_name":
                named_pids.add(ev["pid"])
            elif ev.get("name") == "thread_name":
                named_tids.add((ev["pid"], ev["tid"]))
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i} ({ph} {ev.get('name')}): bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} (X {ev.get('name')}): bad dur {dur!r}")
        if ph == "C" and "value" not in ev.get("args", {}):
            problems.append(f"event {i} (C {ev.get('name')}): counter without value")
    real = [e for e in events if isinstance(e, dict) and e.get("ph") != "M"]
    if not real:
        problems.append("trace holds only metadata events")
    for e in real:
        if not isinstance(e, dict) or "ph" not in e:
            continue
        if e.get("pid") not in named_pids:
            problems.append(f"pid {e.get('pid')} has no process_name metadata")
            break
    for e in real:
        if not isinstance(e, dict) or "ph" not in e:
            continue
        if (e.get("pid"), e.get("tid")) not in named_tids:
            problems.append(
                f"tid {e.get('tid')} (pid {e.get('pid')}) has no thread_name metadata"
            )
            break
    return problems


# -- JSONL ------------------------------------------------------------------


def write_jsonl(path: str, recorder: Recorder) -> None:
    """Lossless event + metrics log: meta header, events oldest-first,
    metric aggregates last."""
    with open(path, "w") as f:
        meta = dict(kind="meta", version=JSONL_VERSION, wall0=recorder.wall0,
                    self_time_s=recorder.self_time_s,
                    events_dropped=recorder.events.dropped)
        f.write(json.dumps(meta) + "\n")
        for ev in recorder.events:
            f.write(json.dumps(ev.as_dict()) + "\n")
        for m in recorder.metrics.as_dict().values():
            f.write(json.dumps(dict(kind="metric", **m)) + "\n")


def read_jsonl(path: str) -> dict:
    """Parse a :func:`write_jsonl` log into ``{"meta": dict, "events":
    [Event], "metrics": [dict], "dropped": int}`` — the ring's drop count
    is lifted to the top level so callers cannot miss that the event list
    is missing its oldest entries when it is nonzero."""
    meta: Optional[dict] = None
    events: list[Event] = []
    metrics: list[dict] = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: bad JSONL line: {e}") from e
            kind = obj.get("kind")
            if kind == "meta":
                meta = obj
            elif kind == "metric":
                metrics.append(obj)
            elif kind in ("span", "instant", "sample"):
                events.append(Event(
                    kind=kind, name=obj["name"], proc=obj["proc"],
                    track=obj["track"], ts=obj["ts"], dur=obj.get("dur"),
                    value=obj.get("value"), args=obj.get("args"),
                ))
            else:
                raise ValueError(f"{path}:{ln}: unknown record kind {kind!r}")
    if meta is None:
        raise ValueError(f"{path}: missing meta header line")
    return dict(meta=meta, events=events, metrics=metrics,
                dropped=int(meta.get("events_dropped", 0) or 0))


def jsonl_to_chrome(in_path: str, out_path: str) -> dict:
    """Re-export a saved JSONL log as a viewable Chrome trace. The log's
    recorded drop count propagates into the trace's ``otherData`` so the
    validator still warns about incomplete history after a round-trip."""
    log = read_jsonl(in_path)
    trace = chrome_trace(log["events"], events_dropped=log["dropped"])
    with open(out_path, "w") as f:
        json.dump(trace, f)
    return trace
