"""Metrics primitives for the in-process observability layer.

Three metric kinds, all host-side and allocation-light:

* :class:`Counter` — monotone count (events, tokens, stalls).
* :class:`Gauge` — last-value sample with a high-water mark (free pages,
  allocator in-use, compile counts bridged at serve end).
* :class:`Histogram` — explicit-bucket distribution (``le`` semantics: a
  value lands in the first bucket whose upper edge is >= the value,
  Prometheus-style). Raw observations are additionally kept up to
  ``max_samples`` so percentiles are exact on bench-scale runs; past that
  the raw ring stops growing (``samples_truncated``) and
  :meth:`Histogram.percentile` falls back to linear interpolation within
  the bucket that holds the requested rank.

:class:`MetricsRegistry` is a get-or-create name → metric map; the serve,
fleet and train stacks share one registry per :class:`~repro.obs.recorder.
Recorder` so the bench and the production path read the same numbers
(benchmarks/serve_bench.py computes its percentiles from these histograms,
not from ad-hoc arrays).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "TTFT_BUCKETS_S",
    "STEP_LATENCY_BUCKETS_S",
    "TPOT_BUCKETS_S",
    "QUEUE_WAIT_STEP_BUCKETS",
]

# Default bucket ladders (seconds unless named otherwise). TTFT spans
# warmed-AOT sub-millisecond dispatch up to cold multi-second admission;
# per-dispatch/step latencies sit one decade lower; queue wait is measured
# in scheduler steps (dispatch clock ticks), not seconds.
TTFT_BUCKETS_S = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)
STEP_LATENCY_BUCKETS_S = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0,
)
TPOT_BUCKETS_S = STEP_LATENCY_BUCKETS_S
QUEUE_WAIT_STEP_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)


class Counter:
    """Monotone counter. ``inc`` only; negative increments are rejected."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        self.value += n

    def as_dict(self) -> dict:
        return dict(type="counter", name=self.name, value=self.value)


class Gauge:
    """Last-value gauge with a high-water mark."""

    __slots__ = ("name", "value", "high_water", "_set")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.high_water = 0.0
        self._set = False

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        self.high_water = v if not self._set else max(self.high_water, v)
        self._set = True

    def as_dict(self) -> dict:
        return dict(
            type="gauge", name=self.name, value=self.value,
            high_water=self.high_water,
        )


class Histogram:
    """Explicit-bucket histogram with a bounded exact-sample store.

    ``buckets`` are the finite upper edges (``le``); one implicit +inf
    bucket catches the overflow. Edge values land in the bucket whose edge
    they equal (``v <= edge``), pinned by tests/test_obs.py.
    """

    __slots__ = (
        "name", "buckets", "counts", "count", "sum", "min", "max",
        "_samples", "max_samples", "samples_truncated",
    )

    def __init__(self, name: str, buckets: Sequence[float], max_samples: int = 65536):
        edges = tuple(float(b) for b in buckets)
        if not edges:
            raise ValueError(f"histogram {name}: needs at least one bucket edge")
        if any(b2 <= b1 for b1, b2 in zip(edges, edges[1:])):
            raise ValueError(f"histogram {name}: bucket edges must strictly increase")
        self.name = name
        self.buckets = edges
        self.counts = [0] * (len(edges) + 1)  # [+inf] overflow last
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: list[float] = []
        self.max_samples = int(max_samples)
        self.samples_truncated = False

    def observe(self, v: float) -> None:
        v = float(v)
        if math.isnan(v):
            return  # NaN observations (e.g. a request with no wall stamp) are skipped
        i = 0
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if len(self._samples) < self.max_samples:
            self._samples.append(v)
        else:
            self.samples_truncated = True

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        """q in [0, 100]. Exact (numpy-linear) while the raw sample store
        holds every observation; bucket-interpolated once truncated."""
        if not self.count:
            return float("nan")
        if not self.samples_truncated:
            import numpy as np

            return float(np.percentile(np.asarray(self._samples), q))
        rank = (q / 100.0) * self.count
        seen = 0.0
        lo = 0.0 if self.min > 0 else self.min
        for i, c in enumerate(self.counts):
            if not c:
                continue
            hi = self.buckets[i] if i < len(self.buckets) else self.max
            if seen + c >= rank:
                frac = (rank - seen) / c
                return lo + frac * (hi - lo)
            seen += c
            lo = hi
        return self.max

    def as_dict(self) -> dict:
        return dict(
            type="histogram",
            name=self.name,
            buckets=list(self.buckets),
            counts=list(self.counts),
            count=self.count,
            sum=self.sum,
            min=self.min if self.count else None,
            max=self.max if self.count else None,
            mean=self.mean if self.count else None,
            p50=self.percentile(50) if self.count else None,
            p90=self.percentile(90) if self.count else None,
            p99=self.percentile(99) if self.count else None,
            samples_truncated=self.samples_truncated,
        )


class MetricsRegistry:
    """Get-or-create registry of named metrics; one per Recorder."""

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, kind, *args, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = kind(name, *args, **kwargs)
            self._metrics[name] = m
        elif not isinstance(m, kind):
            raise TypeError(
                f"metric {name!r} already registered as {type(m).__name__}, "
                f"requested {kind.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, buckets: Optional[Sequence[float]] = None) -> Histogram:
        if name in self._metrics:
            return self._get(name, Histogram)
        if buckets is None:
            raise ValueError(f"histogram {name!r} not registered and no buckets given")
        return self._get(name, Histogram, buckets)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def items(self):
        """Live (name, metric) pairs — cheap iteration WITHOUT serializing
        aggregates (``as_dict`` computes histogram percentiles; the alert
        engine's per-tick path must not pay that for metrics it never
        reads)."""
        return self._metrics.items()

    def as_dict(self) -> dict:
        return {name: m.as_dict() for name, m in sorted(self._metrics.items())}
