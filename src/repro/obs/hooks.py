"""Shared instrumentation hooks for the serving engines.

The continuous-batching engine and the sharded fleet engine trace the same
request lifecycle — enqueue → admit (packed bucket or chunk stream) →
decode ticks → retire — so the span bookkeeping lives here once and both
engines call it at their dispatch boundaries. Everything is host-side: no
hook runs inside traced code, so enabling a recorder cannot change a
sampled token (pinned by tests/test_obs.py).

Track layout (what Perfetto draws):

* one track per decode slot (``slot3``, or ``chip1/slot3`` for the fleet)
  carrying that slot's ``admit``/``chunk`` spans, the per-request
  ``decode`` span (admission → retirement) and the ``retire`` instant;
* one ``engine`` track per process carrying the fused ``decode_step``
  dispatch spans;
* one ``pages`` counter track per allocator (:class:`PoolMonitor`)
  sampling free/in-use/high-water/alloc-failure series.
"""
from __future__ import annotations

from typing import Optional

from repro.obs.metrics import (
    QUEUE_WAIT_STEP_BUCKETS,
    STEP_LATENCY_BUCKETS_S,
    TPOT_BUCKETS_S,
    TTFT_BUCKETS_S,
)
from repro.obs.recorder import NULL_RECORDER, Recorder

__all__ = ["RequestTracer", "PoolMonitor"]


class RequestTracer:
    """Per-request lifecycle spans on per-slot tracks, plus the request
    latency histograms (TTFT, time-per-output-token, queue wait, prefill
    latency) every serving tier records the same way."""

    def __init__(self, recorder: Optional[Recorder], *, proc: str = "serve",
                 track_prefix: str = ""):
        self.rec = recorder if recorder is not None else NULL_RECORDER
        self.proc = proc
        self.prefix = track_prefix
        self._decode_t0: dict = {}  # rid -> trace time its decode life began

    def __bool__(self) -> bool:
        return bool(self.rec)

    def _slot_track(self, slot: int) -> str:
        return f"{self.prefix}slot{slot}"

    # -- admission ---------------------------------------------------------

    def admitted(self, rid: int, slot: int, t0: float, t1: float, *,
                 args: Optional[dict] = None) -> None:
        """One request admitted by a prefill dispatch spanning [t0, t1]
        (several packed requests share the dispatch — each gets an ``admit``
        span on its own slot track). Starts the request's decode span."""
        if not self.rec:
            return
        self.rec.span("admit", proc=self.proc, track=self._slot_track(slot),
                      t0=t0, t1=t1, args=dict(rid=rid, **(args or {})))
        self.rec.observe("serve.prefill_admit_s", t1 - t0, STEP_LATENCY_BUCKETS_S)
        self._decode_t0[rid] = t1

    def chunk(self, rid: int, slot: int, t0: float, t1: float, *,
              final: bool, args: Optional[dict] = None) -> None:
        """One chunk of a long prompt streamed into the slot's page chain;
        the final chunk activates the slot and starts the decode span."""
        if not self.rec:
            return
        self.rec.span("chunk", proc=self.proc, track=self._slot_track(slot),
                      t0=t0, t1=t1, args=dict(rid=rid, final=final, **(args or {})))
        self.rec.observe("serve.prefill_chunk_s", t1 - t0, STEP_LATENCY_BUCKETS_S)
        if final:
            self._decode_t0[rid] = t1

    # -- decode ------------------------------------------------------------

    def decode_dispatch(self, t0: float, t1: float, *, n_active: int,
                        clock: int) -> None:
        """One fused decode dispatch (all active slots advance a token)."""
        if not self.rec:
            return
        self.rec.span("decode_step", proc=self.proc, track=f"{self.prefix}engine",
                      t0=t0, t1=t1, args=dict(n_active=n_active, clock=clock))
        self.rec.observe("serve.decode_step_s", t1 - t0, STEP_LATENCY_BUCKETS_S)

    # -- retirement --------------------------------------------------------

    def retired(self, out, slot: int, t1: float) -> None:
        """Request ``out`` (a RequestOutput) left slot ``slot`` at trace
        time ``t1``: close its decode span, mark the retirement, record its
        latency histograms."""
        if not self.rec:
            return
        t0 = self._decode_t0.pop(out.rid, t1)
        track = self._slot_track(slot)
        n = len(out.tokens)
        self.rec.span(
            "decode", proc=self.proc, track=track, t0=t0, t1=t1,
            args=dict(rid=out.rid, tokens=n, finish_reason=out.finish_reason),
        )
        self.rec.instant(
            "retire", proc=self.proc, track=track,
            args=dict(rid=out.rid, finish_reason=out.finish_reason,
                      queue_wait_steps=out.queue_wait_steps),
        )
        self.rec.count("serve.requests_retired")
        self.rec.count("serve.tokens_emitted", n)
        self.rec.observe("serve.ttft_wall_s", out.ttft_wall_s, TTFT_BUCKETS_S)
        self.rec.observe("serve.queue_wait_steps", float(out.queue_wait_steps),
                         QUEUE_WAIT_STEP_BUCKETS)
        if n > 1:
            self.rec.observe("serve.tpot_s", (t1 - t0) / (n - 1), TPOT_BUCKETS_S)


class PoolMonitor:
    """Page-pool gauge sampling at dispatch boundaries: free pages, pages
    in use, the allocator high-water mark and its admission-failure count,
    as Chrome counter-track series + registry gauges."""

    def __init__(self, recorder: Optional[Recorder], alloc, *,
                 proc: str = "serve", track: str = "pages",
                 name_prefix: str = "kv."):
        self.rec = recorder if recorder is not None else NULL_RECORDER
        self.alloc = alloc
        self.proc = proc
        self.track = track
        self.prefix = name_prefix
        self._last: Optional[tuple] = None

    def __bool__(self) -> bool:
        return bool(self.rec)

    def sample(self) -> None:
        """Record the pool's current state; consecutive identical samples
        collapse (only changes are recorded, so idle ticks are free)."""
        if not self.rec:
            return
        a = self.alloc
        state = (a.free_pages, a.pages_in_use, a.high_water, a.alloc_failures)
        if state == self._last:
            return
        self._last = state
        self._emit(state)

    def flush(self) -> None:
        """Emit the current state unconditionally — called at serve end so
        every counter series extends to the trace's final timestamp instead
        of cutting off at its last *change* (the dedupe above never emits a
        closing sample on its own)."""
        if not self.rec:
            return
        a = self.alloc
        state = (a.free_pages, a.pages_in_use, a.high_water, a.alloc_failures)
        self._last = state
        self._emit(state)

    def _emit(self, state: tuple) -> None:
        p, t = self.proc, self.track
        self.rec.sample(self.prefix + "free_pages", state[0], proc=p, track=t)
        self.rec.sample(self.prefix + "pages_in_use", state[1], proc=p, track=t)
        self.rec.sample(self.prefix + "high_water", state[2], proc=p, track=t)
        self.rec.sample(self.prefix + "alloc_failures", state[3], proc=p, track=t)
