"""Per-chip health scoring — EWMA detectors + a debounced state machine.

Sits between the raw sensors (``repro.obs.abft`` probe results, per-decode
logit statistics, ``PageAllocator`` telemetry) and the consumers (the
alert engine, and the drain/FAM-swap recovery loop ROADMAP item 2 builds
next). Per chip it keeps:

* EWMA detectors over canary mismatch counts and checksum syndromes
  (hard, bitwise-grounded evidence), a z-score drift detector over the
  mean emitted-token logprob (soft evidence), and an allocator
  backpressure EWMA;
* a **debounced** ``healthy -> suspect -> degraded`` state machine driven
  by consecutive bad probes (``HealthConfig.suspect_after`` /
  ``degraded_after``), recovering after ``recover_after`` consecutive
  clean probes;
* a [0, 1] health score (EWMA of the per-tick evidence) recorded as a
  gauge series on the chip's own track, so Perfetto draws one health
  swimlane per chip next to its slot lanes.

Soft evidence (logit drift, backpressure) only moves the *score* by
default — state transitions need probe evidence, which is bitwise-exact
against the golden snapshot, so a healthy fleet can never false-positive
its way into ``suspect`` (gated in benchmarks/serve_bench.py). Set
``HealthConfig.drift_z`` to let sustained drift raise ``suspect`` on its
own (for deployments without a probe budget).

JAX-free on purpose: ``repro.launch.obs --check`` runs the full detector
stack against a numpy silicon model in milliseconds.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.obs.abft import ProbeResult
from repro.obs.recorder import NULL_RECORDER, Recorder

__all__ = [
    "HEALTHY",
    "SUSPECT",
    "DEGRADED",
    "STATE_LEVEL",
    "Ewma",
    "DriftDetector",
    "HealthConfig",
    "ChipHealth",
    "HealthTracker",
]

HEALTHY, SUSPECT, DEGRADED = "healthy", "suspect", "degraded"
STATE_LEVEL = {HEALTHY: 0, SUSPECT: 1, DEGRADED: 2}


@dataclass
class Ewma:
    """Exponentially-weighted moving average, seeded by its first sample."""

    alpha: float = 0.25
    value: float = 0.0
    initialized: bool = False

    def update(self, x: float) -> float:
        x = float(x)
        if not self.initialized:
            self.value = x
            self.initialized = True
        else:
            self.value += self.alpha * (x - self.value)
        return self.value


class DriftDetector:
    """EWMA mean/variance z-score: how far the current sample sits from the
    running distribution. Returns 0.0 during warmup (no baseline yet)."""

    def __init__(self, alpha: float = 0.05, warmup: int = 8,
                 min_std: float = 1e-3):
        self.mean = Ewma(alpha)
        self.var = Ewma(alpha)
        self.warmup = warmup
        self.min_std = min_std
        self.n = 0

    def update(self, x: float) -> float:
        x = float(x)
        self.n += 1
        if self.n <= self.warmup:
            self.mean.update(x)
            self.var.update((x - self.mean.value) ** 2)
            return 0.0
        z = (x - self.mean.value) / max(self.min_std, math.sqrt(self.var.value))
        self.mean.update(x)
        self.var.update((x - self.mean.value) ** 2)
        return z


@dataclass(frozen=True)
class HealthConfig:
    """Debounce thresholds and score weights for one fleet's detectors."""

    suspect_after: int = 2  # consecutive bad probes: healthy -> suspect
    degraded_after: int = 5  # consecutive bad probes: suspect -> degraded
    recover_after: int = 3  # consecutive clean probes: -> healthy
    drift_z: Optional[float] = None  # z threshold for drift-driven suspect
    drift_after: int = 5  # consecutive over-threshold drift ticks
    score_alpha: float = 0.25
    w_canary: float = 0.6
    w_syndrome: float = 0.3
    w_drift: float = 0.05
    w_backpressure: float = 0.05


@dataclass
class ChipHealth:
    """One chip's detector state; fed by :class:`HealthTracker`."""

    chip: int
    config: HealthConfig
    state: str = HEALTHY
    score: Ewma = field(init=False)
    drift: DriftDetector = field(default_factory=DriftDetector)
    backpressure: Ewma = field(default_factory=lambda: Ewma(0.1))
    bad_probes: int = 0  # consecutive
    clean_probes: int = 0  # consecutive
    drift_ticks: int = 0  # consecutive over-threshold
    probes: int = 0
    detections: int = 0  # healthy -> suspect transitions
    detected_at: Optional[int] = None  # clock of the FIRST detection
    last_delta: Optional[np.ndarray] = None  # bool (R, C) reconstructed
    last_result: Optional[ProbeResult] = None
    transitions: list = field(default_factory=list)  # (clock, frm, to, why)
    _alloc_failures: int = 0

    def __post_init__(self):
        self.score = Ewma(self.config.score_alpha, value=1.0, initialized=True)

    def _transition(self, to: str, clock: Optional[int], why: str):
        frm = self.state
        self.state = to
        self.transitions.append((clock, frm, to, why))
        if frm == HEALTHY and to != HEALTHY:
            self.detections += 1
            if self.detected_at is None:
                self.detected_at = clock
        return (clock, frm, to, why)

    def observe_probe(self, result: ProbeResult, *, clock: Optional[int] = None):
        """Feed one probe tick; returns the transition tuple if the state
        machine moved, else None."""
        cfg = self.config
        self.probes += 1
        self.last_result = result
        if result.delta is not None and result.delta.any():
            self.last_delta = result.delta
        bad = result.detected
        if bad:
            self.bad_probes += 1
            self.clean_probes = 0
        else:
            self.clean_probes += 1
            self.bad_probes = 0
        ncols = max(1, result.syndrome_cols.size)
        penalty = (
            cfg.w_canary * (1.0 if result.canary_mismatches else 0.0)
            + cfg.w_syndrome
            * min(1.0, float((result.syndrome_cols > 0).sum()) / ncols * 4.0)
        )
        self.score.update(max(0.0, 1.0 - penalty))
        if self.state == HEALTHY and self.bad_probes >= cfg.suspect_after:
            return self._transition(SUSPECT, clock, "probe")
        if self.state == SUSPECT and self.bad_probes >= cfg.degraded_after:
            return self._transition(DEGRADED, clock, "probe")
        if self.state != HEALTHY and self.clean_probes >= cfg.recover_after:
            return self._transition(HEALTHY, clock, "recovered")
        return None

    def observe_decode(self, *, clock: Optional[int] = None,
                       mean_logprob: Optional[float] = None,
                       alloc_failures: Optional[int] = None):
        """Feed one decode dispatch's soft telemetry; may transition only
        when ``HealthConfig.drift_z`` is set."""
        cfg = self.config
        soft = 0.0
        if mean_logprob is not None and math.isfinite(mean_logprob):
            z = self.drift.update(mean_logprob)
            over = cfg.drift_z is not None and abs(z) > cfg.drift_z
            self.drift_ticks = self.drift_ticks + 1 if over else 0
            soft += cfg.w_drift * min(1.0, abs(z) / 6.0)
        if alloc_failures is not None:
            delta = max(0, alloc_failures - self._alloc_failures)
            self._alloc_failures = alloc_failures
            soft += cfg.w_backpressure * self.backpressure.update(
                1.0 if delta else 0.0
            )
        self.score.update(max(0.0, 1.0 - soft))
        if (
            cfg.drift_z is not None
            and self.state == HEALTHY
            and self.drift_ticks >= cfg.drift_after
        ):
            return self._transition(SUSPECT, clock, "logit-drift")
        return None

    def summary(self) -> dict:
        delta = self.last_delta
        return dict(
            chip=self.chip,
            state=self.state,
            score=self.score.value,
            probes=self.probes,
            detections=self.detections,
            detected_at=self.detected_at,
            bad_probes=self.bad_probes,
            delta_faults=int(delta.sum()) if delta is not None else 0,
            delta_coords=[
                [int(a), int(b)] for a, b in zip(*np.nonzero(delta))
            ][:64] if delta is not None else [],
            transitions=[
                dict(clock=t[0], frm=t[1], to=t[2], why=t[3])
                for t in self.transitions
            ],
        )


class HealthTracker:
    """Fleet-wide health: one :class:`ChipHealth` per chip, recorded as
    gauge series (``health.chip{c}.score`` / ``.state``) on per-chip
    tracks plus ``health.transition`` / ``fault.detected`` instants and a
    ``health.detections`` counter — the signal surface the alert engine's
    rules and the Chrome-trace swimlanes read."""

    def __init__(self, num_chips: int, recorder: Optional[Recorder] = None, *,
                 config: Optional[HealthConfig] = None, proc: str = "serve",
                 track_of=None):
        if num_chips < 1:
            raise ValueError(f"num_chips must be >= 1, got {num_chips}")
        self.config = config or HealthConfig()
        self.rec = recorder if recorder is not None else NULL_RECORDER
        self.proc = proc
        self.chips = [ChipHealth(c, self.config) for c in range(num_chips)]
        if track_of is None:
            track_of = (
                (lambda c: "health") if num_chips == 1
                else (lambda c: f"chip{c}/health")
            )
        self._track_of = track_of

    def __bool__(self) -> bool:
        return True

    # -- feeding -----------------------------------------------------------

    def _record_state(self, ch: ChipHealth):
        if not self.rec:
            return
        t = self._track_of(ch.chip)
        self.rec.sample(f"health.chip{ch.chip}.score", ch.score.value,
                        proc=self.proc, track=t)
        self.rec.sample(f"health.chip{ch.chip}.state", STATE_LEVEL[ch.state],
                        proc=self.proc, track=t)

    def _record_transition(self, ch: ChipHealth, moved, result=None):
        if not self.rec or moved is None:
            return
        clock, frm, to, why = moved
        args = dict(chip=ch.chip, clock=clock, frm=frm, to=to, why=why)
        self.rec.instant("health.transition", proc=self.proc,
                         track=self._track_of(ch.chip), args=args)
        if frm == HEALTHY and to != HEALTHY:
            self.rec.count("health.detections")
            det = dict(args)
            if result is not None:
                det.update(result.as_dict())
            self.rec.instant("fault.detected", proc=self.proc,
                             track=self._track_of(ch.chip), args=det)

    def observe_probe(self, chip: int, result: ProbeResult, *,
                      clock: Optional[int] = None):
        ch = self.chips[chip]
        moved = ch.observe_probe(result, clock=clock)
        self._record_transition(ch, moved, result)
        self._record_state(ch)
        return moved

    def observe_decode(self, chip: int, *, clock: Optional[int] = None,
                       mean_logprob: Optional[float] = None,
                       alloc_failures: Optional[int] = None):
        ch = self.chips[chip]
        moved = ch.observe_decode(clock=clock, mean_logprob=mean_logprob,
                                  alloc_failures=alloc_failures)
        self._record_transition(ch, moved)
        return moved

    def finalize(self) -> None:
        """Closing gauge samples so every chip's health series extends to
        the end of the trace (mirrors ``PoolMonitor.flush``)."""
        for ch in self.chips:
            self._record_state(ch)

    # -- queries -----------------------------------------------------------

    def state(self, chip: int) -> str:
        return self.chips[chip].state

    def score(self, chip: int) -> float:
        return self.chips[chip].score.value

    def detected_at(self, chip: int) -> Optional[int]:
        return self.chips[chip].detected_at

    def last_delta(self, chip: int) -> Optional[np.ndarray]:
        return self.chips[chip].last_delta

    @property
    def detections(self) -> int:
        return sum(ch.detections for ch in self.chips)

    def summary(self) -> dict:
        return dict(
            num_chips=len(self.chips),
            detections=self.detections,
            states={ch.chip: ch.state for ch in self.chips},
            chips=[ch.summary() for ch in self.chips],
        )
