"""Declarative alert/SLO rules over the MetricsRegistry.

An :class:`AlertRule` names a metric (exact name or an ``fnmatch`` glob —
``health.chip*.state`` spans a whole fleet), the field to read off its
aggregate (gauge ``value``/``high_water``, counter ``value``, histogram
``count``/``mean``/``min``/``max``/``p50``/``p90``/``p99``), a comparison
against a threshold, an aggregation across glob matches (``max``/``min``/
``sum``) and a debounce (``for_ticks`` consecutive breaching evaluations
before firing).

:class:`AlertEngine` evaluates its rules against a
:class:`~repro.obs.recorder.Recorder`'s registry — the serving engines
call :meth:`AlertEngine.evaluate` at probe cadence — and records state
changes back INTO the recorder: an ``alert`` instant per fire/resolve on
a per-rule track under the ``alerts`` proc (its own Perfetto swimlane in
the Chrome-trace export), plus ``alerts.fired``/``alerts.resolved``
counters and an ``alerts.firing`` gauge. ``repro.launch.obs --summary``
surfaces those instants from saved JSONL logs, and ``--summary X
--check`` exits nonzero when any rule fired during the run.

Missing metrics make a rule *inactive* (no data is not a breach), so one
default rule set serves both single-chip and fleet runs.

JAX-free on purpose (exercised by ``repro.launch.obs --check``).
"""
from __future__ import annotations

from dataclasses import dataclass
from fnmatch import fnmatchcase
from typing import Optional, Sequence

from repro.obs.metrics import Counter, Gauge, Histogram
from repro.obs.recorder import NULL_RECORDER, Recorder

__all__ = ["AlertRule", "AlertEngine", "default_slo_rules", "detection_rules"]

_OPS = {
    ">": lambda v, t: v > t,
    ">=": lambda v, t: v >= t,
    "<": lambda v, t: v < t,
    "<=": lambda v, t: v <= t,
}
_AGGS = {"max": max, "min": min, "sum": sum}
_FIELDS = {
    "counter": ("value",),
    "gauge": ("value", "high_water"),
    "histogram": ("count", "mean", "min", "max", "p50", "p90", "p99"),
}
_PCT = {"p50": 50.0, "p90": 90.0, "p99": 99.0}


def _metric_field(m, field: str) -> Optional[float]:
    """Read one field off a LIVE metric object, computing only what the
    rule asks for (``as_dict`` would serialize three percentiles per
    histogram per tick). Returns None for a field the kind lacks."""
    if isinstance(m, Counter):
        return float(m.value) if field == "value" else None
    if isinstance(m, Gauge):
        if field in ("value", "high_water"):
            return float(getattr(m, field))
        return None
    if isinstance(m, Histogram):
        if field not in _FIELDS["histogram"] or not m.count:
            return None
        if field == "count":
            return float(m.count)
        if field in _PCT:
            return float(m.percentile(_PCT[field]))
        return float(getattr(m, field))
    return None


@dataclass(frozen=True)
class AlertRule:
    """One declarative threshold rule; see module docstring for schema."""

    name: str
    metric: str  # exact metric name or fnmatch glob
    op: str  # ">" ">=" "<" "<="
    threshold: float
    field: str = "value"
    agg: str = "max"  # across glob matches
    for_ticks: int = 1  # consecutive breaching evaluations before firing
    severity: str = "warn"  # "warn" | "page"

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"rule {self.name!r}: unknown op {self.op!r}")
        if self.agg not in _AGGS:
            raise ValueError(f"rule {self.name!r}: unknown agg {self.agg!r}")
        if self.for_ticks < 1:
            raise ValueError(f"rule {self.name!r}: for_ticks must be >= 1")
        if not any(self.field in fields for fields in _FIELDS.values()):
            raise ValueError(f"rule {self.name!r}: unknown field {self.field!r}")
        if self.severity not in ("warn", "page"):
            raise ValueError(
                f"rule {self.name!r}: severity must be 'warn' or 'page'"
            )

    def as_dict(self) -> dict:
        return dict(name=self.name, metric=self.metric, field=self.field,
                    op=self.op, threshold=self.threshold, agg=self.agg,
                    for_ticks=self.for_ticks, severity=self.severity)


def default_slo_rules(*, ttft_p99_s: float = 5.0,
                      min_health_score: float = 0.5) -> tuple[AlertRule, ...]:
    """The serving SLO set: tail latency + the detection layer's outputs."""
    return (
        AlertRule("slo.ttft_p99", "serve.ttft_wall_s", ">", ttft_p99_s,
                  field="p99"),
        AlertRule("health.chip_suspect", "health.chip*.state", ">=", 1.0,
                  agg="max", severity="page"),
        AlertRule("health.low_score", "health.chip*.score", "<",
                  min_health_score, agg="min"),
        AlertRule("detect.new_faults", "health.detections", ">", 0.0,
                  agg="max", severity="page"),
    )


def detection_rules() -> tuple[AlertRule, ...]:
    """Detection-only subset: rules that can ONLY fire on real probe/health
    evidence — what the healthy-fleet zero-false-positive gate attaches."""
    return tuple(r for r in default_slo_rules()
                 if r.name.startswith(("health.", "detect.")))


class AlertEngine:
    """Evaluate rules against a recorder's metrics; record fire/resolve."""

    def __init__(self, recorder: Optional[Recorder],
                 rules: Sequence[AlertRule], *, proc: str = "alerts"):
        self.rec = recorder if recorder is not None else NULL_RECORDER
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate rule names: {sorted(names)}")
        self.rules = tuple(rules)
        self.proc = proc
        self._streak = {r.name: 0 for r in self.rules}
        self._firing: dict[str, float] = {}  # rule -> breaching value at fire
        self._ever_fired: set[str] = set()  # rules that fired at ANY point
        self.fired_total = 0

    def __bool__(self) -> bool:
        return bool(self.rules)

    def _rule_value(self, rule: AlertRule, metrics) -> Optional[float]:
        vals = []
        for name, m in metrics:
            if name != rule.metric and not fnmatchcase(name, rule.metric):
                continue
            v = _metric_field(m, rule.field)
            if v is not None and v == v:  # skip missing/NaN
                vals.append(v)
        if not vals:
            return None
        return float(_AGGS[rule.agg](vals))

    def evaluate(self, *, clock: Optional[int] = None) -> list[str]:
        """One evaluation tick over every rule. Returns the names of rules
        that NEWLY fired this tick (debounce satisfied)."""
        metrics = list(self.rec.metrics.items())
        newly = []
        for rule in self.rules:
            v = self._rule_value(rule, metrics)
            breach = v is not None and _OPS[rule.op](v, rule.threshold)
            self._streak[rule.name] = self._streak[rule.name] + 1 if breach else 0
            if breach and rule.name not in self._firing and (
                self._streak[rule.name] >= rule.for_ticks
            ):
                self._firing[rule.name] = v  # type: ignore[assignment]
                self._ever_fired.add(rule.name)
                self.fired_total += 1
                newly.append(rule.name)
                if self.rec:
                    self.rec.count("alerts.fired")
                    self.rec.instant(
                        "alert", proc=self.proc, track=rule.name,
                        args=dict(state="firing", value=v, clock=clock,
                                  **rule.as_dict()),
                    )
            elif not breach and rule.name in self._firing:
                del self._firing[rule.name]
                if self.rec:
                    self.rec.count("alerts.resolved")
                    self.rec.instant(
                        "alert", proc=self.proc, track=rule.name,
                        args=dict(state="resolved", value=v, clock=clock,
                                  **rule.as_dict()),
                    )
        if self.rec:
            self.rec.gauge_set("alerts.firing", len(self._firing))
        return newly

    def firing(self) -> list[str]:
        return sorted(self._firing)

    def summary(self) -> dict:
        return dict(
            rules=[r.as_dict() for r in self.rules],
            firing=self.firing(),
            fired=sorted(self._ever_fired),
            fired_total=self.fired_total,
        )
