"""ABFT checksum probes — the fault SENSOR half of ROADMAP item 2.

Every serving path assumes a chip's :class:`~repro.core.faults.FaultMap`
is known before traffic starts, but permanent faults appear in the field.
Zhang et al. (arxiv 1802.04657) observe that a permanent systolic-array
fault corrupts masked-GEMM outputs in a *structured* way, and the
weight-stationary mapping here (``core/mapping.py::periodic_mask``) makes
the structure exact:

    y[m, b] = sum_a x[m, a] * W[a, b] * ok[a % R, b % C]

so a fault at PE ``(rho, c)`` perturbs ONLY output columns ``b`` with
``b % C == c``, through ONLY the weight rows ``a`` with ``a % R == rho``.
That gives two complementary probes, both dispatched through the live
masked path (``kernels/masked_matmul/ops.py::masked_matmul_checksummed``)
between decode steps:

* **canary probe** — a fixed pseudorandom input batch whose output is
  snapshotted at attach time. Healthy re-dispatches of the SAME compiled
  program on the SAME inputs are bitwise identical, so any nonzero
  difference is hard evidence of a silicon change (structurally zero
  false positives) and the appended checksum row localizes the faulty PE
  *columns* by folding the per-column syndrome mod C.
* **structured row probe** — R inputs, row ``rho`` carrying pseudorandom
  values on exactly the ``a % R == rho`` coordinates. Its syndrome
  factorizes per PE row, so thresholding the folded per-(row, col)
  syndrome reconstructs a candidate *delta* ``FaultMap`` — the newly
  faulty PEs relative to the believed map (validated against
  ``core/faults.py`` ground truth in tests/test_detect.py).

Everything in this module is host-side numpy; the only JAX touchpoint is
:func:`select_probe_weight` (lazy import), which picks the GEMM the
engines dispatch probes through. :class:`ChipProber` takes an opaque
``dispatch`` callable, so the same detector runs under the real jitted
path (engines), the interpreted Pallas kernel (tests) or a pure-numpy
silicon model (``repro.launch.obs --check``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

__all__ = [
    "ProbeResult",
    "ChipProber",
    "make_canary",
    "make_structured_probe",
    "periodic_mask_np",
    "fold_syndrome",
    "reconstruct_delta",
    "select_probe_weight",
]

# relative threshold on folded syndromes: healthy probes are bitwise
# identical to their golden snapshot (exact zero syndrome), so this only
# rejects float noise in the *reconstruction* after a real divergence
DEFAULT_REL_TOL = 1e-5


def periodic_mask_np(weight_shape: tuple[int, int], ok: np.ndarray) -> np.ndarray:
    """Numpy twin of ``core/mapping.py::periodic_mask`` for a 2-D weight:
    mask[a, b] = ok[a % R, b % C]. The detector's silicon model."""
    kdim, n = weight_shape
    r, c = ok.shape
    rows = np.arange(kdim) % r
    cols = np.arange(n) % c
    return np.asarray(ok, np.float32)[np.ix_(rows, cols)]


def make_canary(batch: int, k_dim: int, seed: int = 0) -> np.ndarray:
    """Fixed pseudorandom canary inputs (batch, K), float32 in [-1, 1)."""
    rng = np.random.default_rng(seed)
    return (rng.random((batch, k_dim), dtype=np.float32) * 2.0 - 1.0)


def make_structured_probe(k_dim: int, rows: int, seed: int = 0) -> np.ndarray:
    """Row-separating probe (R, K): probe row ``rho`` is nonzero exactly on
    the weight rows PE row ``rho`` serves (``a % R == rho``), with
    pseudorandom magnitudes in [0.5, 1.5) so no weight-row contribution
    cancels by construction."""
    rng = np.random.default_rng(seed)
    g = rng.random(k_dim, dtype=np.float32) + 0.5
    x = np.zeros((rows, k_dim), np.float32)
    rho = np.arange(k_dim) % rows
    x[rho, np.arange(k_dim)] = g
    return x


def fold_syndrome(syndrome: np.ndarray, cols: int) -> np.ndarray:
    """Fold an absolute per-output-column syndrome (..., N) onto the PE
    columns (..., C) by max over ``b % C == c`` — the mapping's period
    makes the fold exact, padding short tails with zero."""
    s = np.abs(np.asarray(syndrome, np.float64))
    n = s.shape[-1]
    pad = (-n) % cols
    if pad:
        s = np.concatenate(
            [s, np.zeros(s.shape[:-1] + (pad,), s.dtype)], axis=-1
        )
    return s.reshape(s.shape[:-1] + (-1, cols)).max(axis=-2)


def reconstruct_delta(
    expected: np.ndarray, actual: np.ndarray, cols: int,
    tol: float,
) -> np.ndarray:
    """Candidate newly-faulty PEs from a structured-probe divergence.

    ``expected``/``actual`` are the golden and live (R, N) probe outputs;
    the row-``rho`` syndrome lives only in columns served by PE row
    ``rho``, so folding each probe row's |syndrome| mod C and thresholding
    yields a bool (R, C) delta grid aligned with ``FaultMap.faulty``."""
    syn = np.asarray(actual, np.float64) - np.asarray(expected, np.float64)
    return fold_syndrome(syn, cols) > tol


def select_probe_weight(params) -> tuple[str, "np.ndarray"]:
    """Pick the probe GEMM target: the largest weight leaf under a
    fault-maskable key (``core/masking.py::MASKABLE_KEYS``) — the matmul a
    silicon fault is guaranteed to corrupt. Layer-stacked leaves
    (ndim > 2) contribute their first layer's (K, N) matrix: the periodic
    mask repeats per GEMM, so one representative slice exercises every PE.
    Returns (path, weight)."""
    import jax

    from repro.core.masking import MASKABLE_KEYS

    best: Optional[tuple[str, object]] = None
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        keys = {getattr(k, "key", getattr(k, "name", None)) for k in path}
        if not (keys & MASKABLE_KEYS):
            continue
        if getattr(leaf, "ndim", 0) < 2:
            continue
        mat = leaf[(0,) * (leaf.ndim - 2)] if leaf.ndim > 2 else leaf
        if best is None or mat.size > best[1].size:  # type: ignore[union-attr]
            best = (jax.tree_util.keystr(path), mat)
    if best is None:
        raise ValueError("params hold no fault-maskable weight matrix to probe")
    return best


@dataclass(frozen=True)
class ProbeResult:
    """One probe tick's verdict for one chip."""

    canary_mismatches: int  # elements of the canary output differing bitwise
    syndrome_cols: np.ndarray  # (C,) folded |checksum-row syndrome| per PE col
    detected: bool
    dispatches: int  # probe GEMM dispatches spent (1 clean, 2 on divergence)
    delta: Optional[np.ndarray] = None  # bool (R, C) candidate new faults
    clock: Optional[int] = None  # decode-dispatch index of the probe
    chip: int = 0

    @property
    def delta_faults(self) -> int:
        return int(self.delta.sum()) if self.delta is not None else 0

    def as_dict(self) -> dict:
        return dict(
            chip=self.chip,
            clock=self.clock,
            detected=bool(self.detected),
            canary_mismatches=int(self.canary_mismatches),
            syndrome_max=float(self.syndrome_cols.max())
            if self.syndrome_cols.size else 0.0,
            delta_faults=self.delta_faults,
            dispatches=self.dispatches,
        )


@dataclass
class ChipProber:
    """Golden-snapshot ABFT prober for one chip's masked-GEMM path.

    ``dispatch(x: (B, K) float32) -> (y: (B, N), check_row: (N,))`` must
    push ``x`` through the chip's LIVE checksummed masked matmul
    (``masked_matmul_checksummed``) and return host numpy arrays.
    :meth:`snapshot` records golden outputs under the *believed* fault
    map at attach time; every later :meth:`probe` re-dispatches the same
    inputs through the same compiled program, so a healthy chip's probe
    is bitwise identical to its golden (zero false positives by
    construction) and any divergence is localized via the syndrome math
    above. After a recovery action rebases the believed map, call
    :meth:`rebase` to re-snapshot.
    """

    dispatch: Callable[[np.ndarray], tuple[np.ndarray, np.ndarray]]
    array_shape: tuple[int, int]  # (R, C) — the PE grid / FaultMap shape
    k_dim: int  # contraction dim of the probed GEMM
    canary_batch: int = 4
    seed: int = 0
    rel_tol: float = DEFAULT_REL_TOL
    chip: int = 0
    canary_x: np.ndarray = field(init=False)
    probe_x: np.ndarray = field(init=False)

    def __post_init__(self):
        r, c = self.array_shape
        if r < 1 or c < 1:
            raise ValueError(f"bad PE array shape {self.array_shape}")
        self.canary_x = make_canary(self.canary_batch, self.k_dim, self.seed)
        self.probe_x = make_structured_probe(self.k_dim, r, self.seed + 1)
        self._gold_canary_y: Optional[np.ndarray] = None
        self._gold_canary_check: Optional[np.ndarray] = None
        self._gold_probe_y: Optional[np.ndarray] = None
        self._tol = 0.0
        self.snapshot()

    def snapshot(self) -> None:
        """(Re)record golden outputs under the currently-believed map."""
        y, chk = self.dispatch(self.canary_x)
        self._gold_canary_y = np.asarray(y).copy()
        self._gold_canary_check = np.asarray(chk, np.float64).copy()
        py, _ = self.dispatch(self.probe_x)
        self._gold_probe_y = np.asarray(py, np.float64).copy()
        self._tol = self.rel_tol * max(
            1.0, float(np.abs(self._gold_probe_y).max(initial=0.0)),
            float(np.abs(self._gold_canary_check).max(initial=0.0)),
        )

    rebase = snapshot  # recovery PRs re-baseline after adopting a new map

    def probe(self, *, clock: Optional[int] = None) -> ProbeResult:
        """One detection tick: canary first (cheap, bitwise-exact), then —
        only on divergence — the structured probe to reconstruct which PEs
        newly died."""
        _, c = self.array_shape
        y, chk = self.dispatch(self.canary_x)
        mism = int((np.asarray(y) != self._gold_canary_y).sum())
        syn = np.asarray(chk, np.float64) - self._gold_canary_check
        syndrome_cols = fold_syndrome(syn, c)
        detected = mism > 0 or bool((syndrome_cols > self._tol).any())
        delta = None
        dispatches = 1
        if detected:
            py, _ = self.dispatch(self.probe_x)
            delta = reconstruct_delta(self._gold_probe_y, py, c, self._tol)
            dispatches = 2
        return ProbeResult(
            canary_mismatches=mism, syndrome_cols=syndrome_cols,
            detected=detected, dispatches=dispatches, delta=delta,
            clock=clock, chip=self.chip,
        )
