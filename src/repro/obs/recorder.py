"""The Recorder — bounded structured-event log + metrics registry.

One :class:`Recorder` instance is shared by everything a process observes
(serve engine, fleet engine, population trainer); engines take it as an
optional constructor argument and fall back to the module-level
:data:`NULL_RECORDER`, a permanently-disabled instance that makes every
record call a cheap early return — so an uninstrumented run pays one
truthiness check per hook site and nothing else.

Events live in a **bounded ring buffer** (:class:`RingBuffer`): when the
buffer is full the oldest event is overwritten and ``dropped`` increments,
so a long-running server can never grow without bound. Metrics
(:mod:`repro.obs.metrics`) are aggregates and never dropped.

Event kinds (mirroring the Chrome trace-event phases they export to —
see :mod:`repro.obs.export`):

* ``span`` — a closed interval on a named track (``ph: "X"``): decode
  dispatches, prefill admissions, per-request decode lifetimes, training
  chunk submissions.
* ``instant`` — a point event (``ph: "i"``): request retirement,
  constraint crossings, schedule decisions.
* ``sample`` — a timestamped numeric sample of a named series on a track
  (``ph: "C"``): page-pool free/in-use, backpressure stalls.

Every event carries a ``proc`` (process lane: "serve", "fleet", "train")
and a ``track`` (thread lane: "engine", "slot3", "chip1/slot0", …); the
Chrome exporter maps those to pid/tid so Perfetto draws one swimlane per
track.

Timestamps are ``time.perf_counter()`` seconds relative to the recorder's
construction (``t0``); ``wall0`` keeps the construction wall-clock epoch
for cross-process alignment. The recorder accumulates its own cost in
``self_time_s`` — the overhead model the serve bench gates on
(``benchmarks/serve_bench.py --heavy-traffic``): recording must stay a
few percent of wall time, and enabling it must change zero sampled tokens
(all hooks are host-side, outside traced code).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Iterator, Optional

from repro.obs.metrics import MetricsRegistry

__all__ = ["Event", "RingBuffer", "Recorder", "NULL_RECORDER"]

JSONL_VERSION = 1


@dataclass(frozen=True)
class Event:
    """One recorded event. ``ts``/``dur`` are seconds relative to the
    recorder's ``t0``; ``dur`` is None for instants, ``value`` is set for
    samples only."""

    kind: str  # "span" | "instant" | "sample"
    name: str
    proc: str
    track: str
    ts: float
    dur: Optional[float] = None
    value: Optional[float] = None
    args: Optional[dict] = None

    def as_dict(self) -> dict:
        d = dict(kind=self.kind, name=self.name, proc=self.proc,
                 track=self.track, ts=self.ts)
        if self.dur is not None:
            d["dur"] = self.dur
        if self.value is not None:
            d["value"] = self.value
        if self.args:
            d["args"] = self.args
        return d


@dataclass
class RingBuffer:
    """Fixed-capacity overwrite-oldest event store."""

    capacity: int
    _buf: list = field(default_factory=list)
    _head: int = 0  # next write position once the buffer is full
    dropped: int = 0

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {self.capacity}")

    def append(self, item) -> None:
        if len(self._buf) < self.capacity:
            self._buf.append(item)
        else:
            self._buf[self._head] = item
            self._head = (self._head + 1) % self.capacity
            self.dropped += 1

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator:
        """Oldest-first iteration."""
        yield from self._buf[self._head:]
        yield from self._buf[: self._head]


class Recorder:
    """Bounded event log + metrics registry; see module docstring."""

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self.enabled = bool(enabled)
        self.events = RingBuffer(capacity)
        self.metrics = MetricsRegistry()
        self.t0 = time.perf_counter()
        self.wall0 = time.time()
        self.self_time_s = 0.0

    def __bool__(self) -> bool:
        # hook sites gate all host bookkeeping on `if recorder:` — a
        # disabled recorder costs one truthiness check per site
        return self.enabled

    # -- clock ------------------------------------------------------------

    def now(self) -> float:
        """Seconds since this recorder's t0 (the trace epoch)."""
        return time.perf_counter() - self.t0

    # -- event emission ---------------------------------------------------

    def _emit(self, ev: Event) -> None:
        self.events.append(ev)

    def span(self, name: str, *, proc: str = "serve", track: str = "engine",
             t0: float, t1: Optional[float] = None,
             args: Optional[dict] = None) -> None:
        """Record a closed interval [t0, t1] (recorder-relative seconds;
        ``t1=None`` closes at now). Use :meth:`timed` for the common
        wrap-a-block case."""
        if not self.enabled:
            return
        s = time.perf_counter()
        if t1 is None:
            t1 = s - self.t0
        self._emit(Event("span", name, proc, track, t0, dur=max(0.0, t1 - t0),
                         args=args))
        self.self_time_s += time.perf_counter() - s

    @contextmanager
    def timed(self, name: str, *, proc: str = "serve", track: str = "engine",
              args: Optional[dict] = None):
        """Context manager emitting one span over the enclosed block."""
        if not self.enabled:
            yield
            return
        t0 = self.now()
        try:
            yield
        finally:
            self.span(name, proc=proc, track=track, t0=t0, args=args)

    def instant(self, name: str, *, proc: str = "serve", track: str = "engine",
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        s = time.perf_counter()
        self._emit(Event("instant", name, proc, track, s - self.t0, args=args))
        self.self_time_s += time.perf_counter() - s

    def sample(self, name: str, value: float, *, proc: str = "serve",
               track: str = "engine") -> None:
        """Timestamped numeric sample (Chrome counter track); also mirrors
        into the gauge of the same name so the last value + high-water are
        queryable without scanning events."""
        if not self.enabled:
            return
        s = time.perf_counter()
        self._emit(Event("sample", name, proc, track, s - self.t0,
                         value=float(value)))
        self.metrics.gauge(name).set(value)
        self.self_time_s += time.perf_counter() - s

    # -- metric shorthands (enabled-gated like event emission) ------------

    def count(self, name: str, n: int | float = 1) -> None:
        if not self.enabled:
            return
        self.metrics.counter(name).inc(n)

    def observe(self, name: str, value: float, buckets=None) -> None:
        if not self.enabled:
            return
        s = time.perf_counter()
        self.metrics.histogram(name, buckets).observe(value)
        self.self_time_s += time.perf_counter() - s

    def gauge_set(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        self.metrics.gauge(name).set(value)

    # -- summaries --------------------------------------------------------

    def event_list(self) -> list[Event]:
        return list(self.events)

    def summary(self) -> dict:
        """Everything aggregate: metric dump + event accounting + the
        recorder's own overhead model. When the ring overwrote events the
        summary says so loudly (``ring`` subdict + a ``warnings`` entry) —
        a trace built from this recorder is missing its oldest events."""
        kinds: dict[str, int] = {}
        for ev in self.events:
            kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
        dropped = self.events.dropped
        out = dict(
            events=len(self.events),
            events_dropped=dropped,
            event_kinds=kinds,
            self_time_s=self.self_time_s,
            ring=dict(capacity=self.events.capacity, len=len(self.events),
                      dropped=dropped),
            metrics=self.metrics.as_dict(),
        )
        if dropped:
            out["warnings"] = [
                f"ring overwrote {dropped} event(s) (capacity "
                f"{self.events.capacity}); the oldest events are missing — "
                "grow Recorder(capacity=...) for complete traces"
            ]
        return out


class _NullRecorder(Recorder):
    """Permanently disabled; shared singleton. Guards against accidental
    state accumulation if a hook site forgets its `if recorder:` gate."""

    def __init__(self):
        super().__init__(capacity=1, enabled=False)

    def __setattr__(self, k: str, v: Any):
        if k == "enabled" and getattr(self, "enabled", None) is False:
            raise AttributeError("NULL_RECORDER cannot be enabled; make a Recorder()")
        super().__setattr__(k, v)


NULL_RECORDER = _NullRecorder()
