"""repro.obs — the runtime observability layer (see README.md here).

One :class:`Recorder` (bounded event ring + metrics registry) is shared by
the serve, fleet and train stacks; engines accept it as an optional
constructor argument and record nothing when it is absent. Exporters
produce a lossless JSONL event log and a Chrome trace-event file viewable
in Perfetto; ``python -m repro.launch.obs`` converts/validates/summarizes
recordings offline.

The detection layer (ROADMAP item 2) lives here too: ABFT checksum/canary
probes (:mod:`repro.obs.abft`), per-chip EWMA health scoring with a
debounced healthy→suspect→degraded state machine
(:mod:`repro.obs.health`), and the declarative alert/SLO engine over the
metrics registry (:mod:`repro.obs.alerts`).
"""
from repro.obs.abft import ChipProber, ProbeResult
from repro.obs.alerts import (
    AlertEngine,
    AlertRule,
    default_slo_rules,
    detection_rules,
)
from repro.obs.export import (
    chrome_trace,
    jsonl_to_chrome,
    read_jsonl,
    validate_chrome_trace,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.health import (
    DEGRADED,
    HEALTHY,
    SUSPECT,
    ChipHealth,
    HealthConfig,
    HealthTracker,
)
from repro.obs.hooks import PoolMonitor, RequestTracer
from repro.obs.metrics import (
    QUEUE_WAIT_STEP_BUCKETS,
    STEP_LATENCY_BUCKETS_S,
    TPOT_BUCKETS_S,
    TTFT_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.recorder import NULL_RECORDER, Event, Recorder, RingBuffer

__all__ = [
    "AlertEngine",
    "AlertRule",
    "ChipHealth",
    "ChipProber",
    "Counter",
    "DEGRADED",
    "Event",
    "Gauge",
    "HEALTHY",
    "HealthConfig",
    "HealthTracker",
    "Histogram",
    "MetricsRegistry",
    "NULL_RECORDER",
    "PoolMonitor",
    "ProbeResult",
    "QUEUE_WAIT_STEP_BUCKETS",
    "Recorder",
    "RequestTracer",
    "RingBuffer",
    "SUSPECT",
    "STEP_LATENCY_BUCKETS_S",
    "TPOT_BUCKETS_S",
    "TTFT_BUCKETS_S",
    "chrome_trace",
    "default_slo_rules",
    "detection_rules",
    "jsonl_to_chrome",
    "read_jsonl",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_jsonl",
]
