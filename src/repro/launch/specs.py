"""ShapeDtypeStruct stand-ins for every model input (no device allocation),
plus the logical-axes trees used to resolve in/out shardings per cell."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as M
from repro.train.optimizer import AdamWConfig, adamw_init


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> tuple[dict, dict]:
    """Returns (batch ShapeDtypeStructs, batch logical-axes tree).

    train/prefill: full-sequence inputs; decode: one new token per sequence
    (the KV cache is a separate argument — see cache_struct)."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    i32 = jnp.int32
    f32 = jnp.float32
    structs: dict[str, Any] = {}
    axes: dict[str, Any] = {}

    if cfg.modality == "audio":
        structs["embeds"] = jax.ShapeDtypeStruct((b, s, M.AUDIO_FRAME_DIM), f32)
        axes["embeds"] = ("batch", "seq", None)
        if shape.kind == "train":
            structs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            axes["labels"] = ("batch", "seq")
        return structs, axes

    s_text = s
    if cfg.modality == "vision" and shape.kind != "decode":
        p = min(cfg.frontend_tokens, max(1, s // 2))
        structs["embeds"] = jax.ShapeDtypeStruct((b, p, M.VISION_PATCH_DIM), f32)
        axes["embeds"] = ("batch", "seq", None)
        s_text = s - p
    structs["tokens"] = jax.ShapeDtypeStruct((b, s_text), i32)
    axes["tokens"] = ("batch", "seq")
    if shape.kind == "train":
        structs["labels"] = jax.ShapeDtypeStruct((b, s_text), i32)
        axes["labels"] = ("batch", "seq")
    return structs, axes


def param_struct(cfg: ArchConfig):
    """(params ShapeDtypeStructs, logical specs) without allocating."""
    params_s = jax.eval_shape(
        lambda key: M.init_params(cfg, key)[0], jax.random.PRNGKey(0)
    )
    return params_s, M.param_specs(cfg)


def opt_struct(cfg: ArchConfig, params_s, moment_dtype: str = "float32"):
    ocfg = AdamWConfig(moment_dtype=moment_dtype)
    return jax.eval_shape(lambda p: adamw_init(p, ocfg), params_s)


def cache_struct(cfg: ArchConfig, batch: int, seq_len: int):
    return jax.eval_shape(lambda: M.init_cache(cfg, batch, seq_len))
