"""Static-analysis CLI: lint the serve/train/fleet stack, gate CI on it.

Runs the four ``repro.analysis`` passes (donation/aliasing, recompile
hazards, sharding resolution, Pallas kernel geometry) over the canonical
entry points registered in ``repro.analysis.programs`` and emits a JSON
findings report.

The committed baseline (``src/repro/analysis/baseline.json``) holds the
*identities* of tolerated findings — known hazards like the raw-prompt-length
prefill (ROADMAP item 1) and the small-model attention replication. With
``--check`` the exit code is 1 iff the run produces a finding whose key is
NOT in the baseline, so CI fails on regressions only; resolved baseline
entries are reported so the baseline can be re-tightened.

Usage:
    PYTHONPATH=src python -m repro.launch.analyze                 # report
    PYTHONPATH=src python -m repro.launch.analyze --check         # CI gate
    PYTHONPATH=src python -m repro.launch.analyze --write-baseline
    PYTHONPATH=src python -m repro.launch.analyze --passes recompile,kernels
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument(
        "--passes",
        default="donation,recompile,sharding,kernels",
        help="comma-separated subset of passes to run",
    )
    ap.add_argument(
        "--min-bytes", type=int, default=1 << 14,
        help="DON001 per-leaf byte threshold",
    )
    ap.add_argument(
        "--shard-min-bytes", type=int, default=1 << 20,
        help="SHD001 replicated-leaf byte threshold",
    )
    ap.add_argument("--baseline", default=None, help="baseline file to check against")
    ap.add_argument(
        "--check", action="store_true",
        help="exit 1 on any finding not covered by the baseline",
    )
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="write the current findings' keys as the new baseline",
    )
    ap.add_argument("--out", default=None, help="write the full JSON report here")
    args = ap.parse_args(argv)

    from repro.analysis import analyze_stack, default_baseline_path, load_baseline

    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    report = analyze_stack(
        args.arch,
        min_bytes=args.min_bytes,
        shard_min_bytes=args.shard_min_bytes,
        passes=passes,
    )

    baseline_path = args.baseline or default_baseline_path()
    if args.write_baseline:
        with open(baseline_path, "w") as f:
            json.dump(report.baseline_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"baseline: wrote {len(report.keys())} keys to {baseline_path}",
              file=sys.stderr)

    text = json.dumps(report.as_dict(), indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)

    for f_ in report.sorted_findings():
        print(f"{f_.severity:5s} {f_.key}: {f_.message}", file=sys.stderr)

    if not args.check:
        return 0
    try:
        baseline = load_baseline(baseline_path)
    except FileNotFoundError:
        baseline = set()
        print(f"check: no baseline at {baseline_path} — all findings are new",
              file=sys.stderr)
    new = report.new_vs_baseline(baseline)
    resolved = report.resolved_vs_baseline(baseline)
    for key in resolved:
        print(f"check: baselined finding no longer fires: {key} "
              "(re-run --write-baseline to tighten)", file=sys.stderr)
    if new:
        print(f"check: {len(new)} NEW finding(s) vs baseline:", file=sys.stderr)
        for f_ in new:
            print(f"  {f_.severity:5s} {f_.key}: {f_.message}", file=sys.stderr)
        return 1
    print(
        f"check: OK — {len(report.findings)} finding(s), all baselined "
        f"({len(baseline)} baseline keys, {len(resolved)} resolved)",
        file=sys.stderr,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
