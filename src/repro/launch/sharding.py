"""Logical-axis sharding rules engine (t5x-style, with divisibility fallback).

Params and activations are annotated with *logical* axis names
('batch', 'embed', 'heads', 'mlp', 'vocab', 'expert', ...). A ``MeshContext``
maps each name to an ordered list of mesh-axis candidates; resolution walks
the dims of a concrete shape, assigns the first candidate whose mesh size
divides the dim (in units of e.g. head_dim so heads never split mid-head)
and that is not already used by an earlier dim, and falls back to
replication otherwise. This is what lets one rule set drive llama3-405b
(128 heads / 16-way TP) and smollm-135m (9 heads -> replicated attention,
MLP/vocab still tensor-parallel) without per-arch special cases.

The same rules compose with the fleet layer's 2-D ``("pop", "model")``
meshes (``repro.launch.mesh.make_fleet_mesh``): a ``MeshContext`` may
*reserve* axes owned by an outer engine (the fleet engine reserves
``"pop"``), and resolution silently skips candidates whose mesh axes are
reserved or absent from the mesh. Model rules therefore resolve *inside* a
pop slice — params shard over ``"model"`` within each slice — while specs
that mention neither reserved nor present axes come out replicated, i.e.
broadcast along ``"pop"``. Which axes the fleet engine owns vs. which the
model rules own is documented in ``src/repro/fleet/README.md``.
"""
from __future__ import annotations

import contextvars
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisCandidate = Union[str, tuple[str, ...]]
LogicalAxes = tuple[Optional[str], ...]


@dataclass
class MeshContext:
    mesh: Mesh
    rules: dict[str, tuple[AxisCandidate, ...]]
    units: dict[str, int] = field(default_factory=dict)
    # mesh axes owned by an outer engine (e.g. the fleet layer's "pop" axis):
    # resolution must never assign them to a logical dim, even if a rule
    # names them — the engine shards the member axis itself via shard_map
    reserved_axes: tuple[str, ...] = ()

    def axis_size(self, cand: AxisCandidate) -> int:
        names = (cand,) if isinstance(cand, str) else cand
        return int(np.prod([self.mesh.shape[a] for a in names]))


_CTX: contextvars.ContextVar[Optional[MeshContext]] = contextvars.ContextVar(
    "repro_mesh_ctx", default=None
)


def current_mesh_context() -> Optional[MeshContext]:
    return _CTX.get()


@contextmanager
def mesh_context(ctx: Optional[MeshContext]):
    token = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(token)


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------


def resolve_spec(axes: LogicalAxes, shape: Sequence[int], ctx: MeshContext) -> P:
    """Logical axes -> PartitionSpec for a concrete shape under ctx rules.

    Candidates whose mesh axes are reserved (``ctx.reserved_axes``) or not
    present in ``ctx.mesh`` are skipped, so one rule set resolves on the
    production ``("data", "model")`` meshes and inside a fleet mesh's pop
    slice (no ``"data"`` axis, ``"pop"`` reserved) alike.
    """
    used: set[str] = set(ctx.reserved_axes)
    parts: list = []
    for name, dim in zip(axes, shape):
        entry = None
        if name is not None:
            unit = ctx.units.get(name, 1)
            for cand in ctx.rules.get(name, ()):
                names = (cand,) if isinstance(cand, str) else tuple(cand)
                if any(a in used for a in names):
                    continue
                if any(a not in ctx.mesh.shape for a in names):
                    continue
                size = ctx.axis_size(cand)
                if dim % unit == 0 and (dim // unit) % size == 0 and size > 1:
                    # singleton axis tuples must collapse to bare names:
                    # PartitionSpec(('data',), 'model') != PartitionSpec('data', 'model')
                    entry = names[0] if len(names) == 1 else tuple(names)
                    used.update(names)
                    break
        parts.append(entry)
    # trim trailing Nones for tidier specs
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def named_sharding(axes: LogicalAxes, shape: Sequence[int], ctx: Optional[MeshContext] = None):
    ctx = ctx or current_mesh_context()
    assert ctx is not None
    return NamedSharding(ctx.mesh, resolve_spec(axes, shape, ctx))


def shard_activation(x: jax.Array, axes: LogicalAxes) -> jax.Array:
    """with_sharding_constraint when a mesh context is active; no-op else."""
    ctx = current_mesh_context()
    if ctx is None:
        return x
    spec = resolve_spec(axes, x.shape, ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def tree_shardings(spec_tree, value_tree, ctx: Optional[MeshContext] = None):
    """Map a pytree of logical-axes tuples + matching values -> NamedShardings."""
    ctx = ctx or current_mesh_context()
    assert ctx is not None
    return jax.tree_util.tree_map(
        lambda axes, v: NamedSharding(ctx.mesh, resolve_spec(axes, v.shape, ctx)),
        spec_tree,
        value_tree,
        is_leaf=lambda a: isinstance(a, tuple) and all(x is None or isinstance(x, str) for x in a),
    )


# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------


def make_rules(cfg, *, multi_pod: bool = False, fsdp: Optional[bool] = None) -> MeshContext:
    """Build the MeshContext for an arch on the production mesh.

    fsdp=None auto-enables ZeRO-3-style param sharding over the data(+pod)
    axes for models > 3B params (weights+optimizer would not fit replicated).
    """
    from repro.launch.mesh import make_production_mesh  # local import (device init)

    mesh = make_production_mesh(multi_pod=multi_pod)
    return make_rules_for_mesh(cfg, mesh, fsdp=fsdp)


def make_rules_for_mesh(
    cfg, mesh: Mesh, *, fsdp: Optional[bool] = None, seq_shard: bool = False,
    seq_rule: bool = False, moe_slot_shard: bool = False,
    reserved_axes: tuple[str, ...] = (),
) -> MeshContext:
    """Build the arch's MeshContext on an arbitrary mesh.

    ``reserved_axes`` marks mesh axes owned by an outer engine so resolution
    never assigns them: the fleet layer passes ``("pop",)`` with its 2-D
    ``("pop", "model")`` mesh, making the model rules resolve per pop slice
    (replicated specs broadcast along "pop"; "model" rules shard within the
    slice). Rules that name axes absent from ``mesh`` (e.g. "data" on a
    fleet mesh) are skipped at resolution time.
    """
    if fsdp is None:
        fsdp = cfg.param_count() > 3e9
    has_pod = "pod" in mesh.shape
    batch_axes: tuple[AxisCandidate, ...] = ((("pod", "data"),) if has_pod else (("data",),))
    # FSDP shards params over the batch axes (pod+data), composing with TP
    fsdp_axes: tuple[AxisCandidate, ...] = batch_axes if fsdp else ()

    hd = max(1, cfg.resolved_head_dim)
    rules: dict[str, tuple[AxisCandidate, ...]] = {
        # activations
        "batch": batch_axes + (("data",),) if has_pod else batch_axes,
        # seq_rule: let attention activations shard their seq axis on
        # 'model' when the heads axis cannot (indivisible head counts)
        "seq": ("model",) if seq_rule else (),
        # Megatron-SP: the between-layer carry shards on seq for huge models
        # (attention/MLP entry all-gathers, exits reduce-scatter back)
        "seq_carry": ("model",) if seq_shard else (),
        "heads": ("model",),  # activation head-count axis
        "kv_heads": ("model",),
        "kv_seq": ("model",),  # decode KV cache: heads first, seq fallback
        # params
        "embed": fsdp_axes,
        "qkv": ("model",),  # flattened heads*head_dim weight axis
        "kv": ("model",),
        # moe_slot_shard: split expert-slot rows over 'model' and gather the
        # expert weights instead (kills the giant TP partial-sum all-reduce
        # when the expert count cannot use expert parallelism)
        "moe_slots": ("model",) if moe_slot_shard else (),
        "mlp": () if moe_slot_shard else ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "inner": ("model",),  # SSM d_inner
        "dt_rank": (),
        "state": (),
        "conv": (),
        "frame": (),
        "layers": (),
    }
    units = {"qkv": hd, "kv": hd}
    return MeshContext(
        mesh=mesh, rules=rules, units=units, reserved_axes=tuple(reserved_axes)
    )
