"""Observability CLI: self-check, log conversion, and log summaries.

Works entirely on the pure-python :mod:`repro.obs` layer — no JAX import,
no model, no devices — so the analysis CI job can gate on ``--check`` in
milliseconds:

* ``--check`` — exercise the recorder end to end in-process (spans /
  instants / samples / metrics, ring wraparound, JSONL round-trip, Chrome
  export + schema validation) and exit 0 iff everything holds. This is the
  canary that the exporters CI later feeds real serve traces through are
  self-consistent.
* ``--convert IN.jsonl --trace-out OUT.json`` — re-export a saved JSONL
  event log (``--metrics-out`` from the serve CLIs / benches) as a Chrome
  trace viewable in https://ui.perfetto.dev.
* ``--summary IN.jsonl`` — print a log's meta line, event-kind counts and
  metric aggregates as JSON.

Usage:
    PYTHONPATH=src python -m repro.launch.obs --check
    PYTHONPATH=src python -m repro.launch.obs --convert run.jsonl --trace-out run.trace.json
    PYTHONPATH=src python -m repro.launch.obs --summary run.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


def _self_check() -> list[str]:
    """Run the in-process smoke; returns problems (empty == healthy)."""
    from repro.obs import (
        NULL_RECORDER,
        Recorder,
        RingBuffer,
        chrome_trace,
        read_jsonl,
        validate_chrome_trace,
        write_jsonl,
    )
    from repro.obs.metrics import TTFT_BUCKETS_S

    problems: list[str] = []

    # ring wraparound: bounded, oldest-first, dropped accounted
    rb = RingBuffer(4)
    for i in range(10):
        rb.append(i)
    if list(rb) != [6, 7, 8, 9] or rb.dropped != 6:
        problems.append(f"ring wraparound broken: {list(rb)} dropped={rb.dropped}")

    # null recorder: falsy, un-enableable
    if NULL_RECORDER:
        problems.append("NULL_RECORDER is truthy")
    try:
        NULL_RECORDER.enabled = True
        problems.append("NULL_RECORDER accepted enable")
    except AttributeError:
        pass

    # record one of everything, export both ways, validate, round-trip
    rec = Recorder(capacity=64)
    t0 = rec.now()
    rec.span("admit", proc="serve", track="slot0", t0=t0, t1=t0 + 0.01,
             args=dict(rid=0))
    rec.span("decode", proc="serve", track="slot0", t0=t0 + 0.01, t1=t0 + 0.05,
             args=dict(rid=0, tokens=4))
    rec.instant("retire", proc="serve", track="slot0", args=dict(rid=0))
    rec.sample("kv.free_pages", 7, proc="serve", track="pages")
    rec.count("serve.tokens_emitted", 4)
    rec.observe("serve.ttft_wall_s", 0.012, TTFT_BUCKETS_S)
    rec.gauge_set("serve.compiles.total", 2)

    trace = chrome_trace(rec)
    problems += validate_chrome_trace(trace)

    h = rec.summary()["metrics"].get("serve.ttft_wall_s")
    if not h or h["count"] != 1 or not (0.01 <= h["p50"] <= 0.025):
        problems.append(f"histogram aggregate wrong: {h}")

    fd, path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        write_jsonl(path, rec)
        back = read_jsonl(path)
        if len(back["events"]) != len(rec.event_list()):
            problems.append(
                f"jsonl round-trip lost events: {len(back['events'])} "
                f"!= {len(rec.event_list())}"
            )
        if back["events"] != rec.event_list():
            problems.append("jsonl round-trip changed event content")
        round_trip = chrome_trace(back["events"])
        problems += [f"re-exported: {p}" for p in validate_chrome_trace(round_trip)]
    finally:
        os.unlink(path)
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="run the recorder/exporter self-check; exit 1 on failure")
    ap.add_argument("--convert", metavar="IN.jsonl", default=None,
                    help="JSONL event log to convert (needs --trace-out)")
    ap.add_argument("--trace-out", metavar="OUT.json", default=None,
                    help="Chrome trace output path for --convert")
    ap.add_argument("--summary", metavar="IN.jsonl", default=None,
                    help="print a JSONL log's meta + aggregates as JSON")
    args = ap.parse_args(argv)

    if not (args.check or args.convert or args.summary):
        ap.error("nothing to do: pass --check, --convert or --summary")

    rc = 0
    if args.check:
        problems = _self_check()
        if problems:
            for p in problems:
                print(f"FAIL: {p}", file=sys.stderr)
            rc = 1
        else:
            print("obs self-check OK")

    if args.convert:
        if not args.trace_out:
            ap.error("--convert needs --trace-out")
        from repro.obs import jsonl_to_chrome, validate_chrome_trace

        trace = jsonl_to_chrome(args.convert, args.trace_out)
        problems = validate_chrome_trace(trace)
        if problems:
            for p in problems:
                print(f"FAIL: {p}", file=sys.stderr)
            rc = 1
        else:
            print(f"wrote {args.trace_out} ({len(trace['traceEvents'])} events)")

    if args.summary:
        from repro.obs import read_jsonl

        log = read_jsonl(args.summary)
        kinds: dict[str, int] = {}
        for ev in log["events"]:
            kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
        print(json.dumps(dict(
            meta=log["meta"],
            events=len(log["events"]),
            event_kinds=kinds,
            metrics={m["name"]: m for m in log["metrics"]},
        ), indent=2, default=str))

    return rc


if __name__ == "__main__":
    sys.exit(main())
