"""Observability CLI: self-check, log conversion, and log summaries.

Works entirely on the pure-python :mod:`repro.obs` layer — no JAX import,
no model, no devices — so the analysis CI job can gate on ``--check`` in
milliseconds:

* ``--check`` — exercise the recorder end to end in-process (spans /
  instants / samples / metrics, ring wraparound, JSONL round-trip, Chrome
  export + schema validation) PLUS the fault-detection stack (ABFT prober
  against a numpy silicon model, health state-machine debounce, alert
  fire/resolve) and exit 0 iff everything holds. This is the canary that
  the exporters CI later feeds real serve traces through are
  self-consistent.
* ``--convert IN.jsonl --trace-out OUT.json`` — re-export a saved JSONL
  event log (``--metrics-out`` from the serve CLIs / benches) as a Chrome
  trace viewable in https://ui.perfetto.dev.
* ``--summary IN.jsonl`` — print a log's meta line, event-kind counts,
  metric aggregates, dropped-event accounting and any alert fire/resolve
  instants as JSON (a dropped-ring log warns on stderr). Combined with
  ``--check``, exits 1 if the log holds alerts that fired.

Usage:
    PYTHONPATH=src python -m repro.launch.obs --check
    PYTHONPATH=src python -m repro.launch.obs --convert run.jsonl --trace-out run.trace.json
    PYTHONPATH=src python -m repro.launch.obs --summary run.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


def _self_check() -> list[str]:
    """Run the in-process smoke; returns problems (empty == healthy)."""
    from repro.obs import (
        NULL_RECORDER,
        Recorder,
        RingBuffer,
        chrome_trace,
        read_jsonl,
        validate_chrome_trace,
        write_jsonl,
    )
    from repro.obs.metrics import TTFT_BUCKETS_S

    problems: list[str] = []

    # ring wraparound: bounded, oldest-first, dropped accounted
    rb = RingBuffer(4)
    for i in range(10):
        rb.append(i)
    if list(rb) != [6, 7, 8, 9] or rb.dropped != 6:
        problems.append(f"ring wraparound broken: {list(rb)} dropped={rb.dropped}")

    # null recorder: falsy, un-enableable
    if NULL_RECORDER:
        problems.append("NULL_RECORDER is truthy")
    try:
        NULL_RECORDER.enabled = True
        problems.append("NULL_RECORDER accepted enable")
    except AttributeError:
        pass

    # record one of everything, export both ways, validate, round-trip
    rec = Recorder(capacity=64)
    t0 = rec.now()
    rec.span("admit", proc="serve", track="slot0", t0=t0, t1=t0 + 0.01,
             args=dict(rid=0))
    rec.span("decode", proc="serve", track="slot0", t0=t0 + 0.01, t1=t0 + 0.05,
             args=dict(rid=0, tokens=4))
    rec.instant("retire", proc="serve", track="slot0", args=dict(rid=0))
    rec.sample("kv.free_pages", 7, proc="serve", track="pages")
    rec.count("serve.tokens_emitted", 4)
    rec.observe("serve.ttft_wall_s", 0.012, TTFT_BUCKETS_S)
    rec.gauge_set("serve.compiles.total", 2)

    trace = chrome_trace(rec)
    problems += validate_chrome_trace(trace)

    h = rec.summary()["metrics"].get("serve.ttft_wall_s")
    if not h or h["count"] != 1 or not (0.01 <= h["p50"] <= 0.025):
        problems.append(f"histogram aggregate wrong: {h}")

    fd, path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        write_jsonl(path, rec)
        back = read_jsonl(path)
        if len(back["events"]) != len(rec.event_list()):
            problems.append(
                f"jsonl round-trip lost events: {len(back['events'])} "
                f"!= {len(rec.event_list())}"
            )
        if back["events"] != rec.event_list():
            problems.append("jsonl round-trip changed event content")
        round_trip = chrome_trace(back["events"])
        problems += [f"re-exported: {p}" for p in validate_chrome_trace(round_trip)]
    finally:
        os.unlink(path)
    return problems + _detection_check()


def _detection_check() -> list[str]:
    """JAX-free smoke of the fault-detection stack: the ABFT prober against
    a numpy silicon model, the health state machine's debounce, and alert
    fire/resolve."""
    import numpy as np

    from repro.obs import (
        HEALTHY,
        SUSPECT,
        AlertEngine,
        AlertRule,
        ChipHealth,
        ChipProber,
        HealthConfig,
        Recorder,
    )
    from repro.obs.abft import periodic_mask_np
    from repro.obs.health import DriftDetector, Ewma

    problems: list[str] = []

    # -- ABFT prober over a numpy silicon model ---------------------------
    rng = np.random.default_rng(0)
    R, C, K, N = 4, 4, 24, 20
    W = rng.standard_normal((K, N)).astype(np.float32)
    ok = np.ones((R, C), bool)

    def dispatch(x):
        m = periodic_mask_np(W.shape, ok)
        y = (np.asarray(x, np.float64) @ (W * m)).astype(np.float32)
        chk = (np.asarray(x, np.float64).sum(axis=0) @ (W * m)).astype(np.float32)
        return y, chk

    prober = ChipProber(dispatch, array_shape=(R, C), k_dim=K)
    res = prober.probe(clock=0)
    if res.detected or res.canary_mismatches or res.dispatches != 1:
        problems.append(f"healthy probe not clean: {res.as_dict()}")
    ok[2, 1] = False  # silicon degrades under the prober
    res = prober.probe(clock=1)
    if not res.detected:
        problems.append("prober missed an injected fault")
    elif res.delta is None or not res.delta[2, 1] or int(res.delta.sum()) != 1:
        problems.append(f"prober mislocalized the fault: {res.as_dict()}")
    prober.rebase()  # accept the new silicon as the believed map
    res = prober.probe(clock=2)
    if res.detected:
        problems.append("probe after rebase still detects")

    # -- EWMA / drift primitives ------------------------------------------
    e = Ewma(alpha=0.5)
    e.update(1.0)
    e.update(0.0)
    if not (0.4 < e.value < 0.6):
        problems.append(f"ewma update wrong: {e.value}")
    d = DriftDetector(warmup=3)
    zs = [d.update(1.0) for _ in range(8)]
    if any(zs):
        problems.append(f"drift z nonzero on a constant series: {zs}")

    # -- health state machine debounce ------------------------------------
    cfg = HealthConfig(suspect_after=2, recover_after=2)
    bad = type(res)(canary_mismatches=3, syndrome_cols=np.ones(C), detected=True,
                    dispatches=2)
    clean = type(res)(canary_mismatches=0, syndrome_cols=np.zeros(C),
                      detected=False, dispatches=1)
    h = ChipHealth(0, cfg)
    h.observe_probe(bad, clock=0)
    if h.state != HEALTHY:
        problems.append("single bad probe transitioned before debounce")
    h.observe_probe(bad, clock=1)
    if h.state != SUSPECT or h.detected_at != 1:
        problems.append(f"debounced suspect transition broken: {h.summary()}")
    h.observe_probe(clean, clock=2)
    h.observe_probe(clean, clock=3)
    if h.state != HEALTHY:
        problems.append(f"recovery after clean streak broken: {h.summary()}")

    # -- alert engine fire / debounce / resolve ---------------------------
    rec = Recorder(capacity=32)
    eng = AlertEngine(rec, [AlertRule("hot", "temp", ">", 10.0, for_ticks=2)])
    rec.gauge_set("temp", 50.0)
    if eng.evaluate(clock=0) != []:
        problems.append("alert fired before for_ticks debounce")
    if eng.evaluate(clock=1) != ["hot"]:
        problems.append("alert failed to fire after debounce")
    rec.gauge_set("temp", 1.0)
    eng.evaluate(clock=2)
    if eng.firing() or eng.fired_total != 1:
        problems.append(f"alert resolve broken: {eng.summary()}")
    alert_events = [e for e in rec.event_list() if e.name == "alert"]
    states = [e.args["state"] for e in alert_events]
    if states != ["firing", "resolved"]:
        problems.append(f"alert instants wrong: {states}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="run the recorder/exporter self-check; exit 1 on failure")
    ap.add_argument("--convert", metavar="IN.jsonl", default=None,
                    help="JSONL event log to convert (needs --trace-out)")
    ap.add_argument("--trace-out", metavar="OUT.json", default=None,
                    help="Chrome trace output path for --convert")
    ap.add_argument("--summary", metavar="IN.jsonl", default=None,
                    help="print a JSONL log's meta + aggregates as JSON")
    args = ap.parse_args(argv)

    if not (args.check or args.convert or args.summary):
        ap.error("nothing to do: pass --check, --convert or --summary")

    rc = 0
    if args.check:
        problems = _self_check()
        if problems:
            for p in problems:
                print(f"FAIL: {p}", file=sys.stderr)
            rc = 1
        else:
            print("obs self-check OK")

    if args.convert:
        if not args.trace_out:
            ap.error("--convert needs --trace-out")
        from repro.obs import jsonl_to_chrome, validate_chrome_trace

        trace = jsonl_to_chrome(args.convert, args.trace_out)
        problems = validate_chrome_trace(trace)
        if problems:
            for p in problems:
                print(f"FAIL: {p}", file=sys.stderr)
            rc = 1
        else:
            print(f"wrote {args.trace_out} ({len(trace['traceEvents'])} events)")

    if args.summary:
        from repro.obs import read_jsonl

        log = read_jsonl(args.summary)
        kinds: dict[str, int] = {}
        for ev in log["events"]:
            kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
        alert_events = [
            dict(ts=ev.ts, **(ev.args or {}))
            for ev in log["events"]
            if ev.kind == "instant" and ev.name == "alert"
        ]
        fired = sorted({a.get("name") for a in alert_events
                        if a.get("state") == "firing"})
        detections = [
            dict(ts=ev.ts, **(ev.args or {}))
            for ev in log["events"]
            if ev.kind == "instant" and ev.name == "fault.detected"
        ]
        out = dict(
            meta=log["meta"],
            events=len(log["events"]),
            events_dropped=log["dropped"],
            event_kinds=kinds,
            alerts=dict(fired=fired, events=alert_events),
            fault_detections=detections,
            metrics={m["name"]: m for m in log["metrics"]},
        )
        if log["dropped"]:
            out["warnings"] = [
                f"ring overwrote {log['dropped']} event(s); the oldest "
                "events are missing from this log"
            ]
            print(f"WARNING: {out['warnings'][0]}", file=sys.stderr)
        print(json.dumps(out, indent=2, default=str))
        if args.check and fired:
            print(f"FAIL: log holds fired alerts: {fired}", file=sys.stderr)
            rc = 1

    return rc


if __name__ == "__main__":
    sys.exit(main())
