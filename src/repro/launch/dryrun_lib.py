"""Dry-run core: lower + compile every (arch x shape x mesh) cell and
extract memory / cost / collective statistics. Import-safe for tests (the
512-device XLA flag is set by dryrun.py, the CLI)."""
from __future__ import annotations

import json
import os
import time
import traceback
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cell_skip_reason, get_arch
from repro.core.masking import FaultContext
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.policy import launch_policy
from repro.launch.sharding import (
    MeshContext,
    make_rules_for_mesh,
    mesh_context,
    resolve_spec,
    tree_shardings,
)
from repro.launch.specs import cache_struct, input_specs, opt_struct, param_struct
from repro.models import model as M
from repro.train.optimizer import AdamWConfig, opt_state_specs
from repro.train.step import make_train_step

_SPEC_LEAF = lambda a: isinstance(a, tuple) and all(
    x is None or isinstance(x, str) for x in a
)


def sharded_bytes(specs, structs, mctx: MeshContext) -> float:
    """Analytic per-device bytes of a pytree under the resolved shardings."""
    total = 0.0
    flat_s = jax.tree_util.tree_leaves(specs, is_leaf=_SPEC_LEAF)
    flat_v = jax.tree_util.tree_leaves(structs)
    for ax, v in zip(flat_s, flat_v):
        spec = resolve_spec(ax, v.shape, mctx)
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            shards *= mctx.axis_size(entry)
        total += v.size * v.dtype.itemsize / shards
    return total


def _ctx_struct(cfg, mode: str):
    if mode == "none":
        return FaultContext(ok=None, mode="none"), FaultContext(ok=None, mode="none")
    struct = FaultContext(
        ok=jax.ShapeDtypeStruct((cfg.array_rows, cfg.array_cols), np.float32),
        mode=mode,
    )
    return struct, None  # sharding filled by caller (needs mesh)


def build_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    fault_mode: str = "fap",
    moe_impl: str = "einsum",
    profile: str = "baseline",
    mesh=None,
    overrides: Optional[dict] = None,
):
    """Returns (lowered, info) for one cell. ``mesh=None`` -> production mesh."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    skip = cell_skip_reason(cfg, shape)
    if skip:
        raise ValueError(f"cell skipped: {skip}")
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_pod = mesh.shape.get("pod", 1)
    n_data = mesh.shape["data"]
    pol = launch_policy(
        cfg, shape, n_data=n_data, n_pod=n_pod, moe_impl=moe_impl, profile=profile
    )
    if overrides:
        from dataclasses import replace

        pol = replace(pol, **overrides)
    mctx = make_rules_for_mesh(
        cfg, mesh, fsdp=pol.fsdp, seq_shard=pol.seq_shard, seq_rule=pol.seq_rule,
        moe_slot_shard=pol.moe_slot_shard,
    )

    ctx_s, _ = _ctx_struct(cfg, fault_mode)
    ctx_sh = (
        FaultContext(ok=None, mode="none")
        if fault_mode == "none"
        else FaultContext(ok=NamedSharding(mesh, P()), mode=fault_mode)
    )

    with mesh, mesh_context(mctx):
        params_s, specs = param_struct(cfg)
        param_sh = tree_shardings(specs, params_s, mctx)
        batch_s, batch_axes = input_specs(cfg, shape)
        batch_sh = tree_shardings(batch_axes, batch_s, mctx)

        info: dict[str, Any] = dict(
            arch=arch,
            shape=shape_name,
            kind=shape.kind,
            mesh=dict(mesh.shape),
            policy=pol.describe(),
            fault_mode=fault_mode,
            param_bytes_per_device=sharded_bytes(specs, params_s, mctx),
            params_total=cfg.param_count(),
        )

        if shape.kind == "train":
            ocfg = AdamWConfig(moment_dtype=pol.moment_dtype, learning_rate=1e-4)
            step = make_train_step(
                cfg, ocfg,
                attn_impl=pol.attn_impl, moe_impl=pol.moe_impl,
                remat=pol.remat, microbatches=pol.microbatches,
                fault_apply=pol.fault_apply,
            )
            opt_s = opt_struct(cfg, params_s, pol.moment_dtype)
            opt_sh = tree_shardings(opt_state_specs(specs), opt_s, mctx)
            info["opt_bytes_per_device"] = sharded_bytes(
                opt_state_specs(specs), opt_s, mctx
            )
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh, ctx_sh),
                out_shardings=(param_sh, opt_sh, None),
            ).lower(params_s, opt_s, batch_s, ctx_s)
        elif shape.kind == "prefill":
            def prefill_fn(params, batch, ctx):
                return M.prefill(
                    params, batch, cfg, ctx,
                    attn_impl=pol.attn_impl, moe_impl=pol.moe_impl,
                )

            cache_s = cache_struct(cfg, shape.global_batch, shape.seq_len)
            cache_sh = tree_shardings(M.cache_specs(cfg), cache_s, mctx)
            info["cache_bytes_per_device"] = sharded_bytes(
                M.cache_specs(cfg), cache_s, mctx
            )
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(param_sh, batch_sh, ctx_sh),
                out_shardings=(None, cache_sh),
            ).lower(params_s, batch_s, ctx_s)
        else:  # decode
            def decode_fn(params, tokens, cache, ctx):
                return M.decode_step(params, tokens, cache, cfg, ctx, moe_impl=pol.moe_impl)

            cache_s = cache_struct(cfg, shape.global_batch, shape.seq_len)
            cache_sh = tree_shardings(M.cache_specs(cfg), cache_s, mctx)
            info["cache_bytes_per_device"] = sharded_bytes(
                M.cache_specs(cfg), cache_s, mctx
            )
            tok_s = jax.ShapeDtypeStruct((shape.global_batch, 1), np.int32)
            tok_sh = NamedSharding(mesh, resolve_spec(("batch", None), tok_s.shape, mctx))
            lowered = jax.jit(
                decode_fn,
                in_shardings=(param_sh, tok_sh, cache_sh, ctx_sh),
                out_shardings=(None, cache_sh),
            ).lower(params_s, tok_s, cache_s, ctx_s)
    return lowered, info


def compile_and_analyze(lowered, info: dict, n_devices: int, hlo_path=None) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    info["compile_seconds"] = time.time() - t0

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        info["cost_analysis"] = {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds", "utilization operand 0 {}", )
            or k in ("flops", "bytes accessed")
        }
    except Exception as e:  # pragma: no cover
        info["cost_analysis"] = {"error": str(e)}

    try:
        mem = compiled.memory_analysis()
        fields = (
            "temp_size_in_bytes",
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        )
        info["memory_analysis"] = {
            f: int(getattr(mem, f)) for f in fields if hasattr(mem, f)
        }
        if not info["memory_analysis"]:
            info["memory_analysis"] = {"repr": str(mem)}
    except Exception as e:  # pragma: no cover
        info["memory_analysis"] = {"error": str(e)}

    try:
        hlo = compiled.as_text()
        info["hlo_bytes"] = len(hlo)
        cost = analyze_hlo(hlo, n_devices_default=n_devices)
        d = cost.as_dict()
        info["hlo_cost"] = d  # loop-aware flops/bytes/collectives (per device)
        info["collectives"] = dict(
            total_bytes=d["collective_bytes"],
            bytes_by_kind=d["coll_by_kind"],
            count_by_kind=d["coll_count"],
        )
        if hlo_path:
            import gzip

            with gzip.open(hlo_path, "wt") as f:
                f.write(hlo)
    except Exception as e:  # pragma: no cover
        info["collectives"] = {"error": str(e)}
    return info


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    fault_mode: str = "fap",
    moe_impl: str = "einsum",
    profile: str = "baseline",
    out_dir: Optional[str] = None,
    overrides: Optional[dict] = None,
) -> dict:
    t0 = time.time()
    try:
        lowered, info = build_cell(
            arch, shape_name,
            multi_pod=multi_pod, fault_mode=fault_mode, moe_impl=moe_impl,
            profile=profile, overrides=overrides,
        )
        info["lower_seconds"] = time.time() - t0
        n = 512 if multi_pod else 256
        hlo_path = None
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            tag = "pod2" if multi_pod else "pod1"
            hlo_path = os.path.join(out_dir, f"{arch}__{shape_name}__{tag}.hlo.gz")
        info = compile_and_analyze(lowered, info, n, hlo_path=hlo_path)
        info["status"] = "ok"
    except Exception as e:
        info = dict(
            arch=arch, shape=shape_name, status="error",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-2000:],
            multi_pod=multi_pod,
        )
    info["multi_pod"] = multi_pod
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = "pod2" if multi_pod else "pod1"
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{tag}.json")
        with open(path, "w") as f:
            json.dump(info, f, indent=1, default=str)
    return info
