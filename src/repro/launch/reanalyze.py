"""Re-run the HLO cost walk over stored dry-run artifacts (no recompile).

    PYTHONPATH=src python -m repro.launch.reanalyze --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.launch.hlo_cost import analyze_hlo


def reanalyze_dir(d: str) -> int:
    n = 0
    for jpath in sorted(glob.glob(os.path.join(d, "*.json"))):
        hpath = jpath.replace(".json", ".hlo.gz")
        if not os.path.exists(hpath):
            continue
        info = json.load(open(jpath))
        if info.get("status") != "ok":
            continue
        devices = 512 if info.get("multi_pod") else 256
        with gzip.open(hpath, "rt") as f:
            hlo = f.read()
        cost = analyze_hlo(hlo, n_devices_default=devices).as_dict()
        info["hlo_cost"] = cost
        info["collectives"] = dict(
            total_bytes=cost["collective_bytes"],
            bytes_by_kind=cost["coll_by_kind"],
            count_by_kind=cost["coll_count"],
        )
        with open(jpath, "w") as f:
            json.dump(info, f, indent=1, default=str)
        n += 1
    return n


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    print(f"reanalyzed {reanalyze_dir(args.dir)} cells")
