"""Per-(arch x shape) launch policy: the knobs that make each cell fit and
run well on the production mesh. Derived from analytic memory estimates —
see EXPERIMENTS.md SDry-run for the audit of each choice.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class LaunchPolicy:
    fsdp: bool
    moment_dtype: str
    microbatches: int
    seq_shard: bool  # Megatron-SP style: shard the between-layer carry on seq
    attn_impl: str
    moe_impl: str
    remat: str
    # 'per_use' = paper-faithful mask at every matmul; 'per_step' = exact
    # pre-masking optimization (EXPERIMENTS.md SPerf)
    fault_apply: str = "per_use"
    # allow attention seq axes to shard on 'model' (for archs whose head
    # count does not divide the TP degree)
    seq_rule: bool = False
    # shard MoE slot rows over 'model' instead of TP-splitting expert FFNs
    moe_slot_shard: bool = False

    def describe(self) -> str:
        return (
            f"fsdp={self.fsdp} moments={self.moment_dtype} mb={self.microbatches} "
            f"seq_shard={self.seq_shard} attn={self.attn_impl} moe={self.moe_impl} "
            f"remat={self.remat} fault_apply={self.fault_apply}"
        )


def launch_policy(
    cfg: ArchConfig,
    shape: ShapeConfig,
    *,
    n_data: int = 16,
    n_pod: int = 1,
    n_model: int = 16,
    carry_budget_bytes: float = 2.5e9,
    moe_impl: str = "einsum",
    profile: str = "baseline",
) -> LaunchPolicy:
    """profile='baseline' is the paper-faithful configuration; 'optimized'
    applies the beyond-paper wins validated in EXPERIMENTS.md SPerf:
    per-step fault masking, causal-unrolled mixed-precision attention,
    scatter MoE dispatch, and seq-sharded attention for archs whose head
    count cannot use tensor parallelism."""
    params = cfg.param_count()
    fsdp_train = params > 3e9
    fsdp_serve = params * 2 > 8e9  # bf16 weights won't fit replicated-ish
    opt = profile == "optimized"
    fault_apply = "per_step" if opt else "per_use"
    moe = ("scatter" if opt else "einsum") if moe_impl == "einsum" else moe_impl
    # the unroll/mixed/seq-shard attention wins only apply to full causal
    # attention; SWA's dynamic kv slices and encoder bidirectional attention
    # regress with them (EXPERIMENTS.md SPerf: hymba +5x, hubert +13%)
    causal_full = (
        cfg.has_attention and not cfg.is_encoder and cfg.sliding_window is None
    )
    seq_rule = bool(
        opt and causal_full and cfg.num_heads and cfg.num_heads % n_model
    )
    if shape.kind == "train":
        local_batch = max(1, shape.global_batch // (n_data * n_pod))
        # choose microbatches so the saved scan carry fits the budget:
        # carry bytes = (local/mb) * S * d * 2 * L   (/16 more if seq_shard)
        seq_shard = params >= 50e9
        denom = 16 if seq_shard else 1
        mb = 1
        while (
            mb < local_batch
            and (local_batch / mb) * shape.seq_len * cfg.d_model * 2 * cfg.num_layers / denom
            > carry_budget_bytes
        ):
            mb *= 2
        attn = "blockwise" if shape.seq_len > 512 else "dense"
        if opt and attn == "blockwise" and causal_full:
            attn = "blockwise_mx_unroll"
        return LaunchPolicy(
            fsdp=fsdp_train,
            moment_dtype="bfloat16" if params > 50e9 else "float32",
            microbatches=mb,
            seq_shard=seq_shard,
            attn_impl=attn,
            moe_impl=moe,
            remat="full",
            fault_apply=fault_apply,
            seq_rule=seq_rule,
        )
    if shape.kind == "prefill":
        return LaunchPolicy(
            fsdp=fsdp_serve,
            moment_dtype="float32",
            microbatches=1,
            seq_shard=params >= 50e9,
            attn_impl="blockwise_mx_unroll" if (opt and causal_full) else "blockwise",
            moe_impl=moe,
            remat="none",
            fault_apply=fault_apply,
            seq_rule=seq_rule,
        )
    # decode: per_step masking is moot (weights static per request);
    # production serving masks offline (fault_mode none + pre-masked params)
    return LaunchPolicy(
        fsdp=fsdp_serve,
        moment_dtype="float32",
        microbatches=1,
        seq_shard=False,
        attn_impl="dense",
        moe_impl=moe,
        remat="none",
        fault_apply="per_use",
        seq_rule=False,
    )
