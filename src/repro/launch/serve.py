"""Serving launcher CLI: batched generation with an optional fault map.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --fault-rate 0.1 --batch 4 --new-tokens 16

``--continuous`` serves the same prompts as a request stream through the
continuous-batching engine (paged KV cache, per-request budgets skewed
around --new-tokens) instead of one static batch. With ``--trace-out`` /
``--metrics-out`` the continuous run records its request lifecycle
(repro.obs) and writes a Chrome trace / JSONL event+metrics log; convert
or summarize saved logs with ``python -m repro.launch.obs``.
``--probe-every N`` turns on the online fault-detection stack (ABFT
checksum/canary probes + health scoring + SLO alerts) and
``--health-out`` saves its summary JSON.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--fault-rate", type=float, default=0.0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching (paged KV, skewed budgets)")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--buckets", type=int, nargs="+", default=None, metavar="W",
                    help="prefill bucket ladder (default 32 64 128 256); "
                         "pass 0 to disable bucketing (exact-length prefill)")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="chunked-prefill width for prompts past the top "
                         "bucket (default: the top bucket)")
    ap.add_argument("--max-pack", type=int, default=4,
                    help="max short prompts packed into one bucket dispatch")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-precompile every (bucket, chunk, decode) "
                         "program before serving (continuous engine only)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write the run's Chrome trace (continuous only)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the run's JSONL event+metrics log "
                         "(continuous only)")
    ap.add_argument("--probe-every", type=int, default=None, metavar="N",
                    help="dispatch an ABFT checksum/canary probe every N "
                         "decode dispatches and score chip health "
                         "(continuous only)")
    ap.add_argument("--health-out", default=None, metavar="FILE",
                    help="write the health + alert summary JSON "
                         "(needs --probe-every)")
    args = ap.parse_args()
    if args.health_out and not args.probe_every:
        ap.error("--health-out needs --probe-every")

    import jax

    from repro.configs import get_arch, reduce_config
    from repro.core import from_fault_map, healthy, random_fault_map
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode path")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    ctx = healthy()
    if args.fault_rate > 0:
        fm = random_fault_map(0, cfg.array_rows, cfg.array_cols, args.fault_rate)
        ctx = from_fault_map(fm)
        print(f"fault map rate={fm.fault_rate:.3f}")

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    if args.continuous:
        import numpy as np

        from repro.serve import ContinuousBatchingEngine, Request

        budgets = [
            max(1, args.new_tokens // (4 if i % 2 else 1)) for i in range(args.batch)
        ]
        reqs = [
            Request(i, np.asarray(prompts[i]), max_new_tokens=budgets[i], arrival=i % 3)
            for i in range(args.batch)
        ]
        from repro.serve.bucketing import DEFAULT_PREFILL_BUCKETS

        buckets = (
            None
            if args.buckets == [0]
            else tuple(args.buckets) if args.buckets else DEFAULT_PREFILL_BUCKETS
        )
        rec = None
        if args.trace_out or args.metrics_out or args.health_out:
            from repro.obs import Recorder

            rec = Recorder()
        alert_rules = None
        if args.probe_every:
            from repro.obs import default_slo_rules

            alert_rules = default_slo_rules()
        eng = ContinuousBatchingEngine(
            cfg, params, ctx, num_slots=args.slots, prefill_buckets=buckets,
            chunk_size=args.chunk_size, max_pack=args.max_pack, recorder=rec,
            probe_every=args.probe_every, alert_rules=alert_rules,
        )
        if args.warmup:
            t0 = time.time()
            n = eng.warmup()
            print(f"warmup: {n} AOT programs in {time.time() - t0:.2f}s")
        t0 = time.time()
        outs, stats = eng.serve(reqs, temperature=args.temperature)
        dt = time.time() - t0
        cc = eng.compile_counts()
        print(
            f"{stats.emitted_tokens} tokens over {args.batch} requests in "
            f"{stats.decode_dispatches} dispatches / {dt:.2f}s "
            f"({stats.emitted_tokens/dt:.1f} tok/s, "
            f"slot util {stats.slot_utilization:.0%}, "
            f"peak KV {stats.peak_resident_kv_bytes} B, "
            f"compiles aot={cc['aot']} jit={cc['jit_fallback']})"
        )
        for i in range(min(2, args.batch)):
            o = outs[i]
            print(f"req{i}: ttft={o.ttft} qwait={o.queue_wait_steps} {o.tokens.tolist()}")
        if args.probe_every:
            print(
                f"probes: {stats.probe_dispatches} dispatches "
                f"(every {args.probe_every}), health={eng.health.state(0)}, "
                f"alerts firing={eng.alerts.firing() if eng.alerts else []}"
            )
        if args.health_out:
            import json

            with open(args.health_out, "w") as f:
                json.dump(dict(
                    health=eng.health.summary(),
                    alerts=eng.alerts.summary() if eng.alerts else None,
                ), f, indent=2)
            print(f"health: {args.health_out}")
        if args.trace_out:
            from repro.obs import write_chrome_trace

            t = write_chrome_trace(args.trace_out, rec)
            print(f"trace: {args.trace_out} ({len(t['traceEvents'])} events)")
        if args.metrics_out:
            from repro.obs import write_jsonl

            write_jsonl(args.metrics_out, rec)
            print(f"metrics: {args.metrics_out} "
                  f"({len(rec.event_list())} events, "
                  f"self time {rec.self_time_s*1e3:.2f} ms)")
        return

    engine = ServeEngine(cfg, params, ctx, max_len=args.max_len)
    t0 = time.time()
    out = engine.generate(
        prompts, max_new_tokens=args.new_tokens, temperature=args.temperature
    )
    dt = time.time() - t0
    print(f"{args.batch}x{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"seq{i}: {out.tokens[i, args.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
