"""Training launcher CLI.

Runs (or resumes) fault-aware training of any assigned arch on the local
device set, with the same config/policy machinery the dry-run validates at
pod scale. On a real TPU deployment this binary is what every host runs;
here --reduced exercises it end-to-end on CPU.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
        --steps 100 --fault-rate 0.1 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="CPU-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--fault-rate", type=float, default=0.0)
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moe-impl", default="einsum", choices=["einsum", "scatter"])
    args = ap.parse_args()

    import jax

    from repro.configs import get_arch, reduce_config
    from repro.core import from_fault_map, healthy, random_fault_map
    from repro.data.synthetic import TokenStream
    from repro.models import model as M
    from repro.train.loop import LoopConfig, run_training
    from repro.train.optimizer import AdamWConfig, adamw_init, cosine_schedule
    from repro.train.step import make_eval_step, make_jit_train_step

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M")

    stream = TokenStream(cfg.vocab_size, args.seq, args.batch, seed=0)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(
        learning_rate=cosine_schedule(args.lr, warmup=20, total=args.steps)
    )
    # donating (params, opt_state) — safe here because the loop re-binds
    # both from each step's outputs and checkpointing snapshots to host
    # synchronously before the next dispatch
    train_step = make_jit_train_step(
        cfg, ocfg, remat="none", microbatches=args.microbatches,
        moe_impl=args.moe_impl,
    )
    eval_step = jax.jit(make_eval_step(cfg, remat="none"))
    opt = adamw_init(params, ocfg)

    ctx = healthy()
    if args.fault_rate > 0:
        fm = random_fault_map(
            args.fault_seed, cfg.array_rows, cfg.array_cols, args.fault_rate
        )
        ctx = from_fault_map(fm)
        print(f"fault map: rate={fm.fault_rate:.3f} ({fm.num_faults} faulty PEs)")

    eval_batch = stream.batch_at(10_000_000)

    def eval_fn(p):
        return eval_step(p, eval_batch, ctx)

    def on_metrics(step, m):
        keys = ("loss", "accuracy", "eval_loss", "eval_accuracy", "grad_norm", "step_time_s")
        line = " ".join(f"{k}={m[k]:.4f}" for k in keys if k in m)
        print(f"step {step}: {line}", flush=True)

    lc = LoopConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        eval_every=args.eval_every,
        log_every=10,
    )
    t0 = time.time()
    params, opt, state = run_training(
        lc, train_step=train_step, batch_at=stream.batch_at,
        params=params, opt_state=opt, ctx=ctx,
        eval_fn=eval_fn, on_metrics=on_metrics,
    )
    print(f"done: {state.step} steps in {time.time()-t0:.1f}s, "
          f"restarts={state.restarts}, stragglers={len(state.straggler_events)}")


if __name__ == "__main__":
    main()
