"""Loop-aware cost accounting from optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts scan-over-layers / microbatch programs by orders of magnitude.
This module walks the HLO call graph from ENTRY, multiplying while bodies
by their ``known_trip_count`` backend config, and accounts per top-level
instruction:

  flops  — dot instructions: 2 * prod(result dims) * prod(contracting dims)
           (contracting sizes resolved via a per-computation symbol table)
  bytes  — HBM traffic model: operands + result per top-level op; fusions
           count as single ops; bookkeeping ops (tuple/GTE/bitcast/param/
           constant) are free; dynamic-update-slice counts 2x update size
           (read+write, aliased buffer)
  collective wire bytes — ring-model per kind (see _collective_bytes)

This intentionally mirrors HloCostAnalysis conventions where they are
defensible and documents divergences; the roofline terms in EXPERIMENTS.md
cite this module as the source.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Iterator, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*?\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n":"(\d+)"')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "custom-call",  # custom-calls on this path are layout/control
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _shapes_in(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return default


def _collective_bytes(kind: str, out_bytes: int, n: int) -> float:
    frac = (n - 1) / n if n > 1 else 0.0
    if kind == "all-gather":
        return out_bytes * frac
    if kind == "all-reduce":
        return 2 * out_bytes * frac
    if kind == "reduce-scatter":
        return out_bytes * (n - 1)
    if kind == "all-to-all":
        return out_bytes * frac
    return float(out_bytes)  # collective-permute


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    coll_count: dict = field(default_factory=lambda: defaultdict(int))
    dot_flops_by_name: dict = field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += int(v * mult)
        for k, v in other.dot_flops_by_name.items():
            self.dot_flops_by_name[k] += v * mult

    def as_dict(self) -> dict:
        top_dots = sorted(
            self.dot_flops_by_name.items(), key=lambda kv: -kv[1]
        )[:8]
        return dict(
            flops=self.flops,
            bytes=self.bytes,
            collective_bytes=self.collective_bytes,
            coll_by_kind={k: float(v) for k, v in self.coll_by_kind.items()},
            coll_count=dict(self.coll_count),
            top_dots=[(k, float(v)) for k, v in top_dots],
        )


def _parse_computations(hlo: str):
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _parse_instr(line: str):
    """Returns (name, result_text, opcode) or None.

    Result types may be tuples containing '=' inside /*index=N*/ comments,
    so the opcode is located as the first 'word(' after the '='."""
    am = _ASSIGN_RE.match(line)
    if not am:
        return None
    name, rest = am.groups()
    om = _OPCODE_RE.search(rest)
    if not om:
        return None
    return name, rest[: om.start()], om.group(1)


# ---------------------------------------------------------------------------
# Public per-instruction API
#
# Downstream passes (repro.analysis) consume parsed instructions and the
# module's input/output alias table through these instead of re-parsing the
# HLO text with their own regexes.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Instruction:
    """One parsed HLO instruction (top level of one computation)."""

    computation: str
    name: str
    opcode: str
    result_text: str  # raw result-type text, e.g. "f32[128,256]{1,0} "
    operands: tuple[str, ...]
    is_root: bool
    line: str

    @property
    def result_bytes(self) -> int:
        return _bytes_of(self.result_text)


@dataclass(frozen=True)
class IOAlias:
    """One entry of the module's ``input_output_alias`` table: output (tuple
    index into the result) aliases entry parameter ``param_number`` — i.e.
    that parameter's buffer was donated and XLA reuses it in place."""

    output_index: tuple[int, ...]
    param_number: int
    kind: str = "may-alias"


def _operands_of(line: str, opcode: str) -> tuple[str, ...]:
    """Operand instruction names of one HLO line (shared by the cost walk)."""
    tail = line.split(opcode + "(", 1)
    if len(tail) < 2:
        return ()
    return tuple(_OPERAND_RE.findall(tail[1].split("), ")[0]))


def iter_instructions(
    hlo: str, computation: Optional[str] = None, entry_only: bool = False
) -> Iterator[Instruction]:
    """Yield every parsed instruction of ``hlo``.

    ``computation`` restricts to one computation by name; ``entry_only``
    restricts to the ENTRY computation. Lines that are not instructions
    (headers, braces, metadata continuations) are skipped.
    """
    comps, entry = _parse_computations(hlo)
    if entry_only:
        if entry is None:
            return
        names = [entry]
    elif computation is not None:
        names = [computation] if computation in comps else []
    else:
        names = list(comps)
    for comp in names:
        for line in comps[comp]:
            parsed = _parse_instr(line)
            if not parsed:
                continue
            name, result_text, op = parsed
            yield Instruction(
                computation=comp,
                name=name,
                opcode=op,
                result_text=result_text,
                operands=_operands_of(line, op),
                is_root=line.strip().startswith("ROOT"),
                line=line,
            )


_ALIAS_TABLE_RE = re.compile(r"input_output_alias=\{(.*?)\}\s*(?:,|$)")
_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+)\s*,\s*\{[\d,\s]*\}\s*(?:,\s*([\w\-]+))?\)"
)


def input_output_aliases(hlo: str) -> list[IOAlias]:
    """Parse the ``input_output_alias={...}`` table from the HloModule header.

    Returns one :class:`IOAlias` per aliased (donated) entry parameter; an
    empty list when the program donates nothing. The table only appears in
    *optimized* HLO (``compiled.as_text()``), not in pre-compile StableHLO.
    """
    out: list[IOAlias] = []
    for line in hlo.splitlines():
        if not line.startswith("HloModule"):
            continue
        # the table's inner braces nest one level: grab everything between
        # 'input_output_alias={' and the matching close brace
        start = line.find("input_output_alias={")
        if start < 0:
            return []
        depth = 0
        body = []
        for ch in line[start + len("input_output_alias=") :]:
            if ch == "{":
                depth += 1
                if depth == 1:
                    continue
            elif ch == "}":
                depth -= 1
                if depth == 0:
                    break
            body.append(ch)
        for m in _ALIAS_ENTRY_RE.finditer("".join(body)):
            idx = tuple(int(x) for x in m.group(1).split(",") if x.strip())
            out.append(
                IOAlias(
                    output_index=idx,
                    param_number=int(m.group(2)),
                    kind=m.group(3) or "must-alias",
                )
            )
        break
    return out


def entry_parameters(hlo: str) -> dict[int, Instruction]:
    """ENTRY computation parameters by parameter number.

    ``entry_parameters(hlo)[n].result_bytes`` is the byte size of entry
    parameter ``n`` — the donation lint joins this against
    :func:`input_output_aliases` to weigh undonated buffers.
    """
    out: dict[int, Instruction] = {}
    for instr in iter_instructions(hlo, entry_only=True):
        if instr.opcode != "parameter":
            continue
        m = re.search(r"parameter\((\d+)\)", instr.line)
        if m:
            out[int(m.group(1))] = instr
    return out


def analyze_hlo(hlo: str, n_devices_default: int = 1) -> Cost:
    comps, entry = _parse_computations(hlo)

    # fusion computations are called via fusion instructions; never walk them
    fusion_comps = set()
    for lines in comps.values():
        for line in lines:
            if " fusion(" in line:
                fm = re.search(r"calls=%?([\w.\-]+)", line)
                if fm:
                    fusion_comps.add(fm.group(1))

    # per-computation symbol table: instruction name -> result-type text
    symtab: dict[str, dict[str, str]] = {}
    for name, lines in comps.items():
        tab = {}
        for line in lines:
            parsed = _parse_instr(line)
            if parsed:
                tab[parsed[0]] = parsed[1]
        symtab[name] = tab

    # Per fused computation: (bytes per parameter index, output-bytes
    # override). A parameter consumed ONLY by dynamic-slice/gather reads
    # just the slice (the scan access pattern); a parameter that is the
    # in-place target of a ROOT dynamic-update-slice is aliased (0 bytes);
    # a DUS-rooted fusion writes only the update slice, not the buffer.
    fusion_info: dict[str, tuple[dict[int, float], float | None]] = {}

    def _fusion_params(comp: str) -> tuple[dict[int, float], float | None]:
        if comp in fusion_info:
            return fusion_info[comp]
        out: dict[int, float] = {}
        out_override: float | None = None
        lines = comps.get(comp, [])
        tab = symtab.get(comp, {})
        # parameter name -> index
        pidx: dict[str, int] = {}
        for line in lines:
            parsed = _parse_instr(line)
            if parsed and parsed[2] == "parameter":
                m = re.search(r"parameter\((\d+)\)", line)
                if m:
                    pidx[parsed[0]] = int(m.group(1))
        # classify uses
        sliced_bytes: dict[str, float] = {p: 0.0 for p in pidx}
        full_use: dict[str, bool] = {p: False for p in pidx}
        dus_target: set[str] = set()
        root_name = None
        defs: dict[str, tuple[str, list[str], str]] = {}
        for line in lines:
            parsed = _parse_instr(line)
            if not parsed:
                continue
            nm, rtext, op = parsed
            tail = line.split(op + "(", 1)
            otext = tail[1].split("), ")[0] if len(tail) > 1 else ""
            onames = _OPERAND_RE.findall(otext)
            defs[nm] = (op, onames, rtext)
            if line.strip().startswith("ROOT"):
                root_name = nm
            if parsed[2] == "parameter":
                continue
            for j, o in enumerate(onames):
                if o not in pidx:
                    continue
                if op in ("dynamic-slice", "gather", "slice"):
                    sliced_bytes[o] += _bytes_of(rtext)
                elif op == "dynamic-update-slice" and j == 0:
                    dus_target.add(o)  # aliased buffer, not traffic
                else:
                    full_use[o] = True
        # DUS-rooted fusion (possibly through a bitcast chain): the write is
        # the update slice
        node = root_name
        for _ in range(3):
            if node not in defs:
                break
            op, onames, rtext = defs[node]
            if op == "dynamic-update-slice":
                upd = onames[1] if len(onames) > 1 else None
                if upd and upd in defs:
                    out_override = _bytes_of(defs[upd][2])
                elif upd in pidx:
                    out_override = _bytes_of(tab.get(upd, ""))
                break
            if op in ("bitcast", "copy") and onames:
                node = onames[0]
            else:
                break
        for p, i in pidx.items():
            if full_use[p]:
                out[i] = _bytes_of(tab.get(p, ""))
            elif p in dus_target:
                out[i] = 0.0
            else:
                out[i] = sliced_bytes[p]
        fusion_info[comp] = (out, out_override)
        return fusion_info[comp]

    memo: dict[str, Cost] = {}

    def walk(comp: str, depth: int = 0) -> Cost:
        if comp in memo:
            return memo[comp]
        cost = Cost()
        memo[comp] = cost  # break cycles defensively
        if depth > 60 or comp not in comps:
            return cost
        tab = symtab[comp]
        for line in comps[comp]:
            parsed = _parse_instr(line)
            if not parsed:
                continue
            name, result_text, op = parsed
            if op.endswith("-done"):
                continue  # counted at -start
            base_op = op[:-6] if op.endswith("-start") else op
            # ---- collectives ------------------------------------------
            if base_op in _COLLECTIVES:
                out_b = _bytes_of(result_text)
                n = _group_size(line, n_devices_default)
                moved = _collective_bytes(base_op, out_b, n)
                cost.collective_bytes += moved
                cost.coll_by_kind[base_op] += moved
                cost.coll_count[base_op] += 1
                cost.bytes += 2 * out_b  # local read+write of the buffer
                continue
            # ---- control flow -----------------------------------------
            if base_op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", line)
                trips = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trips = int(tm.group(1))
                if bm:
                    cost.add(walk(bm.group(1), depth + 1), trips)
                continue
            if base_op in ("call", "conditional"):
                for cm in re.finditer(r"(?:to_apply|body)=%?([\w.\-]+)", line):
                    cost.add(walk(cm.group(1), depth + 1), 1)
                for cm in re.finditer(r"branch_computations=\{([^}]*)\}", line):
                    for b in _OPERAND_RE.findall(cm.group(1)):
                        cost.add(walk(b, depth + 1), 1)
                continue
            if base_op in _FREE_OPS:
                continue
            # ---- operand byte lookup ----------------------------------
            operand_names = _operands_of(line, op)
            op_bytes = sum(_bytes_of(tab.get(o, "")) for o in operand_names)
            out_bytes = _bytes_of(result_text)
            if base_op == "dynamic-update-slice":
                # aliased in-place update: read+write of the update slice
                upd = operand_names[1] if len(operand_names) > 1 else None
                ub = _bytes_of(tab.get(upd, "")) if upd else 0
                cost.bytes += 2 * ub
            elif base_op in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced/gathered region, not the operand
                cost.bytes += 2 * out_bytes
            elif base_op in ("broadcast", "iota"):
                cost.bytes += out_bytes
            elif base_op == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", line)
                if fm:
                    pb, out_override = _fusion_params(fm.group(1))
                    op_bytes = sum(
                        pb.get(i, _bytes_of(tab.get(o, "")))
                        for i, o in enumerate(operand_names)
                    )
                    if out_override is not None:
                        out_bytes = out_override
                cost.bytes += op_bytes + out_bytes
            else:
                cost.bytes += op_bytes + out_bytes
            # ---- dot flops --------------------------------------------
            if base_op == "dot":
                shapes = _shapes_in(result_text)
                out_elems = 1
                for _, dims in shapes:
                    for d in dims:
                        out_elems *= d
                lhs = operand_names[0] if operand_names else None
                lhs_shapes = _shapes_in(tab.get(lhs, "")) if lhs else []
                kdim = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if cm and lhs_shapes:
                    dims = lhs_shapes[0][1]
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            kdim *= dims[int(idx)]
                flops = 2.0 * out_elems * kdim
                cost.flops += flops
                meta = re.search(r'op_name="([^"]*)"', line)
                label = meta.group(1).split("/")[-2] if meta and "/" in (meta.group(1)) else base_op
                cost.dot_flops_by_name[label] += flops
            elif base_op == "convolution":
                cost.flops += 2 * _bytes_of(result_text)  # rough; unused path
        return cost

    if entry is None:
        return Cost()
    return walk(entry)
