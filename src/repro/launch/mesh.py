"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to obtain placeholder devices; everything else sees the real backend.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = one v5e pod (256 chips); 2x16x16 = two pods (512 chips).

    When more placeholder devices exist than the mesh needs (the dry-run
    forces 512 for the multi-pod pass), the single-pod mesh takes the first
    256."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    import numpy as np

    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} "
            "(dry-run must set --xla_force_host_platform_device_count)"
        )
    arr = np.array(devs[:need]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def make_pop_mesh(num_devices: int | None = None, axis: str = "pop"):
    """1-D mesh over the *population* axis — one slice per device, each
    training (or serving) a sub-population of fault maps.

    This is the fleet-scale mesh (repro.fleet): orthogonal to the
    data/model meshes above, it parallelizes over chips-being-retrained
    rather than over one model's tensors. Defaults to every visible device;
    CPU-testable by exporting
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the first
    jax import (a (1,)-mesh on a single device is valid and runs the same
    program).
    """
    import numpy as np

    devs = jax.devices()
    n = len(devs) if num_devices is None else int(num_devices)
    if n < 1 or n > len(devs):
        raise ValueError(f"pop mesh needs 1..{len(devs)} devices, asked for {n}")
    return jax.sharding.Mesh(np.array(devs[:n]), (axis,))
