"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to obtain placeholder devices; everything else sees the real backend.

Two mesh families live here:

* ``make_production_mesh`` / ``make_host_mesh`` — the model-parallel meshes
  (``("data", "model")``, optionally ``("pod", ...)``) that
  ``repro.launch.sharding`` resolves logical param/activation axes onto.
* ``make_fleet_mesh`` / ``make_pop_mesh`` — the fleet meshes used by
  ``repro.fleet``: a leading ``"pop"`` axis parallelizes over
  chips-being-retrained (one sub-population of fault maps per pop slice),
  and the trailing ``"model"`` axis — when > 1 — gives every pop slice a
  tensor-parallel sub-mesh so member params can be sharded *within* a slice
  instead of replicated per member. ``make_pop_mesh`` is the ``model=1``
  degenerate case, kept 1-D for the single-axis engine path.
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = one v5e pod (256 chips); 2x16x16 = two pods (512 chips).

    When more placeholder devices exist than the mesh needs (the dry-run
    forces 512 for the multi-pod pass), the single-pod mesh takes the first
    256."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    import numpy as np

    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} "
            "(dry-run must set --xla_force_host_platform_device_count)"
        )
    arr = np.array(devs[:need]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def _fleet_device_grid(pop: Optional[int], model: int):
    """Validated (pop, model) device grid for the fleet meshes.

    ``pop=None`` auto-sizes: the largest population extent such that
    ``pop * model`` fits the backend (i.e. the device count is *clamped*
    down to the nearest clean tiling instead of failing the reshape).
    Explicit extents that don't fit raise a ValueError naming the numbers —
    never the raw numpy reshape error.
    """
    import numpy as np

    devs = jax.devices()
    n = len(devs)
    try:
        model = int(model)
    except (TypeError, ValueError):
        raise ValueError(f"model extent must be an integer, got {model!r}") from None
    if model < 1:
        raise ValueError(f"model extent must be >= 1, got {model}")
    if model > n:
        raise ValueError(
            f"model extent {model} exceeds the {n} visible device(s); "
            "export XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "before the first jax import to force more host devices"
        )
    if pop is None:
        pop = n // model  # clamp: largest population extent that tiles
    try:
        pop = int(pop)
    except (TypeError, ValueError):
        raise ValueError(f"pop extent must be an integer, got {pop!r}") from None
    if pop < 1:
        raise ValueError(f"pop extent must be >= 1, got {pop}")
    need = pop * model
    if need > n:
        raise ValueError(
            f"fleet mesh {pop}x{model} needs {need} devices, have {n}; "
            "shrink the mesh or force more host devices via XLA_FLAGS"
        )
    return np.array(devs[:need]).reshape(pop, model)


def make_fleet_mesh(
    pop: Optional[int] = None,
    model: int = 1,
    *,
    axis_names: tuple[str, str] = ("pop", "model"),
):
    """2-D ``("pop", "model")`` mesh: ``pop`` slices of ``model`` devices.

    The population engine (``repro.fleet.sharding``) runs manual
    ``shard_map`` collectives only over the leading ``pop`` axis; the
    trailing ``model`` axis is left to the compiler (GSPMD) so the
    tensor-parallel rules in ``repro.launch.sharding`` can lay member
    params out *within* each pop slice. ``pop=None`` takes as many pop
    slices as tile the backend for the given ``model`` extent (clamping,
    not failing, when the device count doesn't divide cleanly).

    CPU-testable by exporting
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the first
    jax import; a 1x1 mesh on a single device is valid and runs the same
    program.
    """
    if len(axis_names) != 2:
        raise ValueError(f"fleet mesh needs exactly 2 axis names, got {axis_names!r}")
    return jax.sharding.Mesh(_fleet_device_grid(pop, model), tuple(axis_names))


def make_pop_mesh(num_devices: Optional[int] = None, axis: str = "pop"):
    """1-D mesh over the *population* axis — the ``model=1`` degenerate case
    of :func:`make_fleet_mesh`, kept 1-D for the single-axis engine path.

    One slice per device, each training (or serving) a sub-population of
    fault maps; orthogonal to the data/model meshes above. Defaults to every
    visible device. Validation (including ``num_devices`` that exceeds or
    doesn't cleanly fit the backend) is shared with ``make_fleet_mesh`` and
    raises clear ValueErrors rather than surfacing a raw reshape failure.
    """
    return jax.sharding.Mesh(_fleet_device_grid(num_devices, 1).reshape(-1), (axis,))
