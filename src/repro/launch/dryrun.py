import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run CLI.

Lowers + compiles every runnable (arch x shape) cell on the production
meshes — 16x16 (one pod, 256 chips) and 2x16x16 (two pods, 512 chips) —
and records memory/cost/collective analysis per cell.

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fault-mode", type=str, default="fap", choices=["fap", "none"])
    ap.add_argument("--moe-impl", type=str, default="einsum", choices=["einsum", "scatter"])
    ap.add_argument("--profile", type=str, default="baseline", choices=["baseline", "optimized"])
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import SHAPES, cell_skip_reason, get_arch, valid_cells
    from repro.launch.dryrun_lib import run_cell

    if args.all:
        cells = valid_cells()
    else:
        assert args.arch, "--arch required without --all"
        shapes = [args.shape] if args.shape else [
            s for s in SHAPES if cell_skip_reason(get_arch(args.arch), SHAPES[s]) is None
        ]
        cells = [(args.arch.replace("-", "_").replace(".", "_"), s) for s in shapes]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for multi_pod in meshes:
        for arch, shape in cells:
            tag = "pod2" if multi_pod else "pod1"
            out_path = f"{args.out}/{arch}__{shape}__{tag}.json"
            if args.skip_existing and os.path.exists(out_path):
                try:
                    prev = json.load(open(out_path))
                    if prev.get("status") == "ok":
                        print(f"[skip] {arch} {shape} {tag} (cached)")
                        continue
                except Exception:
                    pass
            t0 = time.time()
            info = run_cell(
                arch, shape,
                multi_pod=multi_pod,
                fault_mode=args.fault_mode,
                moe_impl=args.moe_impl,
                profile=args.profile,
                out_dir=args.out,
            )
            dt = time.time() - t0
            if info["status"] == "ok":
                ca = info.get("cost_analysis", {})
                mem = info.get("memory_analysis", {})
                coll = info.get("collectives", {})
                print(
                    f"[ok]   {arch:28s} {shape:12s} {tag}  "
                    f"flops/dev={ca.get('flops', 0):.3e} "
                    f"coll={coll.get('total_bytes', 0):.3e}B "
                    f"args={mem.get('argument_size_in_bytes', 0)/1e9:.2f}GB "
                    f"temp={mem.get('temp_size_in_bytes', 0)/1e9:.2f}GB "
                    f"[{dt:.0f}s]",
                    flush=True,
                )
            else:
                failures += 1
                print(f"[FAIL] {arch:28s} {shape:12s} {tag}  {info['error']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
