"""Dual fault types — the paper's §III-B multi-dimensional extension.

Besides PE bypass (FAP), the on-chip *weight memory* can hold stuck-at
cells: a weight stored in a stuck-at-1 cell reads back with a forced
magnitude (worst-case MSB), a stuck-at-0 cell zeroes it. Both follow the
same periodic (R, C) geometry as the PE array (the weight buffer is tiled
with the array). FAT under dual faults is projected training: after every
optimizer step the stored weights are re-projected onto the feasible set.
The resilience surface over (pe_rate, sa1_rate) populates a
``ResilienceTable2D`` and Step 2 interpolates it bilinearly — exactly the
paper's proposal for multi-fault-type systems.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import FaultMap, random_fault_map
from repro.core.mapping import periodic_mask
from repro.core.masking import from_fault_map
from repro.core.resilience import ResilienceTable2D

__all__ = ["dual_fault_weight", "project_params", "measure_resilience_2d"]


def dual_fault_weight(
    w: jax.Array, fm_pe: Optional[FaultMap], fm_sa1: Optional[FaultMap],
    magnitude: float = 1.0,
) -> jax.Array:
    """Effective weight under PE-bypass + weight-memory stuck-at-1 faults.

    SA1 cells read back sign(w) * magnitude; PE bypass then zeroes whatever
    maps onto faulty PEs (bypass dominates: the product never reaches the
    accumulator)."""
    if fm_sa1 is not None:
        sa1 = periodic_mask(w.shape, jnp.asarray(fm_sa1.faulty, jnp.float32), dtype=w.dtype)
        forced = jnp.sign(jnp.where(w == 0, 1.0, w)) * magnitude
        w = jnp.where(sa1 > 0, forced.astype(w.dtype), w)
    if fm_pe is not None:
        w = w * periodic_mask(w.shape, jnp.asarray(fm_pe.ok_mask), dtype=w.dtype)
    return w


def project_params(params: dict, fm_pe, fm_sa1, *, key_prefix: str = "w", magnitude: float = 1.0) -> dict:
    """Project classifier params onto the dual-fault feasible set."""
    out = {}
    for k, v in params.items():
        if k.startswith(key_prefix) and hasattr(v, "ndim") and v.ndim >= 2:
            out[k] = dual_fault_weight(v, fm_pe, fm_sa1, magnitude)
        else:
            out[k] = v
    return out


def measure_resilience_2d(
    trainer,  # ClassifierFATTrainer
    rates_pe: Sequence[float],
    rates_sa1: Sequence[float],
    constraint: float,
    *,
    array_shape=(32, 32),
    max_steps: int = 300,
    repeats: int = 1,
    seed: int = 0,
    magnitude: float = 1.0,
) -> ResilienceTable2D:
    """Steps-to-constraint over the (pe_rate, sa1_rate) grid via projected
    FAT; returns a bilinear-interpolating ResilienceTable2D."""
    from repro.train.optimizer import adamw_init, adamw_update

    rng = np.random.default_rng(seed)
    grid = np.zeros((len(rates_pe), len(rates_sa1)))
    for i, rp in enumerate(rates_pe):
        for j, rs in enumerate(rates_sa1):
            samples = []
            for rep in range(repeats):
                fm_pe = random_fault_map(rng, *array_shape, rp)
                fm_sa1 = random_fault_map(rng, *array_shape, rs)
                ctx = from_fault_map(fm_pe)

                def evaluate(p):
                    return trainer.evaluate_params(
                        project_params(p, None, fm_sa1, magnitude=magnitude), ctx
                    )

                params = project_params(
                    trainer.base_params, None, fm_sa1, magnitude=magnitude
                )
                if evaluate(params) >= constraint:
                    samples.append(0)
                    continue
                opt = adamw_init(params, trainer.opt_cfg)
                used = max_steps
                for s in range(1, max_steps + 1):
                    batch = trainer.data.batch_at(s, trainer.batch_size)
                    (_, _m), g = trainer.grad_fn(params, batch, ctx)
                    params, opt, _ = adamw_update(g, opt, params, trainer.opt_cfg)
                    # hardware projection: stuck cells cannot store updates
                    params = project_params(params, None, fm_sa1, magnitude=magnitude)
                    if s % trainer.eval_every == 0 and evaluate(params) >= constraint:
                        used = s
                        break
                samples.append(used)
            grid[i, j] = max(samples)
    return ResilienceTable2D(
        np.asarray(rates_pe, float), np.asarray(rates_sa1, float), grid,
        cap=max_steps, constraint=constraint,
    )
