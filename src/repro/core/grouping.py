"""Resilience-driven grouping & fusion of fault maps — eFAT Step 3
(paper SIII-D, Algorithm 2) plus the baselines it is compared against:
fixed per-chip policy ([8]) and random pairwise merging (TRE-map [16]).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.faults import FaultMap
from repro.core.resilience import ResilienceTable

__all__ = [
    "RetrainingPlan",
    "group_and_fuse",
    "fixed_policy_plan",
    "random_pair_merge_plan",
    "individual_plan",
]


@dataclass
class RetrainingPlan:
    """Output of Step 3: one entry per retraining job.

    ``links[g]`` lists the original chip indices served by job ``g``
    (the paper's T_Link), ``steps[g]`` the selected retraining amount.
    """

    fault_maps: list[FaultMap]
    links: list[list[int]]
    steps: list[float]
    method: str = ""

    @property
    def total_steps(self) -> float:
        return float(sum(self.steps))

    @property
    def num_jobs(self) -> int:
        return len(self.fault_maps)

    @property
    def num_chips(self) -> int:
        return sum(len(l) for l in self.links)

    def summary(self) -> dict:
        return dict(
            method=self.method,
            jobs=self.num_jobs,
            chips=self.num_chips,
            total_steps=self.total_steps,
            mean_steps_per_chip=self.total_steps / max(1, self.num_chips),
        )


def _cost(table: ResilienceTable, rate: float, stat: str) -> float:
    """Retraining amount at the table's measurement resolution.

    Rewards and prescribed amounts are read at *measured* points: the query
    rate rounds UP to the first rate Step 1 actually measured at or above
    it (conservative — the prescribed amount is a real measured requirement
    for a rate at least as high, never an interpolated undershoot).
    Comparing sub-knot linear interpolants instead manufactures phantom
    cost deltas — a fused map sitting between two knots gets charged a
    fraction of the next knot's cost even when the measurement says the
    whole band needs the same amount, which silently vetoes every
    correlated-map merge. Above the measured range the table's capped
    extrapolation applies unchanged.
    """
    r = np.asarray(table.rates)
    idx = int(np.searchsorted(r, float(rate), side="left"))
    if idx >= len(r):
        return float(table.required_steps(float(rate), stat=stat))
    return float(table.required_steps(float(r[idx]), stat=stat))


# ---------------------------------------------------------------------------
# Algorithm 2 (faithful implementation)
# ---------------------------------------------------------------------------


def group_and_fuse(
    fault_maps: Sequence[FaultMap],
    table: ResilienceTable,
    *,
    m_comparisons: int = 8,
    k_iterations: int = 2,
    stat: str = "max",
    seed: int = 0,
    require_reachable: bool = True,
) -> RetrainingPlan:
    """Paper Algo 2.

    Sort maps by fault rate ascending; for each map, compare against at most
    M randomly selected other maps, pick the candidate giving the lowest
    fused fault rate (paper SIII-D text), and merge when the saving
    ``cost(A) + cost(B) - cost(fused)`` is non-negative (costs evaluated at
    the resilience table's measurement resolution — see ``_cost``). A
    zero-saving merge is still a win: it removes a whole retraining job at
    no modeled step cost, which is the point of Step 3. Repeat K passes.
    Merged maps re-enter the sorted list at their rate position, so they can
    be fused again in later passes.

    ``require_reachable`` refuses merges whose fused rate cannot reach the
    constraint within the measurement cap (cost == cap) — retraining a group
    that can never satisfy the constraint helps nobody.
    """
    rng = np.random.default_rng(seed)
    maps = list(fault_maps)
    links: list[list[int]] = [[i] for i in range(len(maps))]
    rates = [m.fault_rate for m in maps]
    order = np.argsort(rates, kind="stable")
    maps = [maps[i] for i in order]
    links = [links[i] for i in order]
    rates = [rates[i] for i in order]

    for _ in range(k_iterations):
        i = 0
        while i < len(maps) - 1:
            fm = maps[i]
            # candidate pool: every other map (paper selects among MFMs
            # excluding the current one; we sample from the tail like the
            # pseudo-code's MFMs(:, :, i+1:end))
            pool = list(range(i + 1, len(maps)))
            if not pool:
                break
            if len(pool) > m_comparisons:
                pool = list(rng.choice(pool, size=m_comparisons, replace=False))
            # select the pairing with the least fused fault rate
            fused_rates = []
            for j in pool:
                fused = fm.faulty | maps[j].faulty
                fused_rates.append(float(fused.mean()))
            best_pos = int(np.argmin(fused_rates))
            j = pool[best_pos]
            fused_rate = fused_rates[best_pos]
            fused_cost = _cost(table, fused_rate, stat)
            saving = (
                _cost(table, rates[i], stat)
                + _cost(table, rates[j], stat)
                - fused_cost
            )
            # feasibility must use the same knot-quantized cost the plan
            # records, or a merge could be accepted whose prescribed job
            # sits at the cap (= constraint unreachable)
            feasible = (not require_reachable) or fused_cost < table.cap
            if saving >= 0 and feasible:
                fused_map = maps[i].merge(maps[j])
                fused_link = links[i] + links[j]
                # remove j first (j > i), then i
                for idx in sorted((i, j), reverse=True):
                    maps.pop(idx)
                    links.pop(idx)
                    rates.pop(idx)
                # insert at sorted position by rate
                pos = int(np.searchsorted(rates, fused_rate))
                maps.insert(pos, fused_map)
                links.insert(pos, fused_link)
                rates.insert(pos, fused_rate)
                # do not advance: the element now at i is unexamined
            else:
                i += 1

    steps = [_cost(table, r, stat) for r in rates]
    return RetrainingPlan(maps, links, steps, method=f"efat(M={m_comparisons},K={k_iterations},{stat})")


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------


def individual_plan(
    fault_maps: Sequence[FaultMap], table: ResilienceTable, stat: str = "max"
) -> RetrainingPlan:
    """eFAT Steps 1+2 without Step 3: per-chip resilience-selected amounts."""
    maps = list(fault_maps)
    steps = [_cost(table, m.fault_rate, stat) for m in maps]
    return RetrainingPlan(maps, [[i] for i in range(len(maps))], steps, method=f"individual({stat})")


def fixed_policy_plan(
    fault_maps: Sequence[FaultMap], steps_per_chip: float
) -> RetrainingPlan:
    """[8]-style fixed policy: same pre-specified amount for every chip."""
    maps = list(fault_maps)
    return RetrainingPlan(
        maps,
        [[i] for i in range(len(maps))],
        [float(steps_per_chip)] * len(maps),
        method=f"fixed({steps_per_chip})",
    )


def random_pair_merge_plan(
    fault_maps: Sequence[FaultMap],
    table: Optional[ResilienceTable] = None,
    steps_per_job: Optional[float] = None,
    stat: str = "max",
    seed: int = 0,
) -> RetrainingPlan:
    """TRE-map [16] as simulated in the paper SIV-C: randomly pair all chips,
    merge each pair, retrain once per pair (either a fixed amount or the
    resilience-table amount at the fused rate)."""
    rng = np.random.default_rng(seed)
    n = len(fault_maps)
    perm = rng.permutation(n)
    maps, links, steps = [], [], []
    for a in range(0, n - 1, 2):
        i, j = int(perm[a]), int(perm[a + 1])
        fused = fault_maps[i].merge(fault_maps[j])
        maps.append(fused)
        links.append([i, j])
        steps.append(
            float(steps_per_job)
            if steps_per_job is not None
            else _cost(table, fused.fault_rate, stat)
        )
    if n % 2:
        i = int(perm[-1])
        maps.append(fault_maps[i])
        links.append([i])
        steps.append(
            float(steps_per_job)
            if steps_per_job is not None
            else _cost(table, fault_maps[i].fault_rate, stat)
        )
    return RetrainingPlan(maps, links, steps, method="tre-map-random-pairs")
