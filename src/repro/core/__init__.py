"""eFAT core: fault maps, systolic mapping, resilience analysis,
grouping & fusion, and the end-to-end orchestrator (paper Fig. 7)."""
from repro.core.efat import EFAT, BatchFATTrainerFull, EFATConfig, EFATResult
from repro.core.faults import (
    FaultMap,
    clustered_fault_map,
    correlated_family,
    expected_merged_rate,
    gaussian_chip_rates,
    merge_fault_maps,
    overlap_rate,
    random_fault_map,
)
from repro.core.grouping import (
    RetrainingPlan,
    fixed_policy_plan,
    group_and_fuse,
    individual_plan,
    random_pair_merge_plan,
)
from repro.core.mapping import (
    apply_fam,
    expected_weight_loss,
    fam_permutation,
    masked_weight,
    periodic_mask,
)
from repro.core.masking import (
    FaultContext,
    fault_einsum,
    fault_linear,
    from_fault_map,
    healthy,
    stack_contexts,
)
from repro.core.resilience import (
    BatchFATTrainer,
    ResilienceTable,
    ResilienceTable2D,
    fault_rate_list,
    measure_resilience,
)

__all__ = [
    "EFAT",
    "EFATConfig",
    "EFATResult",
    "BatchFATTrainer",
    "BatchFATTrainerFull",
    "FaultMap",
    "FaultContext",
    "RetrainingPlan",
    "ResilienceTable",
    "ResilienceTable2D",
    "apply_fam",
    "clustered_fault_map",
    "correlated_family",
    "expected_merged_rate",
    "expected_weight_loss",
    "fam_permutation",
    "fault_einsum",
    "fault_linear",
    "fault_rate_list",
    "fixed_policy_plan",
    "from_fault_map",
    "gaussian_chip_rates",
    "group_and_fuse",
    "healthy",
    "individual_plan",
    "masked_weight",
    "measure_resilience",
    "merge_fault_maps",
    "overlap_rate",
    "periodic_mask",
    "random_fault_map",
    "random_pair_merge_plan",
    "stack_contexts",
]
