"""Resilience analysis — eFAT Step 1 + Step 2 (paper SIII-B, SIII-C).

Step 1 measures, by fault-injection + FAT runs, the amount of retraining
needed to reach the user accuracy constraint at each fault rate from the
Algo-1 list, repeated over several random fault patterns (min/mean/max kept,
paper Fig. 12 recommends max).

Step 2 answers per-chip queries by interpolating the measured curve
(linear between the two nearest rates — the paper's "bilinear" collapses to
linear in the single-fault-type case; a true bilinear 2-D table is provided
for dual fault-type systems, paper SIII-B last paragraph).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Protocol, Sequence

import numpy as np

from repro.core.faults import FaultMap, random_fault_map

__all__ = [
    "fault_rate_list",
    "FATTrainer",
    "BatchFATTrainer",
    "ResilienceTable",
    "ResilienceTable2D",
    "measure_resilience",
]


# ---------------------------------------------------------------------------
# Algorithm 1 — fault-rate list
# ---------------------------------------------------------------------------


def fault_rate_list(
    chip_fault_rates: Sequence[float],
    max_fr: float = 0.5,
    max_interval: float = 0.05,
    step: float = 0.5,
) -> list[float]:
    """Paper Algo 1. Geometric ramp from the fleet's min fault rate with
    interval growth ``Current_FR * step`` capped at ``max_interval``, covering
    up to max(max chip rate, max_fr) — the headroom above the max chip rate
    is what lets fused (higher-rate) maps interpolate instead of extrapolate.
    """
    if len(chip_fault_rates) == 0:
        raise ValueError("need at least one chip fault rate")
    frs = [float(f) for f in chip_fault_rates]
    current = min(frs)
    upper = max(max(frs), max_fr)
    out = [current]
    # degenerate start (rate 0) would never advance via current*step
    floor_step = max_interval / 64.0
    while current <= upper:
        current = current + max(min(current * step, max_interval), floor_step)
        out.append(current)
    return out


# ---------------------------------------------------------------------------
# Trainer protocol (implemented in repro.train.fat_trainer)
# ---------------------------------------------------------------------------


class FATTrainer(Protocol):
    """Anything that can run fault-aware training to a constraint."""

    def steps_to_constraint(
        self, fault_map: FaultMap, constraint: float, max_steps: int
    ) -> Optional[int]:
        """FAT with this map until eval metric >= constraint; return steps
        used, or None if not reached within max_steps."""
        ...


class BatchFATTrainer(FATTrainer, Protocol):
    """A trainer that can probe a whole population of fault maps at once
    (repro.train.population). Step 1 submits the full rates x repeats grid
    through this method when available."""

    def steps_to_constraint_batch(
        self, fault_maps: Sequence[FaultMap], constraint: float, max_steps: int
    ) -> list[Optional[int]]: ...


# ---------------------------------------------------------------------------
# Resilience tables
# ---------------------------------------------------------------------------


@dataclass
class ResilienceTable:
    """required-retraining vs fault-rate with min/mean/max statistics.

    ``rates`` strictly increasing; stats arrays aligned. ``cap`` is the
    max_steps used during measurement (entries at cap mean 'constraint not
    reachable' — cost clamps there).
    """

    rates: np.ndarray
    min_steps: np.ndarray
    mean_steps: np.ndarray
    max_steps_stat: np.ndarray
    cap: int
    constraint: float
    meta: dict = field(default_factory=dict)

    def __post_init__(self):
        self.rates = np.asarray(self.rates, dtype=np.float64)
        self.min_steps = np.asarray(self.min_steps, dtype=np.float64)
        self.mean_steps = np.asarray(self.mean_steps, dtype=np.float64)
        self.max_steps_stat = np.asarray(self.max_steps_stat, dtype=np.float64)
        if not np.all(np.diff(self.rates) > 0):
            raise ValueError("rates must be strictly increasing")

    def _series(self, stat: str) -> np.ndarray:
        return {
            "min": self.min_steps,
            "mean": self.mean_steps,
            "max": self.max_steps_stat,
        }[stat]

    def required_steps(self, fault_rate: float, stat: str = "max") -> float:
        """Paper Step 2: interpolate between the two nearest measured rates.

        Below the measured range: clamp to the first point (conservative).
        Above: extrapolate with the last segment's slope, clamped to cap —
        Algo 1's Max_FR headroom makes this path rare.
        """
        r, y = self.rates, self._series(stat)
        fr = float(fault_rate)
        if fr <= r[0]:
            return float(y[0])
        if fr >= r[-1]:
            if len(r) >= 2 and r[-1] > r[-2]:
                slope = (y[-1] - y[-2]) / (r[-1] - r[-2])
                return float(min(self.cap, max(0.0, y[-1] + slope * (fr - r[-1]))))
            return float(y[-1])
        return float(np.interp(fr, r, y))

    def reachable(self, fault_rate: float, stat: str = "max") -> bool:
        return self.required_steps(fault_rate, stat) < self.cap

    # --- persistence ----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            dict(
                rates=self.rates.tolist(),
                min_steps=self.min_steps.tolist(),
                mean_steps=self.mean_steps.tolist(),
                max_steps_stat=self.max_steps_stat.tolist(),
                cap=self.cap,
                constraint=self.constraint,
                meta=self.meta,
            )
        )

    @staticmethod
    def from_json(s: str) -> "ResilienceTable":
        d = json.loads(s)
        return ResilienceTable(
            np.array(d["rates"]),
            np.array(d["min_steps"]),
            np.array(d["mean_steps"]),
            np.array(d["max_steps_stat"]),
            cap=d["cap"],
            constraint=d["constraint"],
            meta=d.get("meta", {}),
        )

    @staticmethod
    def from_function(
        rates: Sequence[float], fn: Callable[[float], float], cap: int = 10**9, constraint: float = 0.0
    ) -> "ResilienceTable":
        """Analytic table (used in unit tests / synthetic studies)."""
        rates = np.asarray(sorted(set(float(r) for r in rates)))
        y = np.array([min(cap, fn(r)) for r in rates], dtype=np.float64)
        return ResilienceTable(rates, y, y, y, cap=cap, constraint=constraint)


@dataclass
class ResilienceTable2D:
    """Bilinear table over two fault types (e.g. stuck-at-0 x stuck-at-1 in
    weight memory) — paper SIII-B's multi-dimensional extension."""

    rates_a: np.ndarray
    rates_b: np.ndarray
    steps: np.ndarray  # (len(rates_a), len(rates_b))
    cap: int
    constraint: float

    def __post_init__(self):
        self.rates_a = np.asarray(self.rates_a, dtype=np.float64)
        self.rates_b = np.asarray(self.rates_b, dtype=np.float64)
        self.steps = np.asarray(self.steps, dtype=np.float64)
        assert self.steps.shape == (len(self.rates_a), len(self.rates_b))

    def required_steps(self, ra: float, rb: float) -> float:
        """True bilinear interpolation on the 2-D grid (clamped at edges)."""
        a, b, z = self.rates_a, self.rates_b, self.steps
        ra = float(np.clip(ra, a[0], a[-1]))
        rb = float(np.clip(rb, b[0], b[-1]))
        i = int(np.clip(np.searchsorted(a, ra) - 1, 0, len(a) - 2))
        j = int(np.clip(np.searchsorted(b, rb) - 1, 0, len(b) - 2))
        ta = 0.0 if a[i + 1] == a[i] else (ra - a[i]) / (a[i + 1] - a[i])
        tb = 0.0 if b[j + 1] == b[j] else (rb - b[j]) / (b[j + 1] - b[j])
        top = z[i, j] * (1 - tb) + z[i, j + 1] * tb
        bot = z[i + 1, j] * (1 - tb) + z[i + 1, j + 1] * tb
        return float(top * (1 - ta) + bot * ta)


# ---------------------------------------------------------------------------
# Step-1 measurement driver
# ---------------------------------------------------------------------------


def measure_resilience(
    trainer: FATTrainer,
    rates: Sequence[float],
    constraint: float,
    *,
    array_shape: tuple[int, int] = (256, 256),
    repeats: int = 5,
    max_steps: int = 2000,
    seed: int = 0,
    fault_gen=random_fault_map,
    progress: Optional[Callable[[str], None]] = None,
    engine: Optional[str] = None,
) -> ResilienceTable:
    """Run FAT experiments at each rate x repeat, recording steps-to-
    constraint (paper: 'each data point ... averaged over multiple
    iterations to cope with the variations in fault patterns').

    The fault-map grid is generated up front (rate-major, identical rng
    stream to the historical serial loop) and, when the trainer implements
    the batch protocol, the WHOLE rates x repeats grid is submitted as one
    ``steps_to_constraint_batch`` call. How that population is packed into
    chunks is the trainer's scheduler's job (repro.fleet.FleetScheduler
    packs by fault rate, so chunk members cross at similar times and the
    early-exit loop wastes little straggler work) — Step 1 and Step 4 share
    that single chunking implementation instead of this function hand-sorting
    by rate. ``engine`` forces the submission path: "population" requires
    the batch protocol, "serial" forces the per-map reference loop, None
    (auto) prefers batch when available. Which math runs under either
    submission is the *trainer's* engine choice; this flag only controls
    batching. Per-member results are identical either way.
    """
    rng = np.random.default_rng(seed)
    grid: list[tuple[float, list[FaultMap]]] = [
        (
            rate,
            [fault_gen(rng, array_shape[0], array_shape[1], rate) for _ in range(repeats)],
        )
        for rate in rates
    ]
    batch_capable = hasattr(trainer, "steps_to_constraint_batch")
    if engine == "population" and not batch_capable:
        raise ValueError("engine='population' needs a trainer with steps_to_constraint_batch")
    use_batch = batch_capable and engine != "serial"
    if use_batch:
        # one submission for the whole grid: progress necessarily reports
        # after the population program returns
        flat_maps = [fm for _rate, fms in grid for fm in fms]
        flat_steps = trainer.steps_to_constraint_batch(flat_maps, constraint, max_steps)
    mins, means, maxs = [], [], []
    kept_rates = []
    for k, (rate, fms) in enumerate(grid):
        if use_batch:
            steps_list = flat_steps[k * repeats : (k + 1) * repeats]
        else:
            # serial reference: one map at a time, progress stays live
            steps_list = [trainer.steps_to_constraint(fm, constraint, max_steps) for fm in fms]
        samples = []
        for rep, steps in enumerate(steps_list):
            samples.append(max_steps if steps is None else steps)
            if progress:
                progress(f"rate={rate:.4f} rep={rep} steps={samples[-1]}")
        kept_rates.append(rate)
        mins.append(min(samples))
        means.append(float(np.mean(samples)))
        maxs.append(max(samples))
    # de-duplicate non-increasing rates defensively
    kept = np.asarray(kept_rates)
    order = np.argsort(kept)
    kept, mins, means, maxs = (
        kept[order],
        np.asarray(mins)[order],
        np.asarray(means)[order],
        np.asarray(maxs)[order],
    )
    uniq, idx = np.unique(kept, return_index=True)
    return ResilienceTable(
        uniq,
        np.asarray(mins)[idx],
        np.asarray(means)[idx],
        np.asarray(maxs)[idx],
        cap=max_steps,
        constraint=constraint,
        meta=dict(repeats=repeats, array_shape=list(array_shape)),
    )
