"""Fault maps for systolic-array DNN accelerators (paper SII-B, SIV-A).

A fault map is a boolean grid over the PE array: ``faulty[r, c] == True``
means PE (r, c) has a permanent fault and is bypassed (FAP semantics of
Zhang et al. [8]): any weight mapped onto it contributes zero.

All fault-map machinery is host-side numpy — fault maps are per-chip
artifacts fed to JAX programs as small constants.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "FaultMap",
    "random_fault_map",
    "clustered_fault_map",
    "correlated_family",
    "merge_fault_maps",
    "expected_merged_rate",
    "overlap_rate",
    "gaussian_chip_rates",
]


@dataclass(frozen=True)
class FaultMap:
    """Permanent-fault map of one chip's computational array."""

    faulty: np.ndarray  # bool (rows, cols)
    chip_id: str = ""

    def __post_init__(self):
        object.__setattr__(self, "faulty", np.asarray(self.faulty, dtype=bool))
        if self.faulty.ndim != 2:
            raise ValueError(f"fault map must be 2-D, got {self.faulty.shape}")

    # Eq. 2: Pr = #faulty / total
    @property
    def fault_rate(self) -> float:
        return float(self.faulty.mean())

    @property
    def num_faults(self) -> int:
        return int(self.faulty.sum())

    @property
    def shape(self) -> tuple[int, int]:
        return self.faulty.shape  # type: ignore[return-value]

    @property
    def ok_mask(self) -> np.ndarray:
        """float32 multiplicative mask: 1 healthy, 0 faulty."""
        return (~self.faulty).astype(np.float32)

    def merge(self, other: "FaultMap") -> "FaultMap":
        """Fuse two fault maps: a PE is faulty if faulty in either (union)."""
        if self.shape != other.shape:
            raise ValueError(f"shape mismatch {self.shape} vs {other.shape}")
        return FaultMap(
            self.faulty | other.faulty,
            chip_id=f"{self.chip_id}+{other.chip_id}" if self.chip_id else other.chip_id,
        )

    def __or__(self, other: "FaultMap") -> "FaultMap":
        return self.merge(other)

    # --- serialization -------------------------------------------------
    # np.savez_compressed appends '.npz' to suffix-less paths, so save and
    # load both normalize the suffix — load(p) always reads what save(p)
    # wrote, whichever spelling the caller used.
    @staticmethod
    def _npz_path(path) -> str:
        path = os.fspath(path)
        return path if path.endswith(".npz") else path + ".npz"

    def save(self, path) -> None:
        np.savez_compressed(self._npz_path(path), faulty=self.faulty, chip_id=self.chip_id)

    @staticmethod
    def load(path) -> "FaultMap":
        z = np.load(FaultMap._npz_path(path), allow_pickle=False)
        return FaultMap(z["faulty"], chip_id=str(z["chip_id"]))


# ---------------------------------------------------------------------------
# Generation models
# ---------------------------------------------------------------------------


def random_fault_map(
    rng: np.random.Generator | int,
    rows: int = 256,
    cols: int = 256,
    fault_rate: float = 0.05,
    chip_id: str = "",
    exact: bool = True,
) -> FaultMap:
    """Paper's model: i.i.d. random permanent faults ([8], [12]).

    ``exact=True`` places exactly round(rate * R * C) faults (paper's fault
    rate is a count ratio, Eq. 2); ``False`` samples i.i.d. Bernoulli.
    """
    rng = np.random.default_rng(rng) if isinstance(rng, (int, np.integer)) else rng
    n = rows * cols
    if exact:
        k = int(round(fault_rate * n))
        flat = np.zeros(n, dtype=bool)
        if k > 0:
            flat[rng.choice(n, size=k, replace=False)] = True
        return FaultMap(flat.reshape(rows, cols), chip_id=chip_id)
    return FaultMap(rng.random((rows, cols)) < fault_rate, chip_id=chip_id)


def clustered_fault_map(
    rng: np.random.Generator | int,
    rows: int = 256,
    cols: int = 256,
    fault_rate: float = 0.05,
    cluster_sigma: float = 8.0,
    chip_id: str = "",
) -> FaultMap:
    """Spatially clustered defects (realistic wafer defect model).

    Faults are drawn around a small number of defect centers with Gaussian
    spread — produces the spatial correlation that makes map fusion pay off.
    """
    rng = np.random.default_rng(rng) if isinstance(rng, (int, np.integer)) else rng
    n_target = int(round(fault_rate * rows * cols))
    faulty = np.zeros((rows, cols), dtype=bool)
    n_clusters = max(1, n_target // max(1, int(4 * cluster_sigma**2 * 0.3)))
    centers = rng.uniform([0, 0], [rows, cols], size=(n_clusters, 2))
    placed = 0
    guard = 0
    while placed < n_target and guard < 100 * n_target + 100:
        guard += 1
        c = centers[rng.integers(n_clusters)]
        r = int(round(rng.normal(c[0], cluster_sigma))) % rows
        q = int(round(rng.normal(c[1], cluster_sigma))) % cols
        if not faulty[r, q]:
            faulty[r, q] = True
            placed += 1
    return FaultMap(faulty, chip_id=chip_id)


def correlated_family(
    rng: np.random.Generator | int,
    n_chips: int,
    rows: int = 256,
    cols: int = 256,
    base_rate: float = 0.05,
    idio_rate: float = 0.02,
    chip_prefix: str = "chip",
) -> list[FaultMap]:
    """Chips from the same wafer region: shared base defects + per-chip
    idiosyncratic faults. Fusion of such maps is profitable (Eq. 3 with
    Pr_A AND Pr_B >> Pr_A * Pr_B)."""
    rng = np.random.default_rng(rng) if isinstance(rng, (int, np.integer)) else rng
    base = random_fault_map(rng, rows, cols, base_rate)
    out = []
    for i in range(n_chips):
        idio = random_fault_map(rng, rows, cols, idio_rate)
        out.append(FaultMap(base.faulty | idio.faulty, chip_id=f"{chip_prefix}{i}"))
    return out


def gaussian_chip_rates(
    rng: np.random.Generator | int,
    n_chips: int,
    mean: float = 0.1,
    sigma: float = 0.02,
    lo: float = 0.0,
    hi: float = 1.0,
) -> np.ndarray:
    """Fault-rate distribution used in the paper's SIV-C fleet experiment
    (Gaussian, mean 0.1, sigma 0.02), clipped to [lo, hi]."""
    rng = np.random.default_rng(rng) if isinstance(rng, (int, np.integer)) else rng
    return np.clip(rng.normal(mean, sigma, size=n_chips), lo, hi)


# ---------------------------------------------------------------------------
# Fusion algebra (paper Eq. 3)
# ---------------------------------------------------------------------------


def merge_fault_maps(maps: Sequence[FaultMap]) -> FaultMap:
    if not maps:
        raise ValueError("no fault maps to merge")
    out = maps[0]
    for m in maps[1:]:
        out = out.merge(m)
    return out


def expected_merged_rate(pr_a: float, pr_b: float, pr_ab: Optional[float] = None) -> float:
    """Eq. 3: Pr_comb = Pr_A + Pr_B - Pr_{A AND B}; independent maps give
    Pr_{A AND B} = Pr_A * Pr_B."""
    if pr_ab is None:
        pr_ab = pr_a * pr_b
    return pr_a + pr_b - pr_ab


def overlap_rate(a: FaultMap, b: FaultMap) -> float:
    """Measured Pr_{A AND B}: fraction of PEs faulty in both maps."""
    return float((a.faulty & b.faulty).mean())
