"""Fault-context plumbing: how a chip's fault map reaches every matmul.

Model layers never materialize full-weight masks; they call
``fault_linear(x, w, ctx)`` which applies the periodic systolic mask
on the fly (or via the fused Pallas kernel on TPU). ``FaultContext`` is a
pytree so it can be passed through jit/pjit boundaries; the (R, C) healthy
mask is a tiny replicated constant.

Modes
-----
none    : healthy chip — plain matmul, zero overhead.
fap     : Fault-Aware Pruning semantics — weights on faulty PEs are zeroed
          in the forward pass; gradients are masked automatically by the
          chain rule (= FAP+T when training).
pallas  : same semantics, mask fused into the Pallas masked-matmul kernel
          (TPU target; falls back to 'fap' math on CPU backends).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.faults import FaultMap
from repro.core.mapping import masked_weight

__all__ = [
    "FaultContext",
    "fault_linear",
    "fault_einsum",
    "healthy",
    "from_fault_map",
    "stack_contexts",
    "context_leak_reason",
]


@jax.tree_util.register_pytree_node_class
@dataclass
class FaultContext:
    """Carries the chip's healthy mask (1=healthy PE, 0=faulty) + mode.

    ``ok`` is normally the single chip's (R, C) mask. A *batched* context
    (built with :func:`stack_contexts`) carries an (N, R, C) stack of N
    chips' masks behind the same static ``mode``; it flows through jit
    boundaries like any pytree but must be consumed under ``jax.vmap`` so
    each traced member sees an ordinary (R, C) mask.
    """

    ok: Optional[jax.Array]  # (R, C) float mask, (N, R, C) stack, or None
    mode: str = "none"  # none | fap | pallas

    def tree_flatten(self):
        return (self.ok,), self.mode

    @classmethod
    def tree_unflatten(cls, mode, children):
        return cls(ok=children[0], mode=mode)

    @property
    def active(self) -> bool:
        return self.mode != "none" and self.ok is not None

    @property
    def population(self) -> Optional[int]:
        """Number of stacked members, or None for a per-chip context."""
        if self.ok is None or self.ok.ndim == 2:
            return None
        return int(self.ok.shape[0])


def healthy() -> FaultContext:
    return FaultContext(ok=None, mode="none")


def from_fault_map(
    fm: Optional[FaultMap], mode: str = "fap", dtype=jnp.float32
) -> FaultContext:
    if fm is None:
        return healthy()
    return FaultContext(ok=jnp.asarray(fm.ok_mask, dtype=dtype), mode=mode)


def stack_contexts(ctxs: Sequence[FaultContext]) -> FaultContext:
    """Stack N per-chip contexts into one batched context.

    The result carries a leading population axis on ``ok`` and the members'
    shared static mode. Healthy members are upcast to an all-ones mask (FAP
    with no faulty PE is exactly the healthy matmul), so a population can mix
    healthy and faulty chips; an all-healthy stack collapses to ``healthy()``.
    """
    if len(ctxs) == 0:
        raise ValueError(
            "stack_contexts: empty population — need at least one FaultContext "
            "(a single-member sequence is fine and stacks to population=1)"
        )
    active = [c for c in ctxs if c.active]
    if not active:
        return healthy()
    modes = {c.mode for c in active}
    if len(modes) != 1:
        raise ValueError(f"cannot stack contexts with mixed modes {sorted(modes)}")
    if any(c.ok.ndim != 2 for c in active):
        raise ValueError("stack_contexts takes per-chip (R, C) contexts, not batched ones")
    shapes = {tuple(c.ok.shape) for c in active}
    if len(shapes) != 1:
        raise ValueError(f"cannot stack contexts with mixed mask shapes {sorted(shapes)}")
    shape, dtype = shapes.pop(), active[0].ok.dtype
    oks = [c.ok if c.active else jnp.ones(shape, dtype) for c in ctxs]
    return FaultContext(ok=jnp.stack(oks), mode=modes.pop())


def context_leak_reason(ctx: Optional[FaultContext]) -> Optional[str]:
    """Static form of the batched-context guard: the reason a context would
    be rejected by the masked-GEMM entry points, or None when it is safe.

    Works on abstract contexts too (``ok`` may be a ShapeDtypeStruct), so
    the program linter (``repro.analysis``) can check an entry point's
    traced signature without executing it; the runtime guard
    ``_require_per_chip`` raises on exactly the same condition.
    """
    if ctx is None or not ctx.active:
        return None
    if ctx.population is not None:
        return (
            f"batched FaultContext (population={ctx.population}) reached a "
            "masked GEMM; consume it under jax.vmap so each member sees an "
            "(R, C) mask"
        )
    if getattr(ctx.ok, "ndim", 2) != 2:
        return f"FaultContext.ok must be (R, C) or (N, R, C), got ndim={ctx.ok.ndim}"
    return None


def _require_per_chip(ctx: FaultContext) -> None:
    reason = context_leak_reason(ctx)
    if reason is not None:
        raise ValueError(reason + " (e.g. via PopulationFATEngine)")


# ---------------------------------------------------------------------------
# The masked-GEMM entry points used by every model layer
# ---------------------------------------------------------------------------


def fault_linear(
    x: jax.Array,
    w: jax.Array,
    ctx: Optional[FaultContext] = None,
    *,
    precision=None,
) -> jax.Array:
    """y = x @ mask(w). ``w`` is (..., d_in, d_out); contraction over -1 of x.

    In 'pallas' mode on a TPU backend the fused kernel is used; everywhere
    else the mask is applied with XLA ops (the paper-faithful formulation).
    Weights are cast to the activation dtype (bf16 compute, fp32 master).
    """
    w = w.astype(x.dtype)
    if ctx is None or not ctx.active:
        return jnp.matmul(x, w, precision=precision)
    _require_per_chip(ctx)
    if ctx.mode == "pallas" and jax.default_backend() == "tpu":
        from repro.kernels.masked_matmul import ops as mm_ops

        return mm_ops.masked_matmul(x, w, ctx.ok)
    return jnp.matmul(x, masked_weight(w, ctx.ok), precision=precision)


def fault_einsum(
    spec: str,
    x: jax.Array,
    w: jax.Array,
    ctx: Optional[FaultContext] = None,
    *,
    precision=None,
) -> jax.Array:
    """Masked einsum for weights whose GEMM view is the last two dims of w
    (e.g. MoE experts '(e,d,f)' — every expert GEMM runs on the same chip,
    hence the same periodic mask)."""
    w = w.astype(x.dtype)
    if ctx is None or not ctx.active:
        return jnp.einsum(spec, x, w, precision=precision)
    _require_per_chip(ctx)
    return jnp.einsum(spec, x, masked_weight(w, ctx.ok), precision=precision)


# ---------------------------------------------------------------------------
# Pytree-level helpers
# ---------------------------------------------------------------------------

# Param leaves that flow through fault_linear/fault_einsum (i.e. execute as
# GEMMs on the systolic array). Embedding lookups, depthwise convs, SSM
# A/D tensors and 1-D scales are NOT array-mapped and must not be masked.
MASKABLE_KEYS = frozenset(
    {
        "wq", "wk", "wv", "wo",  # attention projections
        "wg", "wu", "wd", "wi",  # MLP / expert FFNs
        "router",
        "in_proj", "x_proj", "dt_w", "out_proj",  # SSM GEMMs
        "frontend", "lm_head",
    }
)


def mask_selected_params(params: Any, ctx: FaultContext) -> Any:
    """Apply the FAP mask ONCE to every array-mapped weight leaf.

    Because masking is linear and idempotent, pre-masking the params and
    running the model with a healthy context is mathematically identical to
    masking inside every matmul (the paper-faithful formulation) — but it
    touches each weight once per step instead of once per use per
    microbatch. Tied embeddings are intentionally excluded: the lookup must
    see unmasked rows; the tied unembed GEMM keeps its use-site mask.
    """
    if not ctx.active:
        return params
    _require_per_chip(ctx)

    def f(path, leaf):
        keys = {getattr(k, "key", None) for k in path}
        if keys & MASKABLE_KEYS and hasattr(leaf, "ndim") and leaf.ndim >= 2:
            return masked_weight(leaf, ctx.ok.astype(leaf.dtype))
        return leaf

    return jax.tree_util.tree_map_with_path(f, params)


def mask_params(params: Any, ctx: FaultContext, is_mapped=None) -> Any:
    """Apply FAP masks to every array-mapped leaf of a param pytree.

    ``is_mapped(path, leaf) -> bool`` decides which leaves map onto the
    array; default: every float leaf with ndim >= 2.
    """
    if not ctx.active:
        return params
    _require_per_chip(ctx)

    def default_is_mapped(path, leaf):
        return hasattr(leaf, "ndim") and leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating)

    pred = is_mapped or default_is_mapped

    def f(path, leaf):
        if pred(path, leaf):
            return masked_weight(leaf, ctx.ok.astype(leaf.dtype))
        return leaf

    return jax.tree_util.tree_map_with_path(f, params)
