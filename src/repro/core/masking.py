"""Fault-context plumbing: how a chip's fault map reaches every matmul.

Model layers never materialize full-weight masks; they call
``fault_linear(x, w, ctx)`` which applies the periodic systolic mask
on the fly (or via the fused Pallas kernel on TPU). ``FaultContext`` is a
pytree so it can be passed through jit/pjit boundaries; the (R, C) healthy
mask is a tiny replicated constant.

Modes
-----
none    : healthy chip — plain matmul, zero overhead.
fap     : Fault-Aware Pruning semantics — weights on faulty PEs are zeroed
          in the forward pass; gradients are masked automatically by the
          chain rule (= FAP+T when training).
pallas  : same semantics, mask fused into the Pallas masked-matmul kernel
          (TPU target; falls back to 'fap' math on CPU backends).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import FaultMap
from repro.core.mapping import masked_weight

__all__ = ["FaultContext", "fault_linear", "fault_einsum", "healthy", "from_fault_map"]


@jax.tree_util.register_pytree_node_class
@dataclass
class FaultContext:
    """Carries the chip's healthy mask (1=healthy PE, 0=faulty) + mode."""

    ok: Optional[jax.Array]  # (R, C) float mask or None
    mode: str = "none"  # none | fap | pallas

    def tree_flatten(self):
        return (self.ok,), self.mode

    @classmethod
    def tree_unflatten(cls, mode, children):
        return cls(ok=children[0], mode=mode)

    @property
    def active(self) -> bool:
        return self.mode != "none" and self.ok is not None


def healthy() -> FaultContext:
    return FaultContext(ok=None, mode="none")


def from_fault_map(
    fm: Optional[FaultMap], mode: str = "fap", dtype=jnp.float32
) -> FaultContext:
    if fm is None:
        return healthy()
    return FaultContext(ok=jnp.asarray(fm.ok_mask, dtype=dtype), mode=mode)


# ---------------------------------------------------------------------------
# The masked-GEMM entry points used by every model layer
# ---------------------------------------------------------------------------


def fault_linear(
    x: jax.Array,
    w: jax.Array,
    ctx: Optional[FaultContext] = None,
    *,
    precision=None,
) -> jax.Array:
    """y = x @ mask(w). ``w`` is (..., d_in, d_out); contraction over -1 of x.

    In 'pallas' mode on a TPU backend the fused kernel is used; everywhere
    else the mask is applied with XLA ops (the paper-faithful formulation).
    Weights are cast to the activation dtype (bf16 compute, fp32 master).
    """
    w = w.astype(x.dtype)
    if ctx is None or not ctx.active:
        return jnp.matmul(x, w, precision=precision)
    if ctx.mode == "pallas" and jax.default_backend() == "tpu":
        from repro.kernels.masked_matmul import ops as mm_ops

        return mm_ops.masked_matmul(x, w, ctx.ok)
    return jnp.matmul(x, masked_weight(w, ctx.ok), precision=precision)


def fault_einsum(
    spec: str,
    x: jax.Array,
    w: jax.Array,
    ctx: Optional[FaultContext] = None,
    *,
    precision=None,
) -> jax.Array:
    """Masked einsum for weights whose GEMM view is the last two dims of w
    (e.g. MoE experts '(e,d,f)' — every expert GEMM runs on the same chip,
    hence the same periodic mask)."""
    w = w.astype(x.dtype)
    if ctx is None or not ctx.active:
        return jnp.einsum(spec, x, w, precision=precision)
    return jnp.einsum(spec, x, masked_weight(w, ctx.ok), precision=precision)


# ---------------------------------------------------------------------------
# Pytree-level helpers
# ---------------------------------------------------------------------------

# Param leaves that flow through fault_linear/fault_einsum (i.e. execute as
# GEMMs on the systolic array). Embedding lookups, depthwise convs, SSM
# A/D tensors and 1-D scales are NOT array-mapped and must not be masked.
MASKABLE_KEYS = frozenset(
    {
        "wq", "wk", "wv", "wo",  # attention projections
        "wg", "wu", "wd", "wi",  # MLP / expert FFNs
        "router",
        "in_proj", "x_proj", "dt_w", "out_proj",  # SSM GEMMs
        "frontend", "lm_head",
    }
)


def mask_selected_params(params: Any, ctx: FaultContext) -> Any:
    """Apply the FAP mask ONCE to every array-mapped weight leaf.

    Because masking is linear and idempotent, pre-masking the params and
    running the model with a healthy context is mathematically identical to
    masking inside every matmul (the paper-faithful formulation) — but it
    touches each weight once per step instead of once per use per
    microbatch. Tied embeddings are intentionally excluded: the lookup must
    see unmasked rows; the tied unembed GEMM keeps its use-site mask.
    """
    if not ctx.active:
        return params

    def f(path, leaf):
        keys = {getattr(k, "key", None) for k in path}
        if keys & MASKABLE_KEYS and hasattr(leaf, "ndim") and leaf.ndim >= 2:
            return masked_weight(leaf, ctx.ok.astype(leaf.dtype))
        return leaf

    return jax.tree_util.tree_map_with_path(f, params)


def mask_params(params: Any, ctx: FaultContext, is_mapped=None) -> Any:
    """Apply FAP masks to every array-mapped leaf of a param pytree.

    ``is_mapped(path, leaf) -> bool`` decides which leaves map onto the
    array; default: every float leaf with ndim >= 2.
    """
    if not ctx.active:
        return params

    def default_is_mapped(path, leaf):
        return hasattr(leaf, "ndim") and leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating)

    pred = is_mapped or default_is_mapped

    def f(path, leaf):
        if pred(path, leaf):
            return masked_weight(leaf, ctx.ok.astype(leaf.dtype))
        return leaf

    return jax.tree_util.tree_map_with_path(f, params)
