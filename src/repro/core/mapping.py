"""Weight-stationary systolic mapping (paper SII-B, Fig. 6).

A GEMM weight ``W[d_in, d_out]`` executes on an (R, C) array as
ceil(d_in/R) x ceil(d_out/C) stationary tile loads; PE (r, c) hosts
``W[i*R + r, j*C + c]`` for every tile (i, j). A bypassed (faulty) PE zeroes
its weight, so the effective mask on W is the fault map's healthy-mask tiled
periodically:  mask_W[a, b] = ok[a % R, b % C].

Also provides the FAM (SalvageDNN [12]) saliency-driven column-permutation
baseline: mitigation without retraining.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import FaultMap

__all__ = [
    "periodic_mask",
    "masked_weight",
    "fam_permutation",
    "apply_fam",
    "expected_weight_loss",
]


def periodic_mask(
    weight_shape: tuple[int, ...],
    ok: jax.Array | np.ndarray,
    dtype=jnp.float32,
) -> jax.Array:
    """Expand the (R, C) healthy mask to a weight's shape.

    The LAST TWO dims of the weight are the GEMM (d_in, d_out) view; leading
    dims (e.g. experts, layers) replicate the same chip mask — every tile of
    every GEMM executes on the same physical array.
    """
    ok = jnp.asarray(ok, dtype=dtype)
    r_, c_ = ok.shape
    d_in, d_out = weight_shape[-2], weight_shape[-1]
    if d_in % r_ == 0 and d_out % c_ == 0:
        m = jnp.tile(ok, (d_in // r_, d_out // c_))
    else:
        rows = jax.lax.broadcasted_iota(jnp.int32, (d_in, d_out), 0) % r_
        cols = jax.lax.broadcasted_iota(jnp.int32, (d_in, d_out), 1) % c_
        m = ok[rows, cols]
    return jnp.broadcast_to(m, weight_shape)


def masked_weight(w: jax.Array, ok: Optional[jax.Array]) -> jax.Array:
    """FAP: zero the weights mapped onto faulty PEs."""
    if ok is None:
        return w
    return w * periodic_mask(w.shape, ok, dtype=w.dtype)


# ---------------------------------------------------------------------------
# FAM baseline (SalvageDNN [12]) — saliency-driven fault-aware mapping
# ---------------------------------------------------------------------------


_EXACT_ASSIGNMENT_MAX_DOUT = 2048  # Hungarian is O(d_out^3)


def _greedy_perm(saliency: np.ndarray, slot_badness: np.ndarray) -> np.ndarray:
    """Rearrangement-inequality pairing: least-salient logical columns into
    the worst slots — the exact minimizer of the separable proxy cost
    ``sum(saliency[j] * badness[perm[j]])``, so it never exceeds the
    identity (FAP) placement on that proxy."""
    d_out = len(saliency)
    slots_by_badness = np.argsort(-slot_badness, kind="stable")  # worst first
    logical_by_saliency = np.argsort(saliency, kind="stable")  # least salient first
    perm = np.empty(d_out, dtype=np.int64)
    perm[logical_by_saliency] = slots_by_badness
    return perm


def fam_permutation(w: np.ndarray, fm: FaultMap) -> np.ndarray:
    """Choose an output-column permutation mapping salient weight columns
    away from faulty array columns.

    Column j of W executes on array column ``j % C``; permuting output
    columns (filters/neurons) re-routes them. The cost of placing logical
    column j in slot s is the saliency mass actually zeroed there —
    ``sum(|W[a, j]|  for GEMM rows a with faulty[a % R, s % C])`` — which
    depends on *which rows* of the physical column are bypassed, not only
    on how many (leading dims, e.g. experts, replicate the same mask per
    GEMM, matching ``periodic_mask``). The assignment minimizing total
    zeroed mass is solved exactly (Hungarian); the identity (= plain FAP
    placement) is always a feasible assignment, so FAM never bypasses more
    saliency mass than FAP. Very wide layers (Hungarian is O(d_out^3)) use
    the greedy saliency/fault-count pairing, which carries the same
    never-worse-than-FAP guarantee on its separable proxy cost.

    Returns ``perm`` with semantics: logical output j is computed in
    physical slot ``perm[j]``.
    """
    # scipy is a hard dependency of jax itself, so it is always importable
    # in any environment that can run this repo; a missing scipy should
    # fail loudly here, not silently degrade the mitigation quality.
    from scipy.optimize import linear_sum_assignment

    d_in, d_out = w.shape[-2], w.shape[-1]
    rows, cols = fm.shape
    w2 = np.abs(np.asarray(w, dtype=np.float64).reshape(-1, d_out))
    if d_out > _EXACT_ASSIGNMENT_MAX_DOUT:
        col_faults = fm.faulty.sum(axis=0).astype(np.float64)  # (C,)
        return _greedy_perm(w2.sum(axis=0), col_faults[np.arange(d_out) % cols])
    # fold the R-periodic rows first: mask row of flattened row a is its
    # index WITHIN its GEMM, mod R — leading dims see the same periodic
    # mask (periodic_mask broadcasts) — then damage[j, c] is the saliency
    # mass of logical column j zeroed when it runs on physical column c
    row_idx = np.tile(np.arange(d_in) % rows, w2.shape[0] // d_in)
    folded = np.zeros((rows, d_out))
    np.add.at(folded, row_idx, w2)
    damage = folded.T @ fm.faulty.astype(np.float64)  # (d_out, C)
    cost = damage[:, np.arange(d_out) % cols].astype(np.float32)  # (d_out, slots)
    logical, slots = linear_sum_assignment(cost)
    perm = np.empty(d_out, dtype=np.int64)
    perm[logical] = slots
    return perm


def apply_fam(
    w: jax.Array, ok: jax.Array, perm: np.ndarray | jax.Array
) -> jax.Array:
    """Effective FAM weight: permute columns into slots, mask, un-permute.

    out[:, j] = (W[:, j] placed in slot perm[j], masked there)
    """
    perm = jnp.asarray(perm)
    w_slots = jnp.zeros_like(w).at[..., perm].set(w)  # slot s holds logical perm^-1(s)
    w_slots = masked_weight(w_slots, ok)
    return w_slots[..., perm]  # back to logical order


def expected_weight_loss(weight_shape: tuple[int, int], fm: FaultMap) -> float:
    """Fraction of weight entries zeroed by FAP for this (shape, map)."""
    d_in, d_out = weight_shape
    reps_r = np.bincount(np.arange(d_in) % fm.shape[0], minlength=fm.shape[0])
    reps_c = np.bincount(np.arange(d_out) % fm.shape[1], minlength=fm.shape[1])
    hits = reps_r @ fm.faulty.astype(np.int64) @ reps_c
    return float(hits) / float(d_in * d_out)
