"""The eFAT orchestrator — Steps 1-4 of paper Fig. 7, end to end.

Inputs: a pre-trained model + training data (wrapped in a FATTrainer), a
user-defined accuracy constraint, and the fleet's fault maps.
Output: a RetrainingPlan, the fault-aware weights per retraining job, and
per-chip evaluation — plus the same pipeline run under baseline policies
for comparison (paper SIV-C).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional, Protocol, Sequence

import numpy as np

from repro.core.faults import FaultMap
from repro.core.grouping import (
    RetrainingPlan,
    fixed_policy_plan,
    group_and_fuse,
    individual_plan,
    random_pair_merge_plan,
)
from repro.core.resilience import (
    ResilienceTable,
    fault_rate_list,
    measure_resilience,
)

__all__ = ["EFATConfig", "EFATResult", "EFAT", "FATTrainerFull", "BatchFATTrainerFull"]


class FATTrainerFull(Protocol):
    """Full trainer protocol: resilience probing + consolidated FAT + eval."""

    def steps_to_constraint(
        self, fault_map: FaultMap, constraint: float, max_steps: int
    ) -> Optional[int]: ...

    def train(self, fault_map: FaultMap, steps: int) -> Any:
        """Run FAT for ``steps`` with this (possibly fused) map; return the
        shipped fault-aware params (already FAP-masked)."""
        ...

    def evaluate(self, params: Any, fault_map: FaultMap) -> float:
        """Deployed metric of params on a chip with this fault map."""
        ...


class BatchFATTrainerFull(FATTrainerFull, Protocol):
    """Batch extension of the full protocol (repro.train.population): a
    trainer that can run every retraining job of a plan as one population
    and evaluate a batch of (params, chip) pairs in one vmapped program.
    ``execute_plan`` uses these when present; the single-map methods remain
    the serial fallback."""

    def steps_to_constraint_batch(
        self, fault_maps: Sequence[FaultMap], constraint: float, max_steps: int
    ) -> list[Optional[int]]: ...

    def train_batch(
        self, fault_maps: Sequence[FaultMap], steps: Sequence[int]
    ) -> list[Any]: ...

    def evaluate_batch(
        self, params_list: Sequence[Any], fault_maps: Sequence[FaultMap]
    ) -> list[float]: ...


@dataclass
class EFATConfig:
    constraint: float
    # Algo 1
    max_fr: float = 0.3
    max_interval: float = 0.05
    step_ratio: float = 0.5
    # Step 1 measurement
    repeats: int = 5
    max_steps: int = 2000
    seed: int = 0
    # Algo 2
    m_comparisons: int = 8
    k_iterations: int = 2
    stat: str = "max"  # paper recommends max bounds (Fig. 12)


@dataclass
class EFATResult:
    plan: RetrainingPlan
    table: Optional[ResilienceTable]
    chip_metrics: dict[int, float]  # chip index -> deployed metric
    constraint: float
    wall_seconds: float = 0.0
    # repro.fleet.FleetScheduler.report for the executed plan's job budgets
    # (None when the trainer has no scheduler): how the jobs were packed into
    # population chunks and the wasted vectorized lane-steps vs arrival order
    scheduling: Optional[dict] = None

    @property
    def satisfied_fraction(self) -> float:
        if not self.chip_metrics:
            return 0.0
        ok = sum(1 for v in self.chip_metrics.values() if v >= self.constraint)
        return ok / len(self.chip_metrics)

    @property
    def total_retraining_steps(self) -> float:
        return self.plan.total_steps

    def summary(self) -> dict:
        s = self.plan.summary()
        s.update(
            satisfied_fraction=self.satisfied_fraction,
            constraint=self.constraint,
            mean_metric=float(np.mean(list(self.chip_metrics.values()))) if self.chip_metrics else 0.0,
            wall_seconds=self.wall_seconds,
        )
        if self.scheduling is not None:
            s["wasted_steps"] = self.scheduling["wasted_steps"]
            s["wasted_steps_reduction"] = self.scheduling["wasted_steps_reduction"]
        return s


class EFAT:
    """End-to-end framework: resilience map -> amounts -> grouping -> FAT."""

    def __init__(self, trainer: FATTrainerFull, config: EFATConfig):
        self.trainer = trainer
        self.config = config
        self.table: Optional[ResilienceTable] = None

    # -- Step 1 ----------------------------------------------------------
    def build_resilience_table(
        self,
        fault_maps: Sequence[FaultMap],
        progress: Optional[Callable[[str], None]] = None,
        cache_path: Optional[str] = None,
    ) -> ResilienceTable:
        """Measure (or load) the Step-1 resilience table.

        ``cache_path``: JSON file reused across runs. A cached table is
        only accepted when its recorded measurement config (rates,
        constraint, repeats, cap, array shape, seed) matches this run's —
        otherwise it is re-measured and the file rewritten.
        """
        cfg = self.config
        rates = fault_rate_list(
            [fm.fault_rate for fm in fault_maps],
            max_fr=cfg.max_fr,
            max_interval=cfg.max_interval,
            step=cfg.step_ratio,
        )
        array_shape = fault_maps[0].shape
        config_key = dict(
            rates=[float(r) for r in rates],
            constraint=float(cfg.constraint),
            repeats=int(cfg.repeats),
            max_steps=int(cfg.max_steps),
            seed=int(cfg.seed),
            array_shape=[int(s) for s in array_shape],
        )
        if cache_path is not None and os.path.exists(cache_path):
            try:
                with open(cache_path) as f:
                    cached = ResilienceTable.from_json(f.read())
            except (ValueError, KeyError, OSError):
                cached = None  # corrupt/truncated cache -> re-measure
            if cached is not None and cached.meta.get("config") == config_key:
                if progress:
                    progress(f"resilience table loaded from {cache_path}")
                self.table = cached
                return cached
        self.table = measure_resilience(
            self.trainer,
            rates,
            cfg.constraint,
            array_shape=array_shape,
            repeats=cfg.repeats,
            max_steps=cfg.max_steps,
            seed=cfg.seed,
            progress=progress,
        )
        self.table.meta["config"] = config_key
        if cache_path is not None:
            # atomic replace: a killed run must not leave half a JSON doc
            tmp = cache_path + ".tmp"
            with open(tmp, "w") as f:
                f.write(self.table.to_json())
            os.replace(tmp, cache_path)
        return self.table

    # -- Steps 2+3 ---------------------------------------------------------
    def make_plan(self, fault_maps: Sequence[FaultMap]) -> RetrainingPlan:
        assert self.table is not None, "run build_resilience_table first"
        return group_and_fuse(
            fault_maps,
            self.table,
            m_comparisons=self.config.m_comparisons,
            k_iterations=self.config.k_iterations,
            stat=self.config.stat,
            seed=self.config.seed,
        )

    # -- Step 4 ------------------------------------------------------------
    def execute_plan(
        self,
        plan: RetrainingPlan,
        fault_maps: Sequence[FaultMap],
        progress: Optional[Callable[[str], None]] = None,
    ) -> EFATResult:
        """Run consolidated FAT per job; evaluate each chip with its own map
        applied on top of the shipped (FAP-masked) weights.

        With a batch-capable trainer every retraining job of the plan is
        trained as ONE population (packed into chunks by the trainer's
        FleetScheduler — see ``result.scheduling`` for the waste accounting)
        and all per-chip deployments are evaluated as one vmapped batch;
        otherwise the serial per-job loop runs (same math — the population
        engine is proven equivalent)."""
        t0 = time.time()
        chip_metrics: dict[int, float] = {}
        job_steps = [int(round(s)) for s in plan.steps]
        scheduler = getattr(self.trainer, "scheduler", None)
        scheduling = scheduler.report(job_steps) if scheduler is not None else None
        if hasattr(self.trainer, "train_batch") and hasattr(self.trainer, "evaluate_batch"):
            job_params = self.trainer.train_batch(plan.fault_maps, job_steps)
            pairs = [
                (g, chip) for g, chips in enumerate(plan.links) for chip in chips
            ]
            metrics = self.trainer.evaluate_batch(
                [job_params[g] for g, _ in pairs],
                [fault_maps[chip] for _, chip in pairs],
            )
            for (_, chip), m in zip(pairs, metrics):
                chip_metrics[chip] = float(m)
        else:
            for g, (fm, chips, steps) in enumerate(
                zip(plan.fault_maps, plan.links, job_steps)
            ):
                params = self.trainer.train(fm, steps)
                for chip in chips:
                    chip_metrics[chip] = float(
                        self.trainer.evaluate(params, fault_maps[chip])
                    )
        if progress:
            for g, chips in enumerate(plan.links):
                progress(
                    f"job {g + 1}/{plan.num_jobs}: chips={chips} "
                    f"steps={plan.steps[g]:.0f} "
                    f"metrics={[f'{chip_metrics[c]:.3f}' for c in chips]}"
                )
        return EFATResult(
            plan=plan,
            table=self.table,
            chip_metrics=chip_metrics,
            constraint=self.config.constraint,
            wall_seconds=time.time() - t0,
            scheduling=scheduling,
        )

    # -- convenience: full pipeline + baselines ------------------------------
    def run(
        self,
        fault_maps: Sequence[FaultMap],
        progress: Optional[Callable[[str], None]] = None,
    ) -> EFATResult:
        if self.table is None:
            self.build_resilience_table(fault_maps, progress=progress)
        plan = self.make_plan(fault_maps)
        return self.execute_plan(plan, fault_maps, progress=progress)

    def run_baseline(
        self,
        fault_maps: Sequence[FaultMap],
        method: str,
        *,
        steps_per_chip: Optional[float] = None,
        progress: Optional[Callable[[str], None]] = None,
    ) -> EFATResult:
        """Baselines of paper SIV-C: 'fixed' ([8]), 'random-merge' ([16]),
        'individual' (eFAT without Step 3)."""
        if method == "fixed":
            assert steps_per_chip is not None
            plan = fixed_policy_plan(fault_maps, steps_per_chip)
        elif method == "random-merge":
            plan = random_pair_merge_plan(
                fault_maps,
                table=self.table if steps_per_chip is None else None,
                steps_per_job=steps_per_chip,
                stat=self.config.stat,
                seed=self.config.seed,
            )
        elif method == "individual":
            assert self.table is not None
            plan = individual_plan(fault_maps, self.table, stat=self.config.stat)
        else:
            raise ValueError(f"unknown baseline {method!r}")
        return self.execute_plan(plan, fault_maps, progress=progress)
