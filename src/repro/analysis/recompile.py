"""Recompile-hazard census (RCP001/RCP002) over analytic trace signatures.

``jax.jit`` retraces (and XLA recompiles) whenever an argument's shape,
dtype or a static argument changes. For a serving stack the dangerous case
is a *request-dependent* signature: a prefill traced at the raw prompt
length compiles once per distinct prompt length in the traffic — unbounded
compile volume (ROADMAP item 1 names this as the next traffic risk).

Executing every entry point over a traffic sweep just to count compiles is
exactly what a static lint must avoid, so each entry point declares its
**signature function**: the tuple of shape/static values its jit boundary
actually keys on, as a pure function of a :class:`TraceRequest`. Those
functions are small and auditable (they mirror the jit signatures in
``serve/engine.py``, ``serve/continuous.py``, ``train/step.py``), and the
golden tests pin them against real ``jitted._cache_size()`` counts.

Two findings:

* RCP001 — *unbounded* hazard: sweeping one request dimension produces a
  distinct signature per value (injective growth), i.e. real traffic keeps
  compiling forever.
* RCP002 — the given synthetic trace alone already induces more distinct
  signatures than ``max_signatures``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Sequence

from repro.analysis.findings import Finding

__all__ = [
    "TraceRequest",
    "EntryTraceModel",
    "synthetic_trace",
    "census",
    "lint_recompile",
]

# Request dimensions a signature may legally depend on in *bounded* ways
# (e.g. through a page-rounded, capacity-clamped cache length).
SWEEP_DIMS = ("prompt_len", "max_new_tokens", "batch")


@dataclass(frozen=True)
class TraceRequest:
    """One request of the synthetic traffic trace."""

    prompt_len: int
    max_new_tokens: int = 32
    batch: int = 1


@dataclass(frozen=True)
class EntryTraceModel:
    """An entry point's analytic jit signature.

    ``signature_of(req)`` returns the hashable tuple the jit cache keys on
    for that request — argument shapes that vary with the request plus any
    static argnums/argnames. Dimensions the entry point never sees can be
    excluded from the sweep via ``dims``.
    """

    name: str
    signature_of: Callable[[TraceRequest], tuple]
    dims: tuple = SWEEP_DIMS


def synthetic_trace(
    *,
    prompt_lens: Sequence[int] = (7, 12, 17, 33, 52, 64, 99, 128, 200, 311),
    max_new: Sequence[int] = (8, 16, 32, 64),
    batch: int = 1,
) -> list:
    """A deterministic mixed-length traffic trace (no RNG — resumable)."""
    out = []
    for i, p in enumerate(prompt_lens):
        out.append(
            TraceRequest(
                prompt_len=int(p),
                max_new_tokens=int(max_new[i % len(max_new)]),
                batch=batch,
            )
        )
    return out


def census(model: EntryTraceModel, trace: Sequence[TraceRequest]) -> dict:
    """Distinct signatures the trace induces on one entry point."""
    sigs = {model.signature_of(r) for r in trace}
    return dict(requests=len(trace), signatures=len(sigs))


def _sweep_values(lo: int = 1, n: int = 12) -> list:
    # strictly increasing, mixed parity/alignment so page rounding and
    # bucketing genuinely collapse values when the signature is bounded
    vals = []
    v = lo
    for i in range(n):
        vals.append(v)
        v += 3 + (i % 5)
    return vals


def lint_recompile(
    models: Sequence[EntryTraceModel],
    trace: Sequence[TraceRequest],
    *,
    max_signatures: int = 8,
    base: TraceRequest = TraceRequest(prompt_len=16, max_new_tokens=32, batch=1),
) -> tuple[list, dict]:
    """Returns (findings, stats). RCP001 per unbounded request dimension;
    RCP002 when the concrete trace exceeds the signature budget."""
    findings: list = []
    stats: dict = {}
    for model in models:
        entry: dict = {}
        for dim in model.dims:
            values = _sweep_values()
            sigs = {
                model.signature_of(replace(base, **{dim: v})) for v in values
            }
            entry[f"sweep_{dim}"] = len(sigs)
            if len(sigs) == len(values):
                findings.append(
                    Finding(
                        code="RCP001",
                        entry_point=model.name,
                        subject=dim,
                        message=(
                            f"trace signature varies injectively with {dim} "
                            f"({len(sigs)} signatures over {len(values)} swept "
                            "values): every distinct value recompiles — bucket "
                            f"{dim} (pad to a fixed set of shapes) at this jit "
                            "boundary"
                        ),
                        severity="error",
                    )
                )
        c = census(model, trace)
        entry.update(c)
        if c["signatures"] > max_signatures:
            findings.append(
                Finding(
                    code="RCP002",
                    entry_point=model.name,
                    subject="trace",
                    message=(
                        f"synthetic trace of {c['requests']} requests induces "
                        f"{c['signatures']} distinct trace signatures "
                        f"(budget {max_signatures}) — compile volume scales "
                        "with traffic shape diversity"
                    ),
                    severity="warn",
                )
            )
        stats[model.name] = entry
    return findings, stats
