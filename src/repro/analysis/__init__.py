"""repro.analysis — a static program linter for the serve/train/fleet stack.

Four passes certify the stack's jitted entry points without executing them
(see README.md here and the pass modules' docstrings):

* :mod:`~repro.analysis.donation` — DON001, loop-carried buffers that are
  not donated, read off the optimized HLO ``input_output_alias`` table;
* :mod:`~repro.analysis.recompile` — RCP001/RCP002, jit signatures that
  grow unboundedly with request traffic;
* :mod:`~repro.analysis.shardlint` — SHD001/SHD002, silent replication
  fallbacks and engine-owned-axis violations in the sharding rules;
* :mod:`~repro.analysis.kernelgeom` — KRN001–KRN004, Pallas launch
  geometry (block divisibility, grid bounds, analytic VMEM, context leaks).

``analyze_stack`` runs all four over the registry in
:mod:`~repro.analysis.programs` and returns a :class:`Report`; the CLI is
``python -m repro.launch.analyze`` with a committed ``baseline.json`` so CI
fails on NEW findings only.
"""
from __future__ import annotations

import os

from repro.analysis.donation import ProgramSpec, donation_stats, lint_donation
from repro.analysis.findings import Finding, Report, load_baseline
from repro.analysis.kernelgeom import (
    KernelLaunch,
    check_launch,
    lint_kernels,
)
from repro.analysis.programs import StackPrograms, build_stack
from repro.analysis.recompile import (
    EntryTraceModel,
    TraceRequest,
    lint_recompile,
    synthetic_trace,
)
from repro.analysis.shardlint import FakeMesh, ShardingEntry, lint_sharding

__all__ = [
    "Finding",
    "Report",
    "load_baseline",
    "ProgramSpec",
    "lint_donation",
    "donation_stats",
    "EntryTraceModel",
    "TraceRequest",
    "synthetic_trace",
    "lint_recompile",
    "FakeMesh",
    "ShardingEntry",
    "lint_sharding",
    "KernelLaunch",
    "check_launch",
    "lint_kernels",
    "StackPrograms",
    "build_stack",
    "analyze_stack",
    "default_baseline_path",
]


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(__file__), "baseline.json")


def analyze_stack(
    arch: str = "smollm-135m",
    *,
    programs: StackPrograms = None,
    min_bytes: int = 1 << 14,
    shard_min_bytes: int = 1 << 20,
    max_signatures: int = 8,
    passes: tuple = ("donation", "recompile", "sharding", "kernels"),
) -> Report:
    """Run the linter passes over one arch's stack; returns a :class:`Report`.

    ``min_bytes`` gates DON001 (per-leaf); ``shard_min_bytes`` gates SHD001.
    ``passes`` selects a subset (the donation pass compiles the reduced
    entry points and dominates runtime; the other three are instant).
    """
    progs = programs if programs is not None else build_stack(arch)
    report = Report(meta=dict(arch=progs.arch, min_bytes=min_bytes))

    if "donation" in passes:
        f, stats = donation_stats(progs.donation_specs, min_bytes=min_bytes)
        report.extend(f)
        report.passes["donation"] = stats
    if "recompile" in passes:
        f, stats = lint_recompile(
            progs.trace_models, synthetic_trace(), max_signatures=max_signatures
        )
        report.extend(f)
        report.passes["recompile"] = stats
    if "sharding" in passes:
        f, stats = lint_sharding(progs.sharding_entries, min_bytes=shard_min_bytes)
        report.extend(f)
        report.passes["sharding"] = stats
    if "kernels" in passes:
        f, stats = lint_kernels(progs.kernel_launches)
        report.extend(f)
        report.passes["kernels"] = stats
    return report
