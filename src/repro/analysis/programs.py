"""The program registry: the stack's canonical entry points, declared once.

``build_stack`` assembles everything the four analysis passes need for one
architecture, with **no device execution**:

* donation specs (:class:`~repro.analysis.donation.ProgramSpec`) for the
  jitted serve/train/population entry points, on the *reduced* config —
  lowering+compiling the reduced forms is cheap and donation/aliasing
  structure is config-size-invariant (the same argnums are donated);
* trace models (:class:`~repro.analysis.recompile.EntryTraceModel`) whose
  signature functions mirror each entry's real jit boundary — tokens shapes,
  static cache lengths, page-chain static argnums;
* sharding entries on the **full** config (specs are free via eval_shape)
  for the production train mesh and the fleet pop×model mesh;
* kernel launches at production-representative shapes via the geometry
  builders in :mod:`repro.analysis.kernelgeom`.

The carried-argnum sets here are load-bearing: they encode which operands
each host loop re-binds from the previous dispatch (see the donate_argnums
comments in ``serve/engine.py`` / ``serve/continuous.py`` /
``fleet/serve.py`` / ``train/step.py``). A refactor that adds a loop-carried
operand without donating it turns into a DON001 the moment it lands here.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.analysis.donation import ProgramSpec
from repro.analysis.kernelgeom import (
    KernelLaunch,
    decode_attention_launch,
    flash_attention_launch,
    masked_matmul_launch,
    mamba_scan_launch,
)
from repro.analysis.recompile import EntryTraceModel, TraceRequest
from repro.analysis.shardlint import FakeMesh, ShardingEntry
from repro.core.masking import FaultContext

__all__ = ["StackPrograms", "build_stack"]

# Reduced-config lowering shapes (cheap to compile, structure-identical).
_SERVE_BATCH = 2
_SERVE_MAX_LEN = 64
_SLOTS = 4
_PAGE_SIZE = 8
_NUM_PAGES = 32
_MAX_PAGES_PER_SEQ = 8
_ADMIT_BUCKET = 16  # reduced-config bucket for lowering the packed admit
_ADMIT_CHUNK = 16  # reduced-config chunked-prefill width
_MAX_PACK = 4
_TRAIN_BATCH = 2
_TRAIN_SEQ = 16
_POP = 4


@dataclass
class StackPrograms:
    """Everything the analyzer lints for one arch, grouped by pass."""

    arch: str
    donation_specs: list = field(default_factory=list)
    trace_models: list = field(default_factory=list)
    sharding_entries: list = field(default_factory=list)
    kernel_launches: list = field(default_factory=list)


def _abstract_ctx(cfg, *, mode: str = "fap") -> FaultContext:
    """A traced-fault-context stand-in: abstract (R, C) mask + static mode."""
    return FaultContext(
        ok=jax.ShapeDtypeStruct((cfg.array_rows, cfg.array_cols), jnp.float32),
        mode=mode,
    )


def _key_struct():
    return jax.ShapeDtypeStruct((2,), jnp.uint32)


def _scalar(dtype):
    return jax.ShapeDtypeStruct((), dtype)


def _serve_specs(cfg_r) -> list:
    from repro.launch.specs import cache_struct, param_struct
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(cfg_r, None, max_len=_SERVE_MAX_LEN)
    params_s, _ = param_struct(cfg_r)
    cache_s = cache_struct(cfg_r, _SERVE_BATCH, _SERVE_MAX_LEN)
    cur_s = jax.ShapeDtypeStruct((_SERVE_BATCH, cfg_r.vocab_size), jnp.float32)
    tok_s = jax.ShapeDtypeStruct((_SERVE_BATCH, 1), jnp.int32)
    ctx = _abstract_ctx(cfg_r)
    return [
        ProgramSpec(
            name="serve.sample_decode",
            fn=eng._sample_decode,
            args=(params_s, cur_s, cache_s, _key_struct(), ctx, _scalar(jnp.float32)),
            carried=frozenset({1, 2, 3}),
            arg_names=("params", "cur_logits", "cache", "key", "ctx", "temperature"),
        ),
        ProgramSpec(
            name="serve.decode",
            fn=eng._decode,
            args=(params_s, tok_s, cache_s, ctx),
            carried=frozenset({2}),
            arg_names=("params", "tokens", "cache", "ctx"),
        ),
    ]


def _continuous_specs(cfg_r) -> list:
    from repro.launch.specs import param_struct
    from repro.models import model as M
    from repro.serve.continuous import ContinuousBatchingEngine

    eng = ContinuousBatchingEngine(
        cfg_r,
        None,
        num_slots=_SLOTS,
        page_size=_PAGE_SIZE,
        num_pages=_NUM_PAGES,
        max_pages_per_seq=_MAX_PAGES_PER_SEQ,
        prefill_buckets=(_ADMIT_BUCKET, 2 * _ADMIT_BUCKET),
        chunk_size=_ADMIT_CHUNK,
        max_pack=_MAX_PACK,
    )
    params_s, _ = param_struct(cfg_r)
    cache_s = jax.eval_shape(
        lambda: M.init_paged_cache(
            cfg_r, _NUM_PAGES, _PAGE_SIZE, _SLOTS, _MAX_PAGES_PER_SEQ
        )
    )
    cur_s = jax.ShapeDtypeStruct((_SLOTS, cfg_r.vocab_size), jnp.float32)
    active_s = jax.ShapeDtypeStruct((_SLOTS,), jnp.bool_)
    remaining_s = jax.ShapeDtypeStruct((_SLOTS,), jnp.int32)
    ctx = _abstract_ctx(cfg_r)
    return [
        ProgramSpec(
            name="continuous.sample_decode",
            fn=eng._sample_decode,
            args=(
                params_s, cur_s, cache_s, _key_struct(), ctx,
                _scalar(jnp.float32), active_s, _scalar(jnp.int32), remaining_s,
            ),
            carried=frozenset({1, 2, 3, 6, 8}),
            arg_names=(
                "params", "cur_logits", "cache", "key", "ctx",
                "temperature", "active", "eos_id", "remaining",
            ),
        ),
        ProgramSpec(
            name="continuous.prefill_admit",
            fn=eng._packed_admit,
            args=(
                params_s,
                jax.ShapeDtypeStruct((1, _ADMIT_BUCKET), jnp.int32),
                jax.ShapeDtypeStruct((1, _ADMIT_BUCKET), jnp.int32),
                jax.ShapeDtypeStruct((1, _ADMIT_BUCKET), jnp.int32),
                ctx, cache_s, cur_s, active_s, remaining_s,
                jax.ShapeDtypeStruct((_ADMIT_BUCKET,), jnp.int32),
                jax.ShapeDtypeStruct((_ADMIT_BUCKET,), jnp.int32),
                jax.ShapeDtypeStruct((_MAX_PACK,), jnp.int32),
                jax.ShapeDtypeStruct((_MAX_PACK,), jnp.int32),
                jax.ShapeDtypeStruct((_MAX_PACK, _MAX_PAGES_PER_SEQ), jnp.int32),
                jax.ShapeDtypeStruct((_MAX_PACK,), jnp.int32),
                jax.ShapeDtypeStruct((_MAX_PACK,), jnp.int32),
            ),
            carried=frozenset({5, 6, 7, 8}),
            arg_names=(
                "params", "tokens", "positions", "segments", "ctx", "cache",
                "cur_logits", "active", "remaining", "page_ix", "page_off",
                "gather_pos", "slots", "rows", "seq_lens", "budgets",
            ),
        ),
        ProgramSpec(
            name="continuous.prefill_chunk",
            fn=eng._prefill_chunk,
            args=(
                params_s,
                jax.ShapeDtypeStruct((1, _ADMIT_CHUNK), jnp.int32),
                ctx, cache_s, cur_s, active_s, remaining_s,
                _scalar(jnp.int32),
                jax.ShapeDtypeStruct((_MAX_PAGES_PER_SEQ,), jnp.int32),
                jax.ShapeDtypeStruct((_ADMIT_CHUNK,), jnp.int32),
                jax.ShapeDtypeStruct((_ADMIT_CHUNK,), jnp.int32),
                _scalar(jnp.int32), _scalar(jnp.int32), _scalar(jnp.int32),
                _scalar(jnp.bool_),
            ),
            carried=frozenset({3, 4, 5, 6}),
            arg_names=(
                "params", "tokens", "ctx", "cache", "cur_logits", "active",
                "remaining", "slot", "row", "page_ix", "page_off", "prefix",
                "valid", "budget", "activate",
            ),
        ),
    ]


def _train_specs(cfg_r) -> list:
    from repro.launch.specs import opt_struct, param_struct
    from repro.train.optimizer import AdamWConfig
    from repro.train.step import make_jit_train_step

    params_s, _ = param_struct(cfg_r)
    opt_s = opt_struct(cfg_r, params_s)
    i32 = jnp.int32
    batch_s = dict(
        tokens=jax.ShapeDtypeStruct((_TRAIN_BATCH, _TRAIN_SEQ), i32),
        labels=jax.ShapeDtypeStruct((_TRAIN_BATCH, _TRAIN_SEQ), i32),
    )
    step = make_jit_train_step(cfg_r, AdamWConfig(), remat="none")
    return [
        ProgramSpec(
            name="train.step",
            fn=step,
            args=(params_s, opt_s, batch_s, _abstract_ctx(cfg_r)),
            carried=frozenset({0, 1}),
            arg_names=("params", "opt_state", "batch", "ctx"),
        )
    ]


def _population_specs(cfg_r) -> list:
    from repro.data.synthetic import TokenStream
    from repro.launch.specs import param_struct
    from repro.train.optimizer import AdamWConfig
    from repro.train.population import PopulationFATEngine
    from repro.train.step import make_loss_fn

    stream = TokenStream(cfg_r.vocab_size, _TRAIN_SEQ, _TRAIN_BATCH, seed=0)
    engine = PopulationFATEngine(
        loss_fn=make_loss_fn(cfg_r, remat="none"),
        opt_cfg=AdamWConfig(),
        eval_batches=[stream.batch_at(10_000_000)],
        population_size=_POP,
        eval_every=2,
    )
    params_s, _ = param_struct(cfg_r)
    ok_pop = jax.ShapeDtypeStruct(
        (_POP, cfg_r.array_rows, cfg_r.array_cols), jnp.float32
    )
    budgets = jax.ShapeDtypeStruct((_POP,), jnp.int32)
    # the population sweep fans every member out from ONE params0 buffer the
    # caller keeps for the next sweep — nothing is loop-carried, nothing may
    # be donated; the lint asserts the carried set stays empty
    fit = jax.jit(engine._fit_run(stream.batch_at, "fap"))
    return [
        ProgramSpec(
            name="population.fit_run",
            fn=fit,
            args=(params_s, ok_pop, budgets),
            carried=frozenset(),
            arg_names=("params0", "ok_pop", "budgets"),
        )
    ]


def _trace_models() -> list:
    """Analytic jit signatures, mirroring the entries' real boundaries.

    serve/continuous entries sweep only the request dimensions their jit
    boundary can see (prompt_len, max_new_tokens) — ``batch`` is an engine
    constant (slot count / rectangular batch), not per-request traffic.
    train.step is launch-configured: its shapes never vary with a request.
    """

    from repro.serve.bucketing import DEFAULT_PREFILL_BUCKETS, bucket_of, ladder_rung

    def serve_prefill_sig(r: TraceRequest) -> tuple:
        # ServeEngine._prefill_len: prompts pad up the bucket ladder with a
        # traced valid_len, so the traced width is the prompt's ladder rung
        # (capped by the shipped default max_len=4096 capacity) — one
        # program per rung, not per distinct prompt length
        rung = min(ladder_rung(r.prompt_len, DEFAULT_PREFILL_BUCKETS), 4096)
        return ("serve.prefill", rung, 4096)

    def serve_decode_sig(r: TraceRequest) -> tuple:
        # fused sample+decode: (B, V) logits + fixed-capacity cache
        return ("serve.sample_decode", 4096)

    def cont_decode_sig(r: TraceRequest) -> tuple:
        # the slot-table dispatch: every shape is an engine constant
        return ("continuous.sample_decode", _SLOTS, _NUM_PAGES, _PAGE_SIZE)

    def cont_admit_sig(r: TraceRequest) -> tuple:
        # bucketed planner: a prompt admits at its bucket's packed-admit
        # program, or — past the top bucket — through the single chunked
        # program; page chains and pack occupancy are traced, not static
        b = bucket_of(r.prompt_len, DEFAULT_PREFILL_BUCKETS)
        if b is None:
            return ("continuous.prefill_chunk", DEFAULT_PREFILL_BUCKETS[-1])
        return ("continuous.prefill_admit", b)

    def train_sig(r: TraceRequest) -> tuple:
        return ("train.step", _TRAIN_BATCH, _TRAIN_SEQ)

    serve_dims = ("prompt_len", "max_new_tokens")
    return [
        EntryTraceModel("serve.prefill", serve_prefill_sig, dims=serve_dims),
        EntryTraceModel("serve.sample_decode", serve_decode_sig, dims=serve_dims),
        EntryTraceModel("continuous.sample_decode", cont_decode_sig, dims=serve_dims),
        EntryTraceModel("continuous.prefill_admit", cont_admit_sig, dims=serve_dims),
        EntryTraceModel("train.step", train_sig, dims=("prompt_len", "batch")),
    ]


def _sharding_entries(cfg) -> list:
    from repro.launch.sharding import make_rules_for_mesh
    from repro.launch.specs import param_struct
    from repro.models import model as M

    params_s, _ = param_struct(cfg)
    axes = M.param_specs(cfg)
    train_mesh = FakeMesh.of(data=2, model=4)
    fleet_mesh = FakeMesh.of(pop=4, model=2)
    return [
        ShardingEntry(
            name="train.params",
            mctx=make_rules_for_mesh(cfg, train_mesh),
            axes=axes,
            structs=params_s,
        ),
        ShardingEntry(
            name="fleet.params",
            mctx=make_rules_for_mesh(cfg, fleet_mesh, reserved_axes=("pop",)),
            axes=axes,
            structs=params_s,
            engine_axes=("pop",),
        ),
    ]


def _kernel_launches(cfg) -> list:
    """Production-representative launches of every shipped Pallas kernel."""
    dtype = jnp.dtype(cfg.dtype)
    mask_shape = (cfg.array_rows, cfg.array_cols)
    chip_ctx = FaultContext(
        ok=jax.ShapeDtypeStruct(mask_shape, jnp.float32), mode="pallas"
    )
    hq = cfg.num_heads or 8
    hkv = cfg.num_kv_heads or hq
    hd = cfg.resolved_head_dim or 64
    launches: list[KernelLaunch] = [
        # the FAP masked GEMM at a full-seq MLP shape (tokens x d_model -> d_ff)
        masked_matmul_launch(
            2048, cfg.d_model, cfg.d_ff or 4 * cfg.d_model,
            mask_shape, dtype=dtype, ctx=chip_ctx,
        ),
        flash_attention_launch(8, hq, hkv, 2048, 2048, hd, dtype=dtype),
        decode_attention_launch(8, hq, hkv, 4096, hd),
        decode_attention_launch(_SLOTS, hq, hkv, 4096, hd, paged=True,
                                page_size=_PAGE_SIZE),
        # the SSM scan ships in the kernel stack regardless of arch family
        mamba_scan_launch(8, 2048, 1536, 16),
    ]
    return launches


def build_stack(arch: str = "smollm-135m", cfg=None, cfg_reduced=None) -> StackPrograms:
    """Assemble the lintable stack for ``arch``.

    ``cfg``/``cfg_reduced`` override the registry lookup (tests inject tiny
    configs); by default the sharding/kernel passes see the full config and
    the lowering passes see ``reduce_config`` of it.
    """
    from repro.configs import get_arch, reduce_config

    cfg = cfg if cfg is not None else get_arch(arch)
    cfg_r = cfg_reduced if cfg_reduced is not None else reduce_config(cfg)

    progs = StackPrograms(arch=arch)
    progs.donation_specs = (
        _serve_specs(cfg_r)
        + _continuous_specs(cfg_r)
        + _train_specs(cfg_r)
        + _population_specs(cfg_r)
    )
    progs.trace_models = _trace_models()
    progs.sharding_entries = _sharding_entries(cfg)
    progs.kernel_launches = _kernel_launches(cfg)
    return progs
