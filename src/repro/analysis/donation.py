"""Donation/aliasing lint (DON001) over optimized-HLO alias metadata.

A serve/train dispatch that carries big state (KV page pools, params,
optimizer moments) back out as a result should *donate* the input buffer:
without ``donate_argnums`` XLA must keep the operand alive while writing a
fresh result buffer, so every token/step round-trips the full state through
a copy that donation makes free. The lint takes a :class:`ProgramSpec`
(declaring which top-level args the caller's loop actually re-binds each
dispatch), lowers+compiles the entry point on abstract args, and joins
three sources:

* ``lowered.args_info`` — the jit-level pytree of per-leaf ``donated``
  flags, which also gives every leaf's aval (bytes) and path label;
* the ``input_output_alias`` table of the optimized HLO module header
  (via ``repro.launch.hlo_cost.input_output_aliases``) — the backend's
  ground truth for which entry parameters were actually aliased;
* ``entry_parameters`` — the HLO-side byte check that flat leaf order
  matches entry parameter numbering (jit may prune unused leaves;
  on any mismatch the lint falls back to the jit-level flags).

Each loop-carried leaf above ``min_bytes`` that is not aliased becomes a
DON001 finding weighted by its per-dispatch byte size.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from repro.analysis.findings import Finding
from repro.launch.hlo_cost import entry_parameters, input_output_aliases

__all__ = ["ProgramSpec", "lint_donation", "donation_stats"]


@dataclass
class ProgramSpec:
    """One jitted entry point plus the facts the linter can't infer.

    ``carried`` are the *top-level positional* arg indices whose buffers the
    host loop re-binds from the previous dispatch's outputs (and therefore
    could donate); everything else (params reused across calls, static
    scalars, the fault context) must NOT be donated and is not linted.
    """

    name: str
    fn: Callable  # the jitted callable (has .lower)
    args: tuple  # abstract args: pytrees of ShapeDtypeStruct leaves
    carried: frozenset  # top-level positional indices that are loop-carried
    kwargs: dict = field(default_factory=dict)  # static kwargs for lower()
    arg_names: tuple = ()  # labels for top-level args (defaults to arg<i>)

    def arg_label(self, i: int) -> str:
        if i < len(self.arg_names):
            return self.arg_names[i]
        return f"arg{i}"


def _leaf_bytes(aval) -> int:
    return int(np.prod(aval.shape, dtype=np.int64)) * np.dtype(aval.dtype).itemsize


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def _flat_arg_leaves(args_info):
    """Flatten ``lowered.args_info`` to [(top_idx, path, ArgInfo)] in the
    entry-parameter flattening order (positional args then kwargs)."""
    pos, kw = args_info
    out = []
    for i, sub in enumerate(pos):
        for path, leaf in jax.tree_util.tree_flatten_with_path(sub)[0]:
            out.append((i, _path_str(path), leaf))
    for name in sorted(kw):  # static kwargs never appear here; traced kwargs do
        for path, leaf in jax.tree_util.tree_flatten_with_path(kw[name])[0]:
            out.append((-1, f"{name}/{_path_str(path)}", leaf))
    return out


def lint_donation(
    spec: ProgramSpec, *, min_bytes: int = 1 << 16
) -> tuple[list, dict]:
    """Lint one entry point; returns (findings, stats).

    Stats: per-dispatch carried bytes, how many of them are donated (by the
    compiled module's own alias table when leaf order is verifiable, else by
    the jit-level flags), and the donated fraction the serve benchmark
    records.
    """
    lowered = spec.fn.lower(*spec.args, **spec.kwargs)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    leaves = _flat_arg_leaves(lowered.args_info)
    params_tab = entry_parameters(hlo)
    aliases = input_output_aliases(hlo)

    # Trust HLO param numbering only when it matches the flat leaf count and
    # per-leaf byte sizes — jit prunes unused leaves, which would shift it.
    aliased_params = {a.param_number for a in aliases}
    hlo_order_ok = len(params_tab) == len(leaves) and all(
        params_tab[i].result_bytes == _leaf_bytes(leaf._aval)
        for i, (_, _, leaf) in enumerate(leaves)
        if i in params_tab
    )

    findings: list = []
    carried_bytes = 0
    donated_bytes = 0
    total_bytes = 0
    for flat_idx, (top, path, leaf) in enumerate(leaves):
        nbytes = _leaf_bytes(leaf._aval)
        total_bytes += nbytes
        if top not in spec.carried:
            continue
        donated = (
            flat_idx in aliased_params if hlo_order_ok else bool(leaf.donated)
        )
        carried_bytes += nbytes
        if donated:
            donated_bytes += nbytes
            continue
        if nbytes < min_bytes:
            continue
        label = spec.arg_label(top)
        subject = f"{label}/{path}" if path else label
        findings.append(
            Finding(
                code="DON001",
                entry_point=spec.name,
                subject=subject,
                message=(
                    f"loop-carried buffer {subject} ({nbytes/2**20:.2f} MiB "
                    f"{np.dtype(leaf._aval.dtype).name}{list(leaf._aval.shape)}) "
                    "round-trips undonated through every dispatch — add it to "
                    "donate_argnums so XLA aliases it in place"
                ),
                severity="error",
                bytes=nbytes,
            )
        )
    stats = dict(
        entry_params=len(params_tab),
        arg_leaves=len(leaves),
        hlo_alias_table=hlo_order_ok,
        aliased_params=len(aliased_params),
        total_arg_bytes=total_bytes,
        carried_bytes=carried_bytes,
        donated_bytes=donated_bytes,
        undonated_carried_bytes=carried_bytes - donated_bytes,
        donated_fraction=(donated_bytes / carried_bytes) if carried_bytes else 1.0,
    )
    return findings, stats


def donation_stats(specs, *, min_bytes: int = 1 << 16) -> tuple[list, dict]:
    """Run the donation lint over a registry of specs; aggregates stats."""
    findings: list = []
    per_entry: dict = {}
    carried = donated = 0
    for spec in specs:
        f, s = lint_donation(spec, min_bytes=min_bytes)
        findings.extend(f)
        per_entry[spec.name] = s
        carried += s["carried_bytes"]
        donated += s["donated_bytes"]
    agg = dict(
        entries=per_entry,
        carried_bytes=carried,
        donated_bytes=donated,
        donated_fraction=(donated / carried) if carried else 1.0,
    )
    return findings, agg
