"""Sharding lint (SHD001/SHD002) — re-resolve the rule engine statically.

``repro.launch.sharding.resolve_spec`` falls back to replication whenever no
rule candidate divides a dim — deliberately (small models replicate their
attention), but silently: a refactor that renames a logical axis or a mesh
that stops dividing a dim degrades to full replication with zero signal.
This pass re-runs the *same* resolution the launch layer uses, over the
same logical-axes trees (``launch/specs.py``), on a duck-typed mesh — no
devices needed — and flags:

* SHD001 — a leaf above ``min_bytes`` resolved to **full replication** even
  though some rule candidate for one of its logical axes exists on the mesh
  (i.e. sharding was available and was lost to divisibility/axis-conflict,
  not by design-with-no-rule);
* SHD002 — a resolved spec assigns a mesh axis the entry declared as
  **engine-owned** (the fleet layer's ``"pop"`` axis): member state inside a
  shard_map lane must never re-shard over the axis the engine itself maps.

``FakeMesh`` quacks like ``jax.sharding.Mesh`` for everything resolution
touches (``.shape`` mapping), so fleet-mesh rule sets lint on a single-CPU
host exactly as they resolve on an 8-device pod.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro.analysis.findings import Finding
from repro.launch.sharding import MeshContext, resolve_spec

__all__ = ["FakeMesh", "ShardingEntry", "lint_sharding"]


@dataclass(frozen=True)
class FakeMesh:
    """Duck-typed stand-in for ``jax.sharding.Mesh``: resolution only reads
    ``mesh.shape`` (an axis-name -> size mapping)."""

    axes: tuple  # ((name, size), ...)

    @property
    def shape(self) -> dict:
        return dict(self.axes)

    @classmethod
    def of(cls, **sizes: int) -> "FakeMesh":
        return cls(axes=tuple(sizes.items()))


@dataclass
class ShardingEntry:
    """One program's sharding surface: logical axes + concrete shapes.

    ``axes``/``structs`` are matching pytrees (axes leaves are tuples of
    logical-axis names, structs leaves are ShapeDtypeStructs).
    ``engine_axes`` are the mesh axes an outer engine owns for this entry —
    any resolved spec touching them is SHD002.
    """

    name: str
    mctx: MeshContext
    axes: Any
    structs: Any
    engine_axes: tuple = ()


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def _leaf_bytes(struct) -> int:
    return int(np.prod(struct.shape, dtype=np.int64)) * np.dtype(struct.dtype).itemsize


def _spec_axes(spec) -> set:
    out: set = set()
    for part in spec:
        if part is None:
            continue
        names = (part,) if isinstance(part, str) else part
        out.update(names)
    return out


def _shardable_rule_exists(axes, mctx: MeshContext) -> Optional[str]:
    """First logical axis with a live (present, unreserved, >1) candidate."""
    for name in axes:
        if name is None:
            continue
        for cand in mctx.rules.get(name, ()):
            names = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a in mctx.reserved_axes for a in names):
                continue
            if any(a not in mctx.mesh.shape for a in names):
                continue
            if mctx.axis_size(cand) > 1:
                return name
    return None


def lint_sharding(
    entries: Sequence[ShardingEntry], *, min_bytes: int = 1 << 20
) -> tuple[list, dict]:
    """Returns (findings, stats) over every entry's (axes, shape) leaves."""
    findings: list = []
    stats: dict = {}
    for entry in entries:
        flat_axes = jax.tree_util.tree_flatten_with_path(
            entry.axes, is_leaf=_is_axes_leaf
        )[0]
        flat_structs = jax.tree_util.tree_leaves(entry.structs)
        if len(flat_axes) != len(flat_structs):
            raise ValueError(
                f"{entry.name}: axes tree has {len(flat_axes)} leaves but "
                f"structs tree has {len(flat_structs)}"
            )
        n_sharded = n_replicated = 0
        replicated_bytes = 0
        for (path, axes), struct in zip(flat_axes, flat_structs):
            label = "/".join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in path
            ) or "value"
            spec = resolve_spec(axes, struct.shape, entry.mctx)
            assigned = _spec_axes(spec)
            owned = assigned & set(entry.engine_axes)
            if owned:
                findings.append(
                    Finding(
                        code="SHD002",
                        entry_point=entry.name,
                        subject=label,
                        message=(
                            f"{label} resolved to spec {spec} using engine-owned "
                            f"mesh axes {sorted(owned)} — the outer engine shards "
                            "that axis itself (shard_map); pass it via "
                            "reserved_axes so model rules skip it"
                        ),
                        severity="error",
                        bytes=_leaf_bytes(struct),
                    )
                )
            if assigned:
                n_sharded += 1
                continue
            n_replicated += 1
            nbytes = _leaf_bytes(struct)
            replicated_bytes += nbytes
            if nbytes < min_bytes:
                continue
            lost_axis = _shardable_rule_exists(axes, entry.mctx)
            if lost_axis is None:
                continue  # replication by design: no live rule for any axis
            findings.append(
                Finding(
                    code="SHD001",
                    entry_point=entry.name,
                    subject=label,
                    message=(
                        f"{label} ({nbytes/2**20:.2f} MiB, logical axes "
                        f"{tuple(a for a in axes if a)}) fell back to full "
                        f"replication although axis {lost_axis!r} has a live "
                        "rule on this mesh — a divisibility or axis-conflict "
                        "regression, not replication by design"
                    ),
                    severity="warn",
                    bytes=nbytes,
                )
            )
        stats[entry.name] = dict(
            leaves=len(flat_structs),
            sharded=n_sharded,
            replicated=n_replicated,
            replicated_bytes=replicated_bytes,
        )
    return findings, stats
