"""Finding/report model for the static program linter.

A :class:`Finding` is one violation with a *stable identity* — the
``(code, entry_point, subject)`` triple — so a committed baseline can
distinguish pre-existing violations (tolerated) from new ones (CI
failure). Codes are grouped by pass:

====== =====================================================================
code   meaning
====== =====================================================================
DON001 loop-carried buffer round-trips undonated through every dispatch
RCP001 trace-signature set unbounded in a request dimension (recompile
       per distinct value — unbounded compile volume under real traffic)
RCP002 distinct trace signatures on the given traffic trace exceed budget
SHD001 array above the size threshold implicitly fell back to full
       replication although a sharding rule for its logical axis exists
SHD002 resolved sharding assigns a mesh axis owned by an outer engine
       (e.g. the fleet layer's reserved "pop" axis)
KRN001 Pallas block geometry invalid: block does not divide the padded dim
       (or is incompatible with the fault-mask period)
KRN002 analytic VMEM footprint of the kernel's resident blocks exceeds the
       per-core budget
KRN003 degenerate grid: an axis extent of zero / overflow, or a total
       program count that is a launch-time scheduling hazard
KRN004 batched FaultContext would reach a masked GEMM outside jax.vmap
====== =====================================================================

The report is plain JSON (``Report.as_dict``); the committed baseline is
the sorted list of finding keys plus metadata (``Report.baseline_dict``).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

__all__ = ["Finding", "Report", "load_baseline", "SEVERITIES"]

SEVERITIES = ("info", "warn", "error")


@dataclass(frozen=True)
class Finding:
    """One violation. ``subject`` must be stable across runs (an arg label,
    a param leaf path, a kernel axis name) — it is the baseline identity."""

    code: str
    entry_point: str
    subject: str
    message: str
    severity: str = "error"
    bytes: float = 0.0

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    @property
    def key(self) -> str:
        return f"{self.code}:{self.entry_point}:{self.subject}"

    def as_dict(self) -> dict:
        return dict(
            code=self.code,
            entry_point=self.entry_point,
            subject=self.subject,
            message=self.message,
            severity=self.severity,
            bytes=float(self.bytes),
        )

    @classmethod
    def from_dict(cls, d: dict) -> "Finding":
        return cls(
            code=d["code"],
            entry_point=d["entry_point"],
            subject=d["subject"],
            message=d.get("message", ""),
            severity=d.get("severity", "error"),
            bytes=float(d.get("bytes", 0.0)),
        )


def _severity_rank(f: Finding) -> tuple:
    return (-SEVERITIES.index(f.severity), -f.bytes, f.key)


@dataclass
class Report:
    """All findings of one analyzer run plus per-pass summary stats."""

    findings: list = field(default_factory=list)
    passes: dict = field(default_factory=dict)  # pass name -> stats dict
    meta: dict = field(default_factory=dict)

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    def sorted_findings(self) -> list:
        return sorted(self.findings, key=_severity_rank)

    def keys(self) -> set:
        return {f.key for f in self.findings}

    def new_vs_baseline(self, baseline_keys) -> list:
        """Findings not covered by the baseline — what ``--check`` fails on."""
        baseline_keys = set(baseline_keys)
        return [f for f in self.sorted_findings() if f.key not in baseline_keys]

    def resolved_vs_baseline(self, baseline_keys) -> list:
        """Baselined keys that no longer fire (candidates for re-baselining)."""
        return sorted(set(baseline_keys) - self.keys())

    def as_dict(self) -> dict:
        return dict(
            meta=self.meta,
            passes=self.passes,
            findings=[f.as_dict() for f in self.sorted_findings()],
        )

    def baseline_dict(self) -> dict:
        """The committable baseline: stable keys only (messages and byte
        counts drift with configs; identities don't)."""
        return dict(
            meta={k: self.meta[k] for k in ("arch",) if k in self.meta},
            keys=sorted(self.keys()),
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=1)
            f.write("\n")


def load_baseline(path: str) -> set:
    """Baseline keys from a committed baseline file (or a full report)."""
    with open(path) as f:
        d = json.load(f)
    if "keys" in d:
        return set(d["keys"])
    return {Finding.from_dict(fd).key for fd in d.get("findings", ())}
