"""Pallas kernel geometry lint (KRN001–KRN004) — launch checks before launch.

A Pallas call with bad geometry fails at Mosaic compile/launch time, i.e.
the first time a traffic shape hits it in production. Every failure mode is
a pure function of static geometry, so this pass checks it at lint time:

* KRN001 — a grid axis' dim is not divisible by its block (the exact
  ``grid_for`` failure), or a masked-matmul block is incompatible with the
  fault-mask period (the exact ``_mask_axis_plan`` failure);
* KRN002 — the analytic VMEM footprint of the launch's resident blocks
  (``kernels/common.py::vmem_footprint``) exceeds ``VMEM_LIMIT_BYTES``;
* KRN003 — a degenerate grid: non-positive or int32-overflowing axis;
* KRN004 — a batched ``FaultContext`` would reach a masked GEMM outside
  ``jax.vmap`` (the static form of ``core/masking.py``'s runtime guard,
  via ``context_leak_reason`` — works on abstract contexts).

The ``*_launch`` builders reproduce the geometry the ``ops.py`` wrappers
compute for given logical shapes (same ``choose_block``/padding calls), so
linting the shipped stack means building its launches and running
:func:`check_launch` on each; golden tests hand-build broken launches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.core.masking import FaultContext, context_leak_reason
from repro.kernels.common import (
    MAX_GRID_AXIS,
    VMEM_LIMIT_BYTES,
    choose_block,
    pad_to_multiple,
    vmem_footprint,
)
from repro.kernels.masked_matmul.masked_matmul import _mask_axis_plan

__all__ = [
    "KernelLaunch",
    "check_launch",
    "masked_matmul_launch",
    "flash_attention_launch",
    "decode_attention_launch",
    "mamba_scan_launch",
    "lint_kernels",
]

_LANES = 128  # TPU lane width: the attention kernels' stats-scratch columns


@dataclass(frozen=True)
class KernelLaunch:
    """Static description of one pallas_call: grid geometry + VMEM blocks.

    ``dims``/``blocks`` are the gridded axes (post-padding dims, in grid
    order); ``vmem_blocks`` is every VMEM-resident buffer of one program
    instance as ``(shape, dtype)`` or ``(shape, dtype, is_io)`` — in/out
    blocks plus scratch; ``is_io=False`` marks scratch buffers the Mosaic
    pipeline does NOT double-buffer (see ``vmem_footprint``).
    ``mask_blocks`` are ``(block, period)`` pairs for periodic-mask axes
    (masked matmul); ``ctx`` is the FaultContext the launch would consume.
    """

    kernel: str
    dims: tuple
    blocks: tuple
    vmem_blocks: tuple  # ((shape, dtype), ...)
    mask_blocks: tuple = ()  # ((block, period), ...)
    ctx: Optional[FaultContext] = None

    @property
    def grid(self) -> tuple:
        return tuple(
            d // b if b else 0 for d, b in zip(self.dims, self.blocks)
        )


def check_launch(launch: KernelLaunch) -> list:
    """All geometry findings for one launch (empty list = launchable)."""
    findings: list = []
    name = launch.kernel
    for axis, (d, b) in enumerate(zip(launch.dims, launch.blocks)):
        if b <= 0 or d <= 0:
            findings.append(
                Finding(
                    code="KRN003",
                    entry_point=name,
                    subject=f"axis{axis}",
                    message=f"degenerate grid axis {axis}: dim {d}, block {b}",
                )
            )
            continue
        if d % b:
            findings.append(
                Finding(
                    code="KRN001",
                    entry_point=name,
                    subject=f"axis{axis}",
                    message=(
                        f"grid axis {axis}: dim {d} not divisible by block {b} "
                        "— pallas_call would read out of bounds / grid_for "
                        "raises at launch"
                    ),
                )
            )
            continue
        if d // b > MAX_GRID_AXIS:
            findings.append(
                Finding(
                    code="KRN003",
                    entry_point=name,
                    subject=f"axis{axis}",
                    message=f"grid axis {axis} extent {d // b} overflows int32",
                )
            )
    for i, (b, period) in enumerate(launch.mask_blocks):
        try:
            _mask_axis_plan(int(b), int(period))
        except ValueError as e:
            findings.append(
                Finding(
                    code="KRN001",
                    entry_point=name,
                    subject=f"mask_axis{i}",
                    message=f"mask-period incompatibility: {e}",
                )
            )
    vmem = vmem_footprint(launch.vmem_blocks)
    if vmem > VMEM_LIMIT_BYTES:
        findings.append(
            Finding(
                code="KRN002",
                entry_point=name,
                subject="vmem",
                message=(
                    f"resident blocks need {vmem/2**20:.2f} MiB VMEM "
                    f"(limit {VMEM_LIMIT_BYTES/2**20:.0f} MiB) — shrink blocks"
                ),
                bytes=vmem,
            )
        )
    reason = context_leak_reason(launch.ctx)
    if reason is not None:
        findings.append(
            Finding(
                code="KRN004",
                entry_point=name,
                subject="ctx",
                message=reason,
            )
        )
    return findings


# ---------------------------------------------------------------------------
# Launch builders — mirror the ops.py wrappers' geometry exactly
# ---------------------------------------------------------------------------


def masked_matmul_launch(
    m: int,
    k: int,
    n: int,
    mask_shape: tuple,
    *,
    bm: int = 512,
    bn: int = 512,
    bk: int = 512,
    dtype: Any = jnp.float32,
    ctx: Optional[FaultContext] = None,
) -> KernelLaunch:
    """Geometry of ``masked_matmul.ops.masked_matmul(x[(m,k)], w[(k,n)])``."""
    r, c = mask_shape
    bm_ = choose_block(m, bm)
    bn_ = choose_block(n, bn, multiple_of=c)
    bk_ = choose_block(k, bk, multiple_of=r)
    mp, np_ = pad_to_multiple(m, bm_), pad_to_multiple(n, bn_)
    kp = k if k % bk_ == 0 else pad_to_multiple(k, max(bk_, r))
    mask_br = min(bk_, r)
    mask_bc = min(bn_, c)
    return KernelLaunch(
        kernel="masked_matmul",
        dims=(mp, np_, kp),
        blocks=(bm_, bn_, bk_),
        vmem_blocks=(
            ((bm_, bk_), dtype),  # x block
            ((bk_, bn_), dtype),  # w block
            ((mask_br, mask_bc), jnp.float32),  # mask block
            ((bm_, bn_), dtype),  # out block
            ((bm_, bn_), jnp.float32, False),  # accumulator scratch
        ),
        mask_blocks=((bk_, r), (bn_, c)),
        ctx=ctx,
    )


def flash_attention_launch(
    batch: int,
    hq: int,
    hkv: int,
    sq: int,
    skv: int,
    head_dim: int,
    *,
    bq: int = 128,
    bkv: int = 128,
    dtype: Any = jnp.float32,
) -> KernelLaunch:
    """Geometry of ``flash_attention.ops.flash_attention`` (B,H,S,D)."""
    bq_ = min(bq, sq)
    sq_p = pad_to_multiple(sq, max(bq_, 8))
    bq_ = min(max(bq_, 8), sq_p)
    bkv_ = min(bkv, skv)
    skv_p = pad_to_multiple(skv, bkv_)
    d = head_dim
    return KernelLaunch(
        kernel="flash_attention",
        dims=(batch * hq, sq_p, skv_p),
        blocks=(1, bq_, bkv_),
        vmem_blocks=(
            ((1, bq_, d), dtype),  # q block
            ((1, bkv_, d), dtype),  # k block
            ((1, bkv_, d), dtype),  # v block
            ((1, bq_, d), dtype),  # out block
            ((bq_, d), jnp.float32, False),  # o accumulator scratch
            ((bq_, _LANES), jnp.float32, False),  # running max scratch
            ((bq_, _LANES), jnp.float32, False),  # running sum scratch
        ),
    )


def decode_attention_launch(
    batch: int,
    hq: int,
    hkv: int,
    skv: int,
    head_dim: int,
    *,
    bkv: int = 128,
    paged: bool = False,
    page_size: int = 0,
) -> KernelLaunch:
    """Geometry of ``decode_attention.ops.decode_attention`` (int8 KV) or
    its paged variant (``paged=True`` with the pool's ``page_size``)."""
    d = head_dim
    group = hq // max(1, hkv)
    if paged:
        gq = 8 * -(-group // 8)
        page = page_size
        return KernelLaunch(
            kernel="paged_decode_attention",
            dims=(batch * hkv, gq, page),
            blocks=(1, gq, page),
            vmem_blocks=(
                ((1, gq, d), jnp.float32),  # q block
                ((1, 1, page, d), jnp.int8),  # k page
                ((1, 1, page), jnp.float32),  # k scales
                ((1, 1, page, d), jnp.int8),  # v page
                ((1, 1, page), jnp.float32),  # v scales
                ((1, gq, d), jnp.float32),  # out block
                ((gq, d), jnp.float32, False),  # o accumulator scratch
                ((gq, _LANES), jnp.float32, False),  # running max scratch
                ((gq, _LANES), jnp.float32, False),  # running sum scratch
            ),
        )
    bq = 8  # TPU sublane minimum; decode q is 1 row padded
    skv_p = pad_to_multiple(skv, min(bkv, skv))
    bkv_ = min(bkv, skv_p)
    return KernelLaunch(
        kernel="decode_attention",
        dims=(batch * hq, bq, skv_p),
        blocks=(1, bq, bkv_),
        vmem_blocks=(
            ((1, bq, d), jnp.float32),  # q block
            ((1, bkv_, d), jnp.int8),  # k block
            ((1, bkv_), jnp.float32),  # k scales
            ((1, bkv_, d), jnp.int8),  # v block
            ((1, bkv_), jnp.float32),  # v scales
            ((1, bq, d), jnp.float32),  # out block
            ((bq, d), jnp.float32, False),  # o accumulator scratch
            ((bq, _LANES), jnp.float32, False),  # running max scratch
            ((bq, _LANES), jnp.float32, False),  # running sum scratch
        ),
    )


def mamba_scan_launch(
    batch: int,
    length: int,
    dim: int,
    state: int,
    *,
    bd: int = 256,
    bl: int = 128,
    dtype: Any = jnp.float32,
) -> KernelLaunch:
    """Geometry of ``mamba_scan.ops.selective_scan`` (B, L, D) + state N."""
    bd_ = min(bd, dim)
    bl_ = min(bl, length)
    dim_p = pad_to_multiple(dim, bd_)
    len_p = pad_to_multiple(length, bl_)
    n = state
    return KernelLaunch(
        kernel="mamba_scan",
        dims=(batch, dim_p, len_p),
        blocks=(1, bd_, bl_),
        vmem_blocks=(
            ((1, bl_, bd_), dtype),  # u block
            ((1, bl_, bd_), dtype),  # dt block
            ((bd_, n), jnp.float32),  # A block
            ((1, bl_, n), dtype),  # B block
            ((1, bl_, n), dtype),  # C block
            ((1, bd_), dtype),  # D skip
            ((1, bl_, bd_), dtype),  # y out
            ((1, bd_, n), jnp.float32),  # h_last out
            ((bd_, n), jnp.float32, False),  # h scratch
        ),
    )


def lint_kernels(launches: Sequence[KernelLaunch]) -> tuple[list, dict]:
    """Run :func:`check_launch` over a stack's launches; (findings, stats)."""
    findings: list = []
    stats: dict = {}
    for i, launch in enumerate(launches):
        f = check_launch(launch)
        findings.extend(f)
        key = launch.kernel
        if key in stats:
            key = f"{key}[{i}]"
        stats[key] = dict(
            grid=list(launch.grid),
            vmem_bytes=vmem_footprint(launch.vmem_blocks),
            findings=len(f),
        )
    return findings, stats
