"""Budget-aware fleet scheduling — packing retraining jobs into population
chunks.

The population engines run a chunk of members as ONE program: ``fit_batch``
drives every member of a chunk to the chunk's **largest** step budget
(smaller-budget members are select-masked off and ride along), and
``steps_to_constraint_batch`` runs a chunk until its **slowest** member
crosses the constraint. Vectorized lanes spent on already-finished members
are pure waste, so chunk *composition* matters: packing a 10-step job next
to a 500-step job wastes 490 lane-steps.

``FleetScheduler`` decides submission order. Because per-member results are
chunk-invariant (pinned by tests/test_population.py), reordering changes
**only** wall-clock/waste, never the math — LPT-packed chunks yield
bitwise-identical params and steps-to-constraint to arrival order.

Policies
--------
arrival : submit in caller order (the pre-fleet behavior).
lpt     : longest-processing-time — sort by descending cost (prescribed
          steps for Step-4 ``fit_batch``; fault rate as the cost proxy for
          Step-1 probing, where the answer *is* the unknown) and slice
          contiguously into ``population_size``-wide chunks, so each chunk
          holds similar-cost members and the span ≈ every member's own cost.

``wasted_steps`` counts lane-steps where a lane runs past its member's
budget — including padding lanes of a partial final chunk (they occupy real
vectorized width at zero budget). LPT strictly reduces it on skewed plans;
``benchmarks/efat_bench.py --sharded`` reports the reduction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

__all__ = ["ScheduledChunk", "FleetSchedule", "FleetScheduler", "round_up_to_multiple"]


def round_up_to_multiple(x: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` >= ``x`` — THE mesh-tiling rounding.
    The scheduler's chunk widths and the sharded engine's compiled chunk
    widths (fleet/sharding.py) must round identically or ``wasted_steps``
    accounting desyncs from what actually runs; both call this."""
    return -(-x // multiple) * multiple


@dataclass(frozen=True)
class ScheduledChunk:
    """One population submission: ``indices`` into the caller's job list (in
    submission order) and their costs. ``width`` is the compiled chunk width
    (>= len(indices); the remainder is padding lanes at cost 0)."""

    indices: tuple[int, ...]
    costs: tuple[float, ...]
    width: int

    @property
    def span(self) -> float:
        """Steps the whole chunk runs for: its largest member budget."""
        return max(self.costs) if self.costs else 0.0

    @property
    def wasted_steps(self) -> float:
        """Lane-steps spent past a member's own budget, padding included."""
        return self.span * self.width - sum(self.costs)


@dataclass(frozen=True)
class FleetSchedule:
    """A submission order + its chunk decomposition and waste accounting."""

    order: tuple[int, ...]  # order[k] = original index of the k-th submitted job
    chunks: tuple[ScheduledChunk, ...]
    policy: str
    population_size: int

    @property
    def wasted_steps(self) -> float:
        return sum(c.wasted_steps for c in self.chunks)

    @property
    def span_steps(self) -> float:
        """Sequential makespan: chunks run one after another, each to its span."""
        return sum(c.span for c in self.chunks)

    def permute(self, seq: Sequence):
        """Reorder caller-order ``seq`` into submission order."""
        if len(seq) != len(self.order):
            raise ValueError(f"schedule covers {len(self.order)} jobs, got {len(seq)}")
        return [seq[i] for i in self.order]

    def unpermute(self, seq: Sequence) -> list:
        """Map submission-order results back to caller order."""
        if len(seq) != len(self.order):
            raise ValueError(f"schedule covers {len(self.order)} jobs, got {len(seq)}")
        out = [None] * len(seq)
        for k, i in enumerate(self.order):
            out[i] = seq[k]
        return out


class FleetScheduler:
    """Bin-packs jobs into ``population_size``-wide chunks by cost.

    One scheduler instance serves both Step-1 (cost = fault rate) and
    Step-4 (cost = prescribed steps) so the fleet has a single chunking
    implementation; the trainer routes every batch submission through it.
    """

    POLICIES = ("lpt", "arrival")

    def __init__(self, population_size: int, policy: str = "lpt", width_multiple: int = 1):
        """``width_multiple``: the engine's mesh-tiling constraint — the
        sharded engine compiles chunks whose width is a multiple of the POP-
        AXIS EXTENT (padding lanes included; on a 2-D ``("pop", "model")``
        mesh that is the number of pop slices, NOT the device count), so
        waste accounting must round up the same way. Prefer
        :meth:`for_engine`, which reads the extent off the engine."""
        if policy not in self.POLICIES:
            raise ValueError(f"unknown schedule policy {policy!r} (use {self.POLICIES})")
        self.population_size = max(1, int(population_size))
        self.policy = policy
        self.width_multiple = max(1, int(width_multiple))

    @classmethod
    def for_engine(cls, engine, policy: str = "lpt") -> "FleetScheduler":
        """Scheduler matched to a FAT engine's chunking: population width
        from the engine, width rounding from its pop-axis extent
        (``num_shards``; 1 for the vmap/serial engines)."""
        return cls(
            engine.population_size,
            policy=policy,
            width_multiple=getattr(engine, "num_shards", 1),
        )

    def _order(self, costs: Sequence[float], policy: str) -> list[int]:
        n = len(costs)
        if policy == "arrival":
            return list(range(n))
        # LPT: descending cost, stable index tiebreak for determinism
        return sorted(range(n), key=lambda i: (-float(costs[i]), i))

    def schedule(self, costs: Sequence[float], policy: str | None = None) -> FleetSchedule:
        policy = policy or self.policy
        order = self._order(costs, policy)
        size = self.population_size
        chunks = []
        for lo in range(0, len(order), size):
            idx = tuple(order[lo : lo + size])
            # the engine pads a partial final chunk to full width (its chunk
            # width is min(population_size, n), rounded up to the device
            # tiling — mirror that so waste accounting matches what runs)
            width = min(size, len(order)) if len(order) else size
            width = round_up_to_multiple(width, self.width_multiple)
            chunks.append(
                ScheduledChunk(
                    indices=idx,
                    costs=tuple(float(costs[i]) for i in idx),
                    width=width,
                )
            )
        return FleetSchedule(
            order=tuple(order),
            chunks=tuple(chunks),
            policy=policy,
            population_size=size,
        )

    def report(self, costs: Sequence[float]) -> dict:
        """Waste accounting of this scheduler's policy vs arrival order —
        surfaced by ``EFAT.execute_plan`` and the ``--sharded`` bench."""
        mine = self.schedule(costs)
        arrival = self.schedule(costs, policy="arrival")
        reduction = arrival.wasted_steps - mine.wasted_steps
        return dict(
            policy=self.policy,
            population_size=self.population_size,
            jobs=len(costs),
            chunks=len(mine.chunks),
            wasted_steps=mine.wasted_steps,
            arrival_wasted_steps=arrival.wasted_steps,
            wasted_steps_reduction=reduction,
            span_steps=mine.span_steps,
            arrival_span_steps=arrival.span_steps,
        )
