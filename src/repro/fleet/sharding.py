"""Device-sharded population FAT — ``shard_map`` over the "pop" mesh axis,
composed with tensor-parallel member-param layout over a "model" axis.

``PopulationFATEngine`` (repro.train.population) turns N fault maps into one
vmap+scan program on a single device. This module makes the population axis
a *device* axis: the same run bodies wrapped in ``shard_map`` over the
leading "pop" axis of a fleet mesh (``repro.launch.mesh.make_pop_mesh`` /
``make_fleet_mesh``), so each pop slice runs a sub-population of
``fit_batch`` / ``steps_to_constraint_batch`` / ``evaluate_batch``.

On a 2-D ``("pop", "model")`` mesh each pop slice is itself a
tensor-parallel sub-mesh: per-member ``(params, opt_state)`` are laid out
over the "model" axis with the logical-axis rules from
``repro.launch.sharding`` (``make_rules_for_mesh`` with "pop" reserved), so
a fleet of large models trains without replicating full weights per member.
The "pop" axis is *manual* (``shard_map``); the "model" axis is *auto* —
left to the compiler, steered by ``with_sharding_constraint`` at the layout
points the run bodies expose.

Design invariants
-----------------
* **Identical math.** The engine wraps the *same* un-jitted run bodies
  (``_fit_run`` / ``_steps_run`` / ``_eval_run``) the vmap engine jits; a
  member's trajectory depends only on its own (mask, budget) and the shared
  batch stream, so serial, vmap, 1-D shard_map and 2-D shard_map produce
  identical steps-to-constraint and resilience tables (pinned in
  tests/test_fleet.py, including a forced-8-device 4x2 subprocess test).
  With the default ``compute="gathered"`` this holds *bitwise*: member
  state is stored "model"-sharded between steps but gathered to full-shape
  replicas for every update/eval, so every matmul runs at exactly the
  single-device shapes (XLA changes accumulation blocking with operand
  shapes, so sharded-compute GEMMs are NOT bit-identical). ``"sharded"``
  leaves compute under the stored layout — true tensor-parallel math, HBM
  *and* FLOPs sharded, results equal to float tolerance instead of bitwise.
* **Population -> device mapping.** A chunk of ``population_size`` members
  is padded to a multiple of the pop-axis extent and split contiguously:
  pop slice d takes members ``[d*k, (d+1)*k)`` of the chunk. Padding
  members are zero-budget (fit) or duplicates (steps) and are sliced off
  the results — they never leak out.
* **Per-shard early exit.** ``fit_batch``'s fori_loop bound is
  ``max(budgets)`` *of the local shard*, and ``steps_to_constraint_batch``'s
  while_loop exits when the local sub-population has crossed — each pop
  slice stops as soon as its own members are done. (No cross-slice
  collectives run inside the loops, so divergent per-slice trip counts are
  legal SPMD; "model"-axis collectives stay *inside* a slice, whose devices
  always agree on the trip count.)

CPU testing: export ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
before the first jax import (see tests/test_fleet.py and the CI fleet job).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.fleet.scheduler import round_up_to_multiple
from repro.launch.mesh import make_pop_mesh
from repro.launch.sharding import MeshContext, make_rules_for_mesh, resolve_spec
from repro.train.optimizer import opt_state_specs
from repro.train.population import BatchFn, PopulationFATEngine

__all__ = ["ShardedPopulationEngine"]

_is_axes_leaf = lambda a: isinstance(a, tuple) and all(
    x is None or isinstance(x, str) for x in a
)


class ShardedPopulationEngine(PopulationFATEngine):
    """PopulationFATEngine whose compiled programs run under ``shard_map``.

    Parameters (beyond the population engine's):

    mesh : a 1-D pop mesh (``make_pop_mesh``) or a 2-D ``("pop", "model")``
        fleet mesh (``make_fleet_mesh``). Default: ``make_pop_mesh()`` over
        every visible device. Any trailing non-pop axes are treated as the
        model sub-mesh of each pop slice.
    axis_name : the population axis name ("pop").
    cfg : ArchConfig used to build the tensor-parallel rules
        (``make_rules_for_mesh`` with the pop axis reserved). Required —
        together with ``param_axes`` — when the mesh has a model axis of
        extent > 1; ``mesh_rules`` overrides it with a prebuilt MeshContext.
    compute : "gathered" (default) stores member state "model"-sharded but
        gathers full-shape replicas for each update/eval — bitwise-pinned
        against the 1-D/vmap/serial engines, memory sharded. "sharded"
        leaves compute under the stored layout (true tensor-parallel math;
        equal to float tolerance, not bitwise).

    ``population_size`` is rounded up to a multiple of the pop-axis extent
    so every chunk tiles the mesh; all-healthy submissions (mode "none",
    e.g. the pretrain call) have no mask to shard and fall back to the
    parent's single-device program.
    """

    kind = "sharded"

    def __init__(
        self,
        *,
        mesh: Optional[Mesh] = None,
        axis_name: str = "pop",
        cfg: Any = None,
        mesh_rules: Optional[MeshContext] = None,
        compute: str = "gathered",
        **kwargs,
    ):
        super().__init__(**kwargs)
        self.mesh = mesh if mesh is not None else make_pop_mesh(axis=axis_name)
        if axis_name not in self.mesh.shape:
            raise ValueError(
                f"mesh axes {tuple(self.mesh.shape)} lack population axis {axis_name!r}"
            )
        if compute not in ("gathered", "sharded"):
            raise ValueError(
                f"compute must be 'gathered' or 'sharded', got {compute!r}"
            )
        self.axis_name = axis_name
        self.compute = compute
        # num_shards is the POP-AXIS EXTENT, not the device count: chunk
        # rounding, scheduler width rounding and padding all key on how many
        # pop slices exist, however many devices each slice spans.
        self.num_shards = int(self.mesh.shape[axis_name])
        self.model_axes = tuple(a for a in self.mesh.axis_names if a != axis_name)
        self.model_size = int(
            math.prod(self.mesh.shape[a] for a in self.model_axes)
        )
        if self.model_size > 1:
            if mesh_rules is not None:
                self.mesh_rules: Optional[MeshContext] = mesh_rules
            elif cfg is not None:
                self.mesh_rules = make_rules_for_mesh(
                    cfg, self.mesh, fsdp=False, reserved_axes=(axis_name,)
                )
            else:
                raise ValueError(
                    "a 2-D fleet mesh with a model axis needs tensor-parallel "
                    "rules: pass cfg= (an ArchConfig) or mesh_rules= (a "
                    "MeshContext built with the pop axis reserved)"
                )
            if self.param_axes is None:
                raise ValueError(
                    "a 2-D fleet mesh with a model axis needs param_axes= "
                    "(the logical-axes pytree mirroring the params structure, "
                    "e.g. models.model.param_specs(cfg) or "
                    "models.classifier.classifier_param_axes(cfg))"
                )
        else:
            self.mesh_rules = mesh_rules
        # chunks must tile the pop axis: round the configured width up
        self.population_size = max(
            self.num_shards,
            round_up_to_multiple(self.population_size, self.num_shards),
        )
        self.last_fit_stats: Optional[dict] = None

    # -- chunking: every chunk width is a multiple of the pop extent -------

    def _chunks(self, n: int):
        size = max(1, min(self.population_size, n))
        size = round_up_to_multiple(size, self.num_shards)
        for lo in range(0, n, size):
            yield lo, min(size, n - lo), size

    # -- member-state layout over the model axis ---------------------------
    # Only the member axis is manual (shard_map over "pop"); every other
    # mesh axis is auto, so these with_sharding_constraint calls — legal on
    # auto axes inside a partial-auto shard_map body — are what lay member
    # params/opt out over the pop slice's model sub-mesh.

    @property
    def _model_sharded(self) -> bool:
        return self.model_size > 1

    def _member_sharding(self, axes, leaf):
        """NamedSharding for one member-stacked leaf: member axis replicated
        (it is manual / already local), trailing dims per the model rules."""
        spec = resolve_spec(tuple(axes), leaf.shape[1:], self.mesh_rules)
        return NamedSharding(self.mesh, P(None, *tuple(spec)))

    def _apply_member_specs(self, axes_tree, tree):
        return jax.tree_util.tree_map(
            lambda axes, leaf: jax.lax.with_sharding_constraint(
                leaf, self._member_sharding(axes, leaf)
            ),
            axes_tree,
            tree,
            is_leaf=_is_axes_leaf,
        )

    def _replicate_tree(self, tree):
        rep = NamedSharding(self.mesh, P())
        return jax.tree_util.tree_map(
            lambda leaf: jax.lax.with_sharding_constraint(leaf, rep), tree
        )

    # hooks called by the shared run bodies (repro.train.population)

    def _constrain_member_state(self, params_pop, opt_pop):
        if not self._model_sharded:
            return params_pop, opt_pop
        if self.compute == "gathered":
            # pin an explicitly replicated point between the update math and
            # the sharded store: GSPMD propagates shardings backward, and
            # without this the stored layout leaks into the preceding GEMMs,
            # re-blocking their accumulation (one-ulp drift vs the 1-D path)
            params_pop = self._replicate_tree(params_pop)
            opt_pop = self._replicate_tree(opt_pop)
        return (
            self._apply_member_specs(self.param_axes, params_pop),
            self._apply_member_specs(opt_state_specs(self.param_axes), opt_pop),
        )

    def _gather_member_state(self, params_pop, opt_pop):
        if not self._model_sharded or self.compute == "sharded":
            return params_pop, opt_pop
        return self._replicate_tree(params_pop), self._replicate_tree(opt_pop)

    def _gather_member_params(self, params_pop):
        if not self._model_sharded or self.compute == "sharded":
            return params_pop
        return self._replicate_tree(params_pop)

    def _constrain_batch(self, tree):
        # batches / masks / the eval stack enter the math replicated along
        # the model axis (Megatron-style: data replicated, weights sharded).
        # Without this the compiler is free to pick model-sharded input
        # layouts, which turns grad contractions into partial-sum psums —
        # numerically fine but not bitwise against the 1-D path.
        if not self._model_sharded:
            return tree
        return self._replicate_tree(tree)

    # -- program wrappers: jit(shard_map(run)) over the pop axis -----------

    def _shard(self, run, in_specs):
        return jax.jit(
            shard_map(
                run,
                mesh=self.mesh,
                in_specs=in_specs,
                out_specs=P(self.axis_name),
                check_rep=False,  # per-shard loop trip counts legitimately diverge
                # trailing mesh axes stay under compiler (GSPMD) control so the
                # model rules can shard member state within each pop slice
                auto=frozenset(self.model_axes),
            )
        )

    def _make_fit(self, batch_fn: BatchFn, mode: str):
        run = self._fit_run(batch_fn, mode)
        if mode == "none":  # all-healthy population: ok is None, nothing to shard
            return jax.jit(run)
        a = self.axis_name
        # (params0 replicated, ok_pop sharded, budgets sharded)
        return self._shard(run, (P(), P(a), P(a)))

    def _make_steps(self, batch_fn: BatchFn, mode: str):
        run = self._steps_run(batch_fn, mode)
        a = self.axis_name
        # (params0 replicated, ok_pop sharded, constraint, max_steps)
        return self._shard(run, (P(), P(a), P(), P()))

    def _make_eval(self, mode: str):
        run = self._eval_run(mode)
        if mode == "none":
            return jax.jit(run)
        a = self.axis_name
        return self._shard(run, (P(a), P(a)))

    # -- resident-memory accounting ----------------------------------------

    def _record_fit_output(self, trained, keep: int, width: int) -> None:
        """Per-device resident bytes of the raw member-stacked fit output —
        the proof that member params live "model"-sharded within each pop
        slice instead of replicated (surfaced by efat_bench.py --mesh)."""
        leaves = jax.tree_util.tree_leaves(trained)
        if not leaves or not hasattr(leaves[0], "addressable_shards"):
            return
        dev0 = self.mesh.devices.flat[0]
        dev0_bytes = 0
        for leaf in leaves:
            dev0_bytes += sum(
                sh.data.nbytes
                for sh in leaf.addressable_shards
                if sh.device == dev0
            )
        total_bytes = sum(int(leaf.nbytes) for leaf in leaves)
        members_per_lane = max(1, width // self.num_shards)
        self.last_fit_stats = dict(
            chunk_width=width,
            members_kept=keep,
            members_per_lane=members_per_lane,
            pop_extent=self.num_shards,
            model_extent=self.model_size,
            device0_resident_bytes=int(dev0_bytes),
            per_member_resident_bytes=dev0_bytes / members_per_lane,
            per_member_total_bytes=total_bytes / width,
        )
