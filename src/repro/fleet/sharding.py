"""Device-sharded population FAT — ``shard_map`` over the "pop" mesh axis.

``PopulationFATEngine`` (repro.train.population) turns N fault maps into one
vmap+scan program on a single device. This module adds the next rung of the
ROADMAP: the same programs wrapped in ``shard_map`` over a 1-D "pop" mesh
(``repro.launch.mesh.make_pop_mesh``), so each device (or mesh slice) runs a
sub-population of ``fit_batch`` / ``steps_to_constraint_batch`` /
``evaluate_batch``. Fleet-scale Step-1 sweeps and Step-4 plan execution then
scale near-linearly with device count.

Design invariants
-----------------
* **Identical math.** The sharded engine wraps the *same* un-jitted run
  bodies (``_fit_run`` / ``_steps_run`` / ``_eval_run``) the vmap engine
  jits; a member's trajectory depends only on its own (mask, budget) and the
  shared batch stream, so serial, vmap and shard_map produce identical
  steps-to-constraint and resilience tables (pinned in tests/test_fleet.py).
* **Population -> device mapping.** A chunk of ``population_size`` members is
  padded to a multiple of the mesh size and split contiguously: device d
  takes members ``[d*k, (d+1)*k)`` of the chunk. Padding members are
  zero-budget (fit) or duplicates (steps) and are sliced off the results —
  they never leak out.
* **Per-shard early exit.** ``fit_batch``'s fori_loop bound is
  ``max(budgets)`` *of the local shard*, and ``steps_to_constraint_batch``'s
  while_loop exits when the local sub-population has crossed — each device
  stops as soon as its own members are done, which the single-device engine
  cannot do. (No collectives run inside the loops, so divergent per-device
  trip counts are legal SPMD.)

CPU testing: export ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
before the first jax import (see tests/test_fleet.py and the CI fleet job).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.mesh import make_pop_mesh
from repro.train.population import BatchFn, PopulationFATEngine

__all__ = ["ShardedPopulationEngine"]


class ShardedPopulationEngine(PopulationFATEngine):
    """PopulationFATEngine whose compiled programs run under ``shard_map``.

    Parameters (beyond the population engine's): ``mesh`` — a 1-D mesh whose
    single axis is the population axis (default: ``make_pop_mesh()`` over
    every visible device); ``axis_name`` — that axis' name ("pop").

    ``population_size`` is rounded up to a multiple of the mesh size so every
    chunk tiles the mesh exactly; all-healthy submissions (mode "none", e.g.
    the pretrain call) have no mask to shard and fall back to the parent's
    single-device program.
    """

    kind = "sharded"

    def __init__(
        self,
        *,
        mesh: Optional[Mesh] = None,
        axis_name: str = "pop",
        **kwargs,
    ):
        super().__init__(**kwargs)
        self.mesh = mesh if mesh is not None else make_pop_mesh(axis=axis_name)
        if axis_name not in self.mesh.shape:
            raise ValueError(
                f"mesh axes {tuple(self.mesh.shape)} lack population axis {axis_name!r}"
            )
        self.axis_name = axis_name
        self.num_shards = int(self.mesh.shape[axis_name])
        # chunks must tile the mesh: round the configured width up
        self.population_size = max(
            self.num_shards,
            -(-self.population_size // self.num_shards) * self.num_shards,
        )

    # -- chunking: every chunk width is a multiple of the mesh size --------

    def _chunks(self, n: int):
        size = max(1, min(self.population_size, n))
        size = -(-size // self.num_shards) * self.num_shards
        for lo in range(0, n, size):
            yield lo, min(size, n - lo), size

    # -- program wrappers: jit(shard_map(run)) over the pop axis -----------

    def _shard(self, run, in_specs):
        return jax.jit(
            shard_map(
                run,
                mesh=self.mesh,
                in_specs=in_specs,
                out_specs=P(self.axis_name),
                check_rep=False,  # per-shard loop trip counts legitimately diverge
            )
        )

    def _make_fit(self, batch_fn: BatchFn, mode: str):
        run = self._fit_run(batch_fn, mode)
        if mode == "none":  # all-healthy population: ok is None, nothing to shard
            return jax.jit(run)
        a = self.axis_name
        # (params0 replicated, ok_pop sharded, budgets sharded)
        return self._shard(run, (P(), P(a), P(a)))

    def _make_steps(self, batch_fn: BatchFn, mode: str):
        run = self._steps_run(batch_fn, mode)
        a = self.axis_name
        # (params0 replicated, ok_pop sharded, constraint, max_steps)
        return self._shard(run, (P(), P(a), P(), P()))

    def _make_eval(self, mode: str):
        run = self._eval_run(mode)
        if mode == "none":
            return jax.jit(run)
        a = self.axis_name
        return self._shard(run, (P(a), P(a)))
