"""Fleet capacity planning — size population lanes against device memory.

A population chunk holds, per member, fp32 master params plus the two AdamW
moments; with a 2-D ``("pop", "model")`` fleet mesh that state is sharded
``model_extent``-ways within each pop slice (see ``fleet/sharding.py``), so
the members a single device can hold grows linearly with the model axis.
``suggest_population_size`` turns (arch, mesh, per-device memory) into a
``population_size`` the sharded engine can run without paging — the ROADMAP
"size ``population_size`` against HBM" item, consumed by
``benchmarks/efat_bench.py --population-size auto``.

With ``reserve_kernel_vmem=True`` the planner additionally reserves the
per-lane scratch the Pallas kernels keep resident, read from the tuning
cache's recorded per-kernel VMEM footprints (:func:`kernel_vmem_reserve`)
— tuned geometry often trades bigger blocks for fewer grid steps, so the
reserve grows with the tuned table instead of assuming heuristic blocks.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh

__all__ = ["suggest_population_size", "kernel_vmem_reserve"]

# fp32 master params + fp32 AdamW m and v (repro.train.optimizer defaults;
# 'bfloat16' moment_dtype would be 4 + 2 + 2)
_DEFAULT_BYTES_PER_PARAM = 12
# no backend-reported limit (host CPU backends): assume a v5e-class 16 GiB
_FALLBACK_DEVICE_BYTES = 16 << 30


def _device_memory_bytes(mesh: Optional[Mesh]) -> int:
    dev = mesh.devices.flat[0] if mesh is not None else jax.devices()[0]
    try:
        stats = dev.memory_stats()
    except Exception:
        stats = None
    if stats and stats.get("bytes_limit"):
        return int(stats["bytes_limit"])
    return _FALLBACK_DEVICE_BYTES


def kernel_vmem_reserve(cache=None) -> int:
    """Total per-lane VMEM the tuned kernels keep resident, in bytes.

    Sums the tuning cache's recorded per-kernel maximum VMEM footprints
    (``TuningCache.vmem_footprints()``) — the worst tuned block geometry each
    kernel may pick. An empty or missing cache contributes 0, matching the
    "empty cache == heuristic behaviour" contract. ``cache=None`` reads the
    process-global cache (default table + env overlay).
    """
    if cache is None:
        from repro.tune.cache import get_tuning_cache

        cache = get_tuning_cache()
    return int(sum(cache.vmem_footprints().values()))


def suggest_population_size(
    cfg,
    mesh: Optional[Mesh] = None,
    *,
    hbm_bytes: Optional[int] = None,
    headroom: float = 0.6,
    bytes_per_param: int = _DEFAULT_BYTES_PER_PARAM,
    max_members_per_lane: int = 64,
    reserve_kernel_vmem: bool = False,
    tuning_cache=None,
) -> int:
    """Largest population chunk width the mesh can hold resident.

    Parameters
    ----------
    cfg : ArchConfig — ``cfg.param_count()`` sets the per-member state size.
    mesh : fleet mesh (1-D pop or 2-D pop x model). None = a single lane on
        the default device (the vmap engine's situation).
    hbm_bytes : per-device memory budget; default: the backend's reported
        ``bytes_limit`` when available, else 16 GiB.
    headroom : fraction of ``hbm_bytes`` the member state may use — the rest
        is activations/gradients for the in-flight update and XLA scratch.
    bytes_per_param : resident optimizer+param bytes per parameter per
        member (default fp32 params + fp32 AdamW moments = 12).
    max_members_per_lane : cap on members per pop slice (compile-shape and
        latency guard, matching ``population_size`` chunking semantics).
    reserve_kernel_vmem : opt-in — subtract :func:`kernel_vmem_reserve` from
        the member-state budget before sizing, so tuned kernel geometry
        (bigger resident blocks) shrinks the suggestion instead of paging.
    tuning_cache : explicit ``TuningCache`` for the reserve; None reads the
        process-global cache. Ignored unless ``reserve_kernel_vmem=True``.

    Returns a population size that is a positive multiple of the pop-axis
    extent (the sharded engine would round it up anyway). Raises ValueError
    when even ONE member per lane exceeds the budget — the model needs a
    bigger model axis, not a smaller population.
    """
    if hbm_bytes is None:
        hbm_bytes = _device_memory_bytes(mesh)
    if hbm_bytes <= 0:
        raise ValueError(f"hbm_bytes must be positive, got {hbm_bytes}")
    if not 0.0 < headroom <= 1.0:
        raise ValueError(f"headroom must be in (0, 1], got {headroom}")
    if reserve_kernel_vmem:
        reserve = kernel_vmem_reserve(tuning_cache)
        if reserve >= hbm_bytes:
            raise ValueError(
                f"kernel VMEM reserve {reserve} bytes exceeds the "
                f"{hbm_bytes}-byte device budget"
            )
        hbm_bytes = hbm_bytes - reserve

    pop_extent, model_extent = 1, 1
    if mesh is not None:
        sizes = dict(mesh.shape)
        pop_extent = int(sizes.pop("pop", 1))
        model_extent = int(math.prod(sizes.values())) if sizes else 1

    member_bytes = int(cfg.param_count()) * int(bytes_per_param)
    # the model axis shards each member's resident state within a pop slice
    per_device_member_bytes = max(1, member_bytes // model_extent)
    budget = int(hbm_bytes * headroom)
    members_per_lane = budget // per_device_member_bytes
    if members_per_lane < 1:
        raise ValueError(
            f"one member needs {per_device_member_bytes / 2**30:.2f} GiB resident "
            f"({member_bytes / 2**30:.2f} GiB / model extent {model_extent}) but the "
            f"budget is {budget / 2**30:.2f} GiB ({headroom:.0%} of "
            f"{hbm_bytes / 2**30:.2f} GiB) — grow the mesh's model axis"
        )
    members_per_lane = min(int(members_per_lane), int(max_members_per_lane))
    return members_per_lane * pop_extent
