"""repro.fleet — fleet-scale execution layer between the FAT engines and the
launch/serve stack.

Three cooperating modules (see README.md in this directory):

* :mod:`repro.fleet.sharding` — :class:`ShardedPopulationEngine`, the
  population FAT programs under ``shard_map`` over a "pop" mesh axis (one
  sub-population per device).
* :mod:`repro.fleet.scheduler` — :class:`FleetScheduler`, budget-aware
  (LPT) packing of retraining jobs into population chunks, with
  ``wasted_steps`` accounting.
* :mod:`repro.fleet.serve` — :class:`FleetServeEngine`, one vmapped serving
  engine advancing N faulty chips' deployed models a token per dispatch.
"""
from repro.fleet.scheduler import FleetSchedule, FleetScheduler, ScheduledChunk
from repro.fleet.serve import FleetGenerateResult, FleetServeEngine
from repro.fleet.sharding import ShardedPopulationEngine

__all__ = [
    "FleetSchedule",
    "FleetScheduler",
    "ScheduledChunk",
    "FleetGenerateResult",
    "FleetServeEngine",
    "ShardedPopulationEngine",
]
