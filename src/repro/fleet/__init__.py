"""repro.fleet — fleet-scale execution layer between the FAT engines and the
launch/serve stack.

Three cooperating modules (see README.md in this directory):

* :mod:`repro.fleet.sharding` — :class:`ShardedPopulationEngine`, the
  population FAT programs under ``shard_map`` over the "pop" axis of a 1-D
  pop mesh or a 2-D ``("pop", "model")`` fleet mesh (one sub-population per
  pop slice; member params sharded over the slice's model sub-mesh).
* :mod:`repro.fleet.scheduler` — :class:`FleetScheduler`, budget-aware
  (LPT) packing of retraining jobs into population chunks, with
  ``wasted_steps`` accounting keyed on the pop-axis extent.
* :mod:`repro.fleet.capacity` — :func:`suggest_population_size`, sizing
  population lanes against per-device memory from param/opt bytes.
* :mod:`repro.fleet.serve` — :class:`FleetServeEngine`, one vmapped serving
  engine advancing N faulty chips' deployed models a token per dispatch, and
  :class:`ShardedFleetServeEngine`, continuous-batch fleet serving under
  ``shard_map`` over the pop mesh — one ragged request stream and paged-KV
  slot table per chip.
"""
from repro.fleet.capacity import suggest_population_size
from repro.fleet.scheduler import FleetSchedule, FleetScheduler, ScheduledChunk
from repro.fleet.serve import (
    FleetGenerateResult,
    FleetServeEngine,
    ShardedFleetServeEngine,
)
from repro.fleet.sharding import ShardedPopulationEngine

__all__ = [
    "FleetSchedule",
    "FleetScheduler",
    "ScheduledChunk",
    "FleetGenerateResult",
    "FleetServeEngine",
    "ShardedFleetServeEngine",
    "ShardedPopulationEngine",
    "suggest_population_size",
]
