"""Multi-chip serving — vmapped and shard_mapped engines for a whole fleet.

The deployment half of eFAT produces one fault-aware artifact per
retraining job, each deployed on chips with their own fault maps. Evaluating
the deployed fleet with per-chip ``ServeEngine`` instances costs N Python
generate loops of one-dispatch-per-token each. But the engines differ only
in (params, FaultContext) — the same population trick the training side
uses: ``FleetServeEngine`` stacks N chips' params and masks and vmaps the
fused sampling+decode step (``repro.serve.engine.make_sample_decode``) over
the chip axis, so the *entire fleet* advances one token per dispatch.

Semantics match per-chip serving exactly: greedy decoding is argmax per
chip (independent of the sampling key), so temperature=0.0 reproduces each
chip's own ``ServeEngine`` token-for-token (pinned in tests/test_fleet.py);
with temperature > 0 each chip samples from its own key stream (the fleet
key is split once per chip).

``FleetServeEngine`` shares one prompt batch across chips — the
fleet-evaluation use case is "run the same prompt set through every
deployed model and compare". ``ShardedFleetServeEngine`` is the
production-shaped tier: chips map onto the devices of a "pop" mesh
(``repro.launch.mesh.make_pop_mesh``, mirroring the training-side
``ShardedPopulationEngine``), and every chip consumes its *own* ragged
request stream through its own continuous-batch slot table over a paged KV
cache — the masked form of the same fused step, under ``shard_map``, so
one dispatch advances every chip's in-flight slots and no chip waits for
another chip's prompts. Greedy per-chip outputs are pinned against
per-chip ``ContinuousBatchingEngine`` runs (tests/test_serve_continuous.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.masking import FaultContext, healthy, stack_contexts
from repro.launch.mesh import make_pop_mesh
from repro.models import model as M
from repro.serve.continuous import (
    Request,
    RequestOutput,
    ServeStats,
    _SlotTable,
    prefill_to_chain,
)
from repro.serve.engine import make_sample_decode
from repro.serve.kvcache import DEFAULT_PAGE_SIZE, PageAllocator, page_bytes
from repro.train.population import _stack_trees

__all__ = ["FleetGenerateResult", "FleetServeEngine", "ShardedFleetServeEngine"]


@dataclass
class FleetGenerateResult:
    tokens: jax.Array  # (N, B, prompt + generated)
    logprobs: jax.Array  # (N, B, generated)

    def chip(self, i: int):
        """Per-chip view (tokens, logprobs) — shaped like ServeEngine output."""
        return self.tokens[i], self.logprobs[i]


class FleetServeEngine:
    """Serve N chips' (params, FaultContext) pairs as one batched program.

    ``params_list[i]`` are chip i's shipped (FAP-masked) weights and
    ``ctxs[i]`` its fault context (None/healthy for a fault-free chip —
    mixed fleets are fine; ``stack_contexts`` upcasts healthy members).
    All chips share one model config and prompt batch.
    """

    def __init__(
        self,
        cfg,
        params_list: Sequence,
        ctxs: Optional[Sequence[Optional[FaultContext]]] = None,
        *,
        max_len: int = 4096,
    ):
        n = len(params_list)
        if n == 0:
            raise ValueError("FleetServeEngine needs at least one chip")
        ctxs = list(ctxs) if ctxs is not None else [healthy()] * n
        if len(ctxs) != n:
            raise ValueError(f"{n} params sets but {len(ctxs)} fault contexts")
        self.cfg = cfg
        self.max_len = max_len
        self.num_chips = n
        self.params = _stack_trees(list(params_list))
        self.ctx = stack_contexts([c or healthy() for c in ctxs])
        # vmap axis for the context: the ok mask batches over chips when any
        # chip is faulty; an all-healthy fleet carries no mask at all
        ctx_ax = (
            None
            if self.ctx.ok is None
            else FaultContext(ok=0, mode=self.ctx.mode)  # type: ignore[arg-type]
        )
        self._prefill = jax.jit(
            jax.vmap(
                lambda p, b, ctx: M.prefill(p, b, cfg, ctx, cache_len=max_len),
                in_axes=(0, None, ctx_ax),
            )
        )
        # cur/cache/keys are re-bound from each dispatch's outputs in the
        # generate loop — donated so the fleet's stacked KV caches alias in
        # place (repro.analysis DON001); params/ctx are reused, not donated
        self._sample_decode = jax.jit(
            jax.vmap(make_sample_decode(cfg), in_axes=(0, 0, 0, 0, ctx_ax, None)),
            donate_argnums=(1, 2, 3),
        )

    def generate(
        self,
        prompts: jax.Array,  # (B, S) token ids, shared by every chip
        *,
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
    ) -> FleetGenerateResult:
        logits, cache = self._prefill(self.params, {"tokens": prompts}, self.ctx)
        cur = logits  # (N, B, V)
        key = key if key is not None else jax.random.PRNGKey(0)
        keys = jax.random.split(key, self.num_chips)  # one sample stream per chip
        temp = jnp.float32(temperature)
        toks = [jnp.broadcast_to(prompts[None], (self.num_chips,) + prompts.shape)]
        lps = []
        for _ in range(max_new_tokens):
            nxt, tok_lp, cur, cache, keys = self._sample_decode(
                self.params, cur, cache, keys, self.ctx, temp
            )
            lps.append(tok_lp)
            toks.append(nxt[:, :, None])
        return FleetGenerateResult(
            tokens=jnp.concatenate(toks, axis=2), logprobs=jnp.stack(lps, axis=2)
        )


class ShardedFleetServeEngine:
    """Sharded, ragged fleet serving: chips → devices, streams → slot tables.

    Each chip ``c`` runs its own continuous-batch slot table (paged KV
    cache, admission on arrival, retirement on EOS/budget — the same loop
    as ``repro.serve.continuous.ContinuousBatchingEngine``) over its own
    request stream; ONE ``shard_map``-over-the-pop-mesh dispatch advances
    every chip's in-flight slots a token. The chip axis tiles the mesh
    (``len(params_list)`` must be a multiple of the pop extent; chips
    beyond the extent vmap within a device, mirroring how the training-side
    ``ShardedPopulationEngine`` packs sub-populations into pop slices).

    Greedy decoding is argmax per slot, so every chip's outputs reproduce a
    per-chip ``ContinuousBatchingEngine`` on the same stream; with
    temperature > 0 each chip consumes its own key stream (the fleet key is
    split once per chip), so runs are reproducible per chip and chips'
    samples are independent.
    """

    def __init__(
        self,
        cfg,
        params_list: Sequence,
        ctxs: Optional[Sequence[Optional[FaultContext]]] = None,
        *,
        mesh=None,
        axis_name: str = "pop",
        num_slots: int = 4,
        page_size: int = DEFAULT_PAGE_SIZE,
        num_pages: int = 128,
        max_pages_per_seq: Optional[int] = None,
        pad_id: int = 0,
    ):
        n = len(params_list)
        if n == 0:
            raise ValueError("ShardedFleetServeEngine needs at least one chip")
        if cfg.has_ssm:
            raise ValueError(
                f"continuous fleet serving supports attention families only; "
                f"{cfg.family!r} carries unpaged SSM state"
            )
        if cfg.is_encoder:
            raise ValueError("encoder-only arch has no decode path")
        ctxs = list(ctxs) if ctxs is not None else [healthy()] * n
        if len(ctxs) != n:
            raise ValueError(f"{n} params sets but {len(ctxs)} fault contexts")
        if mesh is None:
            # largest pop extent that both fits the backend and tiles the fleet
            ndev = len(jax.devices())
            extent = max(d for d in range(1, min(n, ndev) + 1) if n % d == 0)
            mesh = make_pop_mesh(extent, axis=axis_name)
        if axis_name not in mesh.shape:
            raise ValueError(
                f"mesh axes {tuple(mesh.shape)} lack population axis {axis_name!r}"
            )
        extent = int(mesh.shape[axis_name])
        if n % extent != 0:
            raise ValueError(
                f"{n} chips don't tile the {extent}-slice {axis_name!r} mesh; "
                "pad the fleet or pass a mesh whose pop extent divides it"
            )
        self.cfg = cfg
        self.mesh = mesh
        self.axis_name = axis_name
        self.num_chips = n
        self.num_slots = num_slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_pages_per_seq = max_pages_per_seq or (num_pages - 1)
        self.pad_id = pad_id
        self._page_bytes = page_bytes(cfg, page_size)
        self.params_list = list(params_list)
        self.ctxs = [c or healthy() for c in ctxs]
        self.params = _stack_trees(self.params_list)
        self.ctx = stack_contexts(self.ctxs)

        sample = make_sample_decode(cfg, pad_id=pad_id)
        mode = self.ctx.mode
        pa = P(axis_name)
        if self.ctx.ok is None:
            hctx = healthy()

            def chip_step(p, cur, cache, key, temp, eos, active, remaining):
                return sample(
                    p, cur, cache, key, hctx, temp,
                    active=active, eos_id=eos, remaining=remaining,
                )

            vmapped = jax.vmap(chip_step, in_axes=(0, 0, 0, 0, None, None, 0, 0))
            in_specs = (pa, pa, pa, pa, P(), P(), pa, pa)
            donate = (1, 2, 3, 6, 7)  # cur, cache, keys, active, remaining
        else:

            def chip_step(p, cur, cache, key, ok, temp, eos, active, remaining):
                return sample(
                    p, cur, cache, key, FaultContext(ok=ok, mode=mode), temp,
                    active=active, eos_id=eos, remaining=remaining,
                )

            vmapped = jax.vmap(chip_step, in_axes=(0, 0, 0, 0, 0, None, None, 0, 0))
            in_specs = (pa, pa, pa, pa, pa, P(), P(), pa, pa)
            donate = (1, 2, 3, 7, 8)  # cur, cache, keys, active, remaining
        # the serve loop re-binds every donated operand from the previous
        # dispatch (host copies of emitted/active are taken synchronously
        # before the next call), so the sharded page pools alias in place
        # (repro.analysis DON001); params and the stacked ok masks are
        # reused across dispatches and stay undonated
        self._step = jax.jit(
            shard_map(
                vmapped,
                mesh=self.mesh,
                in_specs=in_specs,
                out_specs=(pa,) * 7,
                check_rep=False,
            ),
            donate_argnums=donate,
        )
        self._prefill_admit = jax.jit(
            self._prefill_admit_fn,
            static_argnames=("chain",),
            donate_argnums=(3, 4, 5, 6),
        )

    # -- jitted admission: prefill one chip's request, splice into its slot --

    def _prefill_admit_fn(
        self, params_c, tokens, ctx_c, cache, cur, active, remaining,
        chip, slot, pids, budget, *, chain
    ):
        plen = tokens.shape[1]
        logits, kc, vc = prefill_to_chain(
            self.cfg, params_c, tokens, ctx_c, page_size=self.page_size, chain=chain
        )
        kc = jnp.moveaxis(kc, 1, 0)
        vc = jnp.moveaxis(vc, 1, 0)
        row = jnp.zeros((self.max_pages_per_seq,), jnp.int32).at[:chain].set(pids)
        cache = dict(
            # advanced indices (chip, pids) around the layer slice put the
            # chain axis first — kc/vc are moveaxis'd to match
            k_pages=cache["k_pages"].at[chip, :, pids].set(kc.astype(cache["k_pages"].dtype)),
            v_pages=cache["v_pages"].at[chip, :, pids].set(vc.astype(cache["v_pages"].dtype)),
            block_tables=cache["block_tables"].at[chip, slot].set(row),
            seq_lens=cache["seq_lens"].at[chip, slot].set(plen),
        )
        cur = cur.at[chip, slot].set(logits[0].astype(cur.dtype))
        active = active.at[chip, slot].set(True)
        remaining = remaining.at[chip, slot].set(budget)
        return cache, cur, active, remaining

    # -- the fleet serve loop ------------------------------------------------

    def serve(
        self,
        streams: Sequence[Sequence[Request]],
        *,
        temperature: float = 0.0,
        eos_id: Optional[int] = None,
        key: Optional[jax.Array] = None,
    ) -> tuple[list[dict[int, RequestOutput]], ServeStats]:
        """Serve one ragged request stream per chip to completion.

        Returns (per-chip outputs-by-rid, fleet-level stats). Stats count
        fused dispatches — the whole fleet advances per dispatch, so the
        total is driven by the busiest chip, not the sum over chips."""
        if len(streams) != self.num_chips:
            raise ValueError(f"{self.num_chips} chips but {len(streams)} request streams")
        stats = ServeStats(
            num_slots=self.num_chips * self.num_slots, page_size=self.page_size
        )
        allocs = [PageAllocator(self.num_pages, self.page_size) for _ in range(self.num_chips)]
        tables = [
            _SlotTable(list(s), self.num_slots, allocs[c], self.max_pages_per_seq)
            for c, s in enumerate(streams)
        ]

        N, S, V = self.num_chips, self.num_slots, self.cfg.vocab_size
        dtype = jnp.dtype(self.cfg.dtype)
        one = M.init_paged_cache(
            self.cfg, self.num_pages, self.page_size, S, self.max_pages_per_seq
        )
        cache = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (N,) + x.shape).copy(), one
        )
        cur = jnp.zeros((N, S, V), dtype)
        active = jnp.zeros((N, S), bool)
        remaining = jnp.zeros((N, S), jnp.int32)
        key = key if key is not None else jax.random.PRNGKey(0)
        keys = jax.random.split(key, N)  # one sample stream per chip
        temp = jnp.float32(temperature)
        eos = jnp.asarray(-1 if eos_id is None else eos_id, jnp.int32)

        clock = 0
        while not all(t.done for t in tables):
            for c, table in enumerate(tables):
                while True:
                    adm = table.pop_admission(clock)
                    if adm is None:
                        break
                    slot, r, pages = adm
                    cache, cur, active, remaining = self._prefill_admit(
                        self.params_list[c],
                        jnp.asarray(r.tokens, jnp.int32)[None],
                        self.ctxs[c], cache, cur, active, remaining,
                        jnp.asarray(c, jnp.int32),
                        jnp.asarray(slot, jnp.int32),
                        jnp.asarray(pages, jnp.int32),
                        jnp.asarray(r.max_new_tokens, jnp.int32),
                        chain=len(pages),
                    )
                    table.outputs_admitted[r.rid] = clock
                    stats.prefill_dispatches += 1
                    stats.admitted += 1
            pages_in_use = sum(a.pages_in_use for a in allocs)
            stats.peak_resident_kv_bytes = max(
                stats.peak_resident_kv_bytes, pages_in_use * self._page_bytes
            )
            if not any(t.active.any() for t in tables):
                arrivals = [t.next_arrival() for t in tables if t.next_arrival() is not None]
                assert arrivals, "no active slots and no pending arrivals"
                clock = max(clock + 1, min(arrivals))
                continue

            n_active = int(sum(t.active.sum() for t in tables))
            args = (self.params, cur, cache, keys)
            if self.ctx.ok is not None:
                args += (self.ctx.ok,)
            emitted, tok_lp, cur, cache, keys, active, remaining = self._step(
                *args, temp, eos, active, remaining
            )
            clock += 1
            stats.decode_dispatches += 1
            stats.emitted_tokens += n_active
            stats.active_slot_steps += n_active
            stats.kv_byte_steps += pages_in_use * self._page_bytes
            em = np.asarray(emitted)
            lp = np.asarray(tok_lp)
            ac = np.asarray(active)
            for c, table in enumerate(tables):
                table.record_step(em[c], lp[c], ac[c], clock, eos_id=eos_id)
        # peak residency is exact from the per-round samples: pages only
        # grow at admission (sampled) and shrink at retirement
        return [t.outputs for t in tables], stats
