"""Multi-chip serving — one vmapped engine serving a whole fleet's models.

The deployment half of eFAT produces one fault-aware artifact per
retraining job, each deployed on chips with their own fault maps. Evaluating
the deployed fleet with per-chip ``ServeEngine`` instances costs N Python
generate loops of one-dispatch-per-token each. But the engines differ only
in (params, FaultContext) — the same population trick the training side
uses: ``FleetServeEngine`` stacks N chips' params and masks and vmaps the
fused sampling+decode step (``repro.serve.engine.make_sample_decode``) over
the chip axis, so the *entire fleet* advances one token per dispatch.

Semantics match per-chip serving exactly: greedy decoding is argmax per
chip (independent of the sampling key), so temperature=0.0 reproduces each
chip's own ``ServeEngine`` token-for-token (pinned in tests/test_fleet.py);
with temperature > 0 each chip samples from its own key stream (the fleet
key is split once per chip).

Prompts are shared across chips — the fleet-evaluation use case is "run the
same prompt set through every deployed model and compare".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.masking import FaultContext, healthy, stack_contexts
from repro.models import model as M
from repro.serve.engine import make_sample_decode
from repro.train.population import _stack_trees

__all__ = ["FleetGenerateResult", "FleetServeEngine"]


@dataclass
class FleetGenerateResult:
    tokens: jax.Array  # (N, B, prompt + generated)
    logprobs: jax.Array  # (N, B, generated)

    def chip(self, i: int):
        """Per-chip view (tokens, logprobs) — shaped like ServeEngine output."""
        return self.tokens[i], self.logprobs[i]


class FleetServeEngine:
    """Serve N chips' (params, FaultContext) pairs as one batched program.

    ``params_list[i]`` are chip i's shipped (FAP-masked) weights and
    ``ctxs[i]`` its fault context (None/healthy for a fault-free chip —
    mixed fleets are fine; ``stack_contexts`` upcasts healthy members).
    All chips share one model config and prompt batch.
    """

    def __init__(
        self,
        cfg,
        params_list: Sequence,
        ctxs: Optional[Sequence[Optional[FaultContext]]] = None,
        *,
        max_len: int = 4096,
    ):
        n = len(params_list)
        if n == 0:
            raise ValueError("FleetServeEngine needs at least one chip")
        ctxs = list(ctxs) if ctxs is not None else [healthy()] * n
        if len(ctxs) != n:
            raise ValueError(f"{n} params sets but {len(ctxs)} fault contexts")
        self.cfg = cfg
        self.max_len = max_len
        self.num_chips = n
        self.params = _stack_trees(list(params_list))
        self.ctx = stack_contexts([c or healthy() for c in ctxs])
        # vmap axis for the context: the ok mask batches over chips when any
        # chip is faulty; an all-healthy fleet carries no mask at all
        ctx_ax = (
            None
            if self.ctx.ok is None
            else FaultContext(ok=0, mode=self.ctx.mode)  # type: ignore[arg-type]
        )
        self._prefill = jax.jit(
            jax.vmap(
                lambda p, b, ctx: M.prefill(p, b, cfg, ctx, cache_len=max_len),
                in_axes=(0, None, ctx_ax),
            )
        )
        self._sample_decode = jax.jit(
            jax.vmap(make_sample_decode(cfg), in_axes=(0, 0, 0, 0, ctx_ax, None))
        )

    def generate(
        self,
        prompts: jax.Array,  # (B, S) token ids, shared by every chip
        *,
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
    ) -> FleetGenerateResult:
        logits, cache = self._prefill(self.params, {"tokens": prompts}, self.ctx)
        cur = logits  # (N, B, V)
        key = key if key is not None else jax.random.PRNGKey(0)
        keys = jax.random.split(key, self.num_chips)  # one sample stream per chip
        temp = jnp.float32(temperature)
        toks = [jnp.broadcast_to(prompts[None], (self.num_chips,) + prompts.shape)]
        lps = []
        for _ in range(max_new_tokens):
            nxt, tok_lp, cur, cache, keys = self._sample_decode(
                self.params, cur, cache, keys, self.ctx, temp
            )
            lps.append(tok_lp)
            toks.append(nxt[:, :, None])
        return FleetGenerateResult(
            tokens=jnp.concatenate(toks, axis=2), logprobs=jnp.stack(lps, axis=2)
        )
