"""Multi-chip serving — vmapped and shard_mapped engines for a whole fleet.

The deployment half of eFAT produces one fault-aware artifact per
retraining job, each deployed on chips with their own fault maps. Evaluating
the deployed fleet with per-chip ``ServeEngine`` instances costs N Python
generate loops of one-dispatch-per-token each. But the engines differ only
in (params, FaultContext) — the same population trick the training side
uses: ``FleetServeEngine`` stacks N chips' params and masks and vmaps the
fused sampling+decode step (``repro.serve.engine.make_sample_decode``) over
the chip axis, so the *entire fleet* advances one token per dispatch.

Semantics match per-chip serving exactly: greedy decoding is argmax per
chip (independent of the sampling key), so temperature=0.0 reproduces each
chip's own ``ServeEngine`` token-for-token (pinned in tests/test_fleet.py);
with temperature > 0 each chip samples from its own key stream (the fleet
key is split once per chip).

``FleetServeEngine`` shares one prompt batch across chips — the
fleet-evaluation use case is "run the same prompt set through every
deployed model and compare". ``ShardedFleetServeEngine`` is the
production-shaped tier: chips map onto the devices of a "pop" mesh
(``repro.launch.mesh.make_pop_mesh``, mirroring the training-side
``ShardedPopulationEngine``), and every chip consumes its *own* ragged
request stream through its own continuous-batch slot table over a paged KV
cache — the masked form of the same fused step, under ``shard_map``, so
one dispatch advances every chip's in-flight slots and no chip waits for
another chip's prompts. Greedy per-chip outputs are pinned against
per-chip ``ContinuousBatchingEngine`` runs (tests/test_serve_continuous.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.masking import FaultContext, healthy, stack_contexts
from repro.launch.mesh import make_pop_mesh
from repro.models import model as M
from repro.obs.alerts import AlertEngine, AlertRule
from repro.obs.health import HealthConfig, HealthTracker
from repro.obs.hooks import PoolMonitor, RequestTracer
from repro.obs.recorder import NULL_RECORDER, Recorder
from repro.serve.bucketing import (
    DEFAULT_PREFILL_BUCKETS,
    PackItem,
    bucket_of,
    build_pack,
    chunk_step_maps,
    plan_prefill,
    validate_buckets,
)
from repro.serve.continuous import (
    Request,
    RequestOutput,
    ServeStats,
    _SlotTable,
)
from repro.serve.engine import make_sample_decode
from repro.serve.kvcache import DEFAULT_PAGE_SIZE, PageAllocator, page_bytes
from repro.train.population import _stack_trees

__all__ = ["FleetGenerateResult", "FleetServeEngine", "ShardedFleetServeEngine"]


@dataclass
class FleetGenerateResult:
    tokens: jax.Array  # (N, B, prompt + generated)
    logprobs: jax.Array  # (N, B, generated)

    def chip(self, i: int):
        """Per-chip view (tokens, logprobs) — shaped like ServeEngine output."""
        return self.tokens[i], self.logprobs[i]


class FleetServeEngine:
    """Serve N chips' (params, FaultContext) pairs as one batched program.

    ``params_list[i]`` are chip i's shipped (FAP-masked) weights and
    ``ctxs[i]`` its fault context (None/healthy for a fault-free chip —
    mixed fleets are fine; ``stack_contexts`` upcasts healthy members).
    All chips share one model config and prompt batch.
    """

    def __init__(
        self,
        cfg,
        params_list: Sequence,
        ctxs: Optional[Sequence[Optional[FaultContext]]] = None,
        *,
        max_len: int = 4096,
    ):
        n = len(params_list)
        if n == 0:
            raise ValueError("FleetServeEngine needs at least one chip")
        ctxs = list(ctxs) if ctxs is not None else [healthy()] * n
        if len(ctxs) != n:
            raise ValueError(f"{n} params sets but {len(ctxs)} fault contexts")
        self.cfg = cfg
        self.max_len = max_len
        self.num_chips = n
        self.params = _stack_trees(list(params_list))
        self.ctx = stack_contexts([c or healthy() for c in ctxs])
        # vmap axis for the context: the ok mask batches over chips when any
        # chip is faulty; an all-healthy fleet carries no mask at all
        ctx_ax = (
            None
            if self.ctx.ok is None
            else FaultContext(ok=0, mode=self.ctx.mode)  # type: ignore[arg-type]
        )
        self._prefill = jax.jit(
            jax.vmap(
                lambda p, b, ctx: M.prefill(p, b, cfg, ctx, cache_len=max_len),
                in_axes=(0, None, ctx_ax),
            )
        )
        # cur/cache/keys are re-bound from each dispatch's outputs in the
        # generate loop — donated so the fleet's stacked KV caches alias in
        # place (repro.analysis DON001); params/ctx are reused, not donated
        self._sample_decode = jax.jit(
            jax.vmap(make_sample_decode(cfg), in_axes=(0, 0, 0, 0, ctx_ax, None)),
            donate_argnums=(1, 2, 3),
        )

    def generate(
        self,
        prompts: jax.Array,  # (B, S) token ids, shared by every chip
        *,
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
    ) -> FleetGenerateResult:
        logits, cache = self._prefill(self.params, {"tokens": prompts}, self.ctx)
        cur = logits  # (N, B, V)
        key = key if key is not None else jax.random.PRNGKey(0)
        keys = jax.random.split(key, self.num_chips)  # one sample stream per chip
        temp = jnp.float32(temperature)
        toks = [jnp.broadcast_to(prompts[None], (self.num_chips,) + prompts.shape)]
        lps = []
        for _ in range(max_new_tokens):
            nxt, tok_lp, cur, cache, keys = self._sample_decode(
                self.params, cur, cache, keys, self.ctx, temp
            )
            lps.append(tok_lp)
            toks.append(nxt[:, :, None])
        return FleetGenerateResult(
            tokens=jnp.concatenate(toks, axis=2), logprobs=jnp.stack(lps, axis=2)
        )


class ShardedFleetServeEngine:
    """Sharded, ragged fleet serving: chips → devices, streams → slot tables.

    Each chip ``c`` runs its own continuous-batch slot table (paged KV
    cache, admission on arrival, retirement on EOS/budget — the same loop
    as ``repro.serve.continuous.ContinuousBatchingEngine``) over its own
    request stream; ONE ``shard_map``-over-the-pop-mesh dispatch advances
    every chip's in-flight slots a token. The chip axis tiles the mesh
    (``len(params_list)`` must be a multiple of the pop extent; chips
    beyond the extent vmap within a device, mirroring how the training-side
    ``ShardedPopulationEngine`` packs sub-populations into pop slices).

    Greedy decoding is argmax per slot, so every chip's outputs reproduce a
    per-chip ``ContinuousBatchingEngine`` on the same stream; with
    temperature > 0 each chip consumes its own key stream (the fleet key is
    split once per chip), so runs are reproducible per chip and chips'
    samples are independent.
    """

    def __init__(
        self,
        cfg,
        params_list: Sequence,
        ctxs: Optional[Sequence[Optional[FaultContext]]] = None,
        *,
        mesh=None,
        axis_name: str = "pop",
        num_slots: int = 4,
        page_size: int = DEFAULT_PAGE_SIZE,
        num_pages: int = 128,
        max_pages_per_seq: Optional[int] = None,
        pad_id: int = 0,
        prefill_buckets=DEFAULT_PREFILL_BUCKETS,
        chunk_size: Optional[int] = None,
        max_pack: int = 4,
        recorder: Optional[Recorder] = None,
        probe_every: Optional[int] = None,
        health_config: Optional[HealthConfig] = None,
        alert_rules: Optional[Sequence[AlertRule]] = None,
    ):
        n = len(params_list)
        if n == 0:
            raise ValueError("ShardedFleetServeEngine needs at least one chip")
        if cfg.has_ssm:
            raise ValueError(
                f"continuous fleet serving supports attention families only; "
                f"{cfg.family!r} carries unpaged SSM state"
            )
        if cfg.is_encoder:
            raise ValueError("encoder-only arch has no decode path")
        ctxs = list(ctxs) if ctxs is not None else [healthy()] * n
        if len(ctxs) != n:
            raise ValueError(f"{n} params sets but {len(ctxs)} fault contexts")
        if mesh is None:
            # largest pop extent that both fits the backend and tiles the fleet
            ndev = len(jax.devices())
            extent = max(d for d in range(1, min(n, ndev) + 1) if n % d == 0)
            mesh = make_pop_mesh(extent, axis=axis_name)
        if axis_name not in mesh.shape:
            raise ValueError(
                f"mesh axes {tuple(mesh.shape)} lack population axis {axis_name!r}"
            )
        extent = int(mesh.shape[axis_name])
        if n % extent != 0:
            raise ValueError(
                f"{n} chips don't tile the {extent}-slice {axis_name!r} mesh; "
                "pad the fleet or pass a mesh whose pop extent divides it"
            )
        self.cfg = cfg
        self.mesh = mesh
        self.axis_name = axis_name
        self.num_chips = n
        self.num_slots = num_slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_pages_per_seq = max_pages_per_seq or (num_pages - 1)
        self.pad_id = pad_id
        if prefill_buckets is None:
            self.prefill_buckets = None
            self.chunk_size: Optional[int] = None
            self.max_pack = 1
        else:
            self.prefill_buckets = validate_buckets(prefill_buckets)
            self.chunk_size = int(chunk_size) if chunk_size else self.prefill_buckets[-1]
            if self.chunk_size < page_size or self.chunk_size % page_size:
                raise ValueError(
                    f"chunk_size {self.chunk_size} must be a positive multiple "
                    f"of page_size {page_size} (chunk starts must be page-aligned)"
                )
            if max_pack < 1:
                raise ValueError(f"max_pack must be >= 1, got {max_pack}")
            self.max_pack = int(max_pack)
        # host-side observability; one track per chip (chip{c}/slot{s},
        # chip{c}/pages) so Perfetto draws the fleet as per-chip swimlanes.
        # All hooks sit at dispatch boundaries outside traced code.
        self.obs = recorder if recorder is not None else NULL_RECORDER
        self._page_bytes = page_bytes(cfg, page_size)
        self.params_list = list(params_list)
        self.ctxs = [c or healthy() for c in ctxs]
        self.params = _stack_trees(self.params_list)
        self.ctx = stack_contexts(self.ctxs)

        sample = make_sample_decode(cfg, pad_id=pad_id)
        mode = self.ctx.mode
        pa = P(axis_name)
        if self.ctx.ok is None:
            hctx = healthy()

            def chip_step(p, cur, cache, key, temp, eos, active, remaining):
                return sample(
                    p, cur, cache, key, hctx, temp,
                    active=active, eos_id=eos, remaining=remaining,
                )

            vmapped = jax.vmap(chip_step, in_axes=(0, 0, 0, 0, None, None, 0, 0))
            in_specs = (pa, pa, pa, pa, P(), P(), pa, pa)
            donate = (1, 2, 3, 6, 7)  # cur, cache, keys, active, remaining
        else:

            def chip_step(p, cur, cache, key, ok, temp, eos, active, remaining):
                return sample(
                    p, cur, cache, key, FaultContext(ok=ok, mode=mode), temp,
                    active=active, eos_id=eos, remaining=remaining,
                )

            vmapped = jax.vmap(chip_step, in_axes=(0, 0, 0, 0, 0, None, None, 0, 0))
            in_specs = (pa, pa, pa, pa, pa, P(), P(), pa, pa)
            donate = (1, 2, 3, 7, 8)  # cur, cache, keys, active, remaining
        # the serve loop re-binds every donated operand from the previous
        # dispatch (host copies of emitted/active are taken synchronously
        # before the next call), so the sharded page pools alias in place
        # (repro.analysis DON001); params and the stacked ok masks are
        # reused across dispatches and stay undonated
        self._step = jax.jit(
            shard_map(
                vmapped,
                mesh=self.mesh,
                in_specs=in_specs,
                out_specs=(pa,) * 7,
                check_rep=False,
            ),
            donate_argnums=donate,
        )
        self._packed_admit = jax.jit(
            self._packed_admit_fn, donate_argnums=(5, 6, 7, 8)
        )
        self._prefill_chunk = jax.jit(
            self._prefill_chunk_fn, donate_argnums=(3, 4, 5, 6)
        )
        # fault detection (ROADMAP item 2): one ABFT prober per chip, all
        # dispatched every probe_every fused decode dispatches. Probes are
        # SEPARATE dispatches through one shared jitted program and never
        # touch the serve loop's carried state or key streams, so enabling
        # them changes no sampled token on any chip.
        if probe_every is not None and probe_every < 1:
            raise ValueError(f"probe_every must be >= 1, got {probe_every}")
        self.probe_every = int(probe_every) if probe_every else None
        self._probers: Optional[list] = None
        self.health: Optional[HealthTracker] = None
        self.alerts = AlertEngine(self.obs, alert_rules) if alert_rules else None
        if self.probe_every:
            self._init_probers(health_config)

    def _init_probers(self, health_config: Optional[HealthConfig]) -> None:
        from repro.kernels.masked_matmul.ops import masked_matmul_checksummed
        from repro.obs.abft import ChipProber, select_probe_weight

        cfg = self.cfg
        rows, cols = cfg.array_rows, cfg.array_cols
        probe_fn = jax.jit(masked_matmul_checksummed)  # shared: one compile
        ones = jnp.ones((rows, cols), jnp.float32)
        dtype = jnp.dtype(cfg.dtype)

        def make_dispatch(c, w):
            def dispatch(x):
                # chip c's LIVE mask: re-read self.ctxs so a set_silicon()
                # change is what the next probe computes through
                ok = self.ctxs[c].ok
                y, chk = probe_fn(
                    jnp.asarray(x, dtype), w, ok if ok is not None else ones
                )
                return np.asarray(y), np.asarray(chk)

            return dispatch

        self._probers = []
        for c, params_c in enumerate(self.params_list):
            _, w = select_probe_weight(params_c)
            self._probers.append(ChipProber(
                make_dispatch(c, w), array_shape=(rows, cols),
                k_dim=int(w.shape[0]), chip=c,
            ))
        self.health = HealthTracker(
            self.num_chips, self.obs, config=health_config, proc="fleet"
        )

    def set_silicon(self, chip: int, ctx: FaultContext) -> None:
        """Simulate a mid-flight silicon change on one chip: swap the LIVE
        fault context chip ``chip``'s subsequent dispatches compute through,
        WITHOUT rebasing that chip's prober goldens — so its next probe
        sees the divergence and the other chips' don't. The fleet must have
        been built with ACTIVE contexts (possibly zero-fault FaultMaps) on
        every chip: the compiled programs carry the stacked ok mask as a
        live input, and an ok=None ↔ ok=array flip would be a different
        program."""
        if not 0 <= chip < self.num_chips:
            raise ValueError(f"chip {chip} out of range [0, {self.num_chips})")
        if self.ctx.ok is None:
            raise ValueError(
                "set_silicon needs an ACTIVE fleet: construct every chip "
                "with an explicit (possibly zero-fault) FaultMap context so "
                "the stacked mask is a live program input"
            )
        if ctx is None or ctx.ok is None:
            raise ValueError(
                "set_silicon needs an ACTIVE context; pass a zero-fault "
                "FaultMap context to model pristine silicon"
            )
        if ctx.mode != self.ctx.mode:
            raise ValueError(
                f"mode mismatch: fleet {self.ctx.mode!r} vs new {ctx.mode!r}"
            )
        if tuple(ctx.ok.shape) != tuple(self.ctx.ok.shape[1:]):
            raise ValueError(
                f"ok shape mismatch: chip expects "
                f"{tuple(self.ctx.ok.shape[1:])}, got {tuple(ctx.ok.shape)}"
            )
        self.ctxs[chip] = ctx
        # the stacked mask is an UNDONATED dispatch input, so a functional
        # row update is safe between dispatches
        self.ctx = FaultContext(
            ok=self.ctx.ok.at[chip].set(jnp.asarray(ctx.ok, self.ctx.ok.dtype)),
            mode=self.ctx.mode,
        )

    # -- jitted admission: the bucketed planner's programs, chip-indexed ----

    def _packed_admit_fn(
        self, params_c, tokens, positions, segments, ctx_c, cache, cur, active,
        remaining, chip, page_ix, page_off, gather_pos, slots, rows, seq_lens,
        budgets,
    ):
        """Chip-indexed twin of ``ContinuousBatchingEngine._packed_admit_fn``:
        admit a PACK of one chip's requests in one bucket-shaped dispatch,
        scattering into the fleet's stacked state at ``chip``. The chip index
        is traced, so one compiled program per bucket serves the whole fleet
        (per-fault-context pytree structure permitting)."""
        hidden, dense = M.prefill(
            params_c, {"tokens": tokens, "positions": positions}, self.cfg,
            ctx_c, full_kv=True, return_hidden=True, segments=segments,
            attn_impl="dense",
        )
        # (L, 1, Hkv, W, hd) -> (W, L, Hkv, hd): the advanced indices
        # (chip, page_ix, page_off) around the slices put the token dim first
        k = jnp.transpose(dense["k"][:, 0], (2, 0, 1, 3))
        v = jnp.transpose(dense["v"][:, 0], (2, 0, 1, 3))
        kp = cache["k_pages"].at[chip, :, page_ix, :, page_off].set(k.astype(cache["k_pages"].dtype))
        vp = cache["v_pages"].at[chip, :, page_ix, :, page_off].set(v.astype(cache["v_pages"].dtype))
        h = hidden[0, gather_pos]  # (max_pack, d)
        logits = M.unembed(self.cfg, params_c, h[None], ctx_c)[0]  # (max_pack, V)
        cache = dict(
            k_pages=kp,
            v_pages=vp,
            block_tables=cache["block_tables"].at[chip, slots].set(rows),
            seq_lens=cache["seq_lens"].at[chip, slots].set(seq_lens),
        )
        cur = cur.at[chip, slots].set(logits.astype(cur.dtype))
        active = active.at[chip, slots].set(True)
        remaining = remaining.at[chip, slots].set(budgets)
        return cache, cur, active, remaining

    def _prefill_chunk_fn(
        self, params_c, tokens, ctx_c, cache, cur, active, remaining,
        chip, slot, row, page_ix, page_off, prefix, valid, budget, activate,
    ):
        """Chip-indexed twin of ``ContinuousBatchingEngine._prefill_chunk_fn``:
        one fixed-size chunk of a long prompt streaming into one chip's page
        chain; the final chunk (``activate``) flips the slot live."""
        logits, kc, vc = M.prefill_chunk(
            params_c, tokens, self.cfg, ctx_c,
            k_pages=cache["k_pages"][chip], v_pages=cache["v_pages"][chip],
            row=row, prefix_len=prefix, valid_len=valid,
        )
        k = jnp.transpose(kc[:, 0], (2, 0, 1, 3))
        v = jnp.transpose(vc[:, 0], (2, 0, 1, 3))
        new_len = jnp.where(activate, prefix + valid, cache["seq_lens"][chip, slot])
        cache = dict(
            k_pages=cache["k_pages"].at[chip, :, page_ix, :, page_off].set(k.astype(cache["k_pages"].dtype)),
            v_pages=cache["v_pages"].at[chip, :, page_ix, :, page_off].set(v.astype(cache["v_pages"].dtype)),
            block_tables=cache["block_tables"].at[chip, slot].set(row),
            seq_lens=cache["seq_lens"].at[chip, slot].set(new_len),
        )
        cur = cur.at[chip, slot].set(
            jnp.where(activate, logits[0].astype(cur.dtype), cur[chip, slot])
        )
        active = active.at[chip, slot].set(active[chip, slot] | activate)
        remaining = remaining.at[chip, slot].set(
            jnp.where(activate, budget, remaining[chip, slot])
        )
        return cache, cur, active, remaining

    # -- the fleet serve loop ------------------------------------------------

    def serve(
        self,
        streams: Sequence[Sequence[Request]],
        *,
        temperature: float = 0.0,
        eos_id: Optional[int] = None,
        key: Optional[jax.Array] = None,
        on_step: Optional[Callable[[int], None]] = None,
    ) -> tuple[list[dict[int, RequestOutput]], ServeStats]:
        """Serve one ragged request stream per chip to completion.

        Returns (per-chip outputs-by-rid, fleet-level stats). Stats count
        fused dispatches — the whole fleet advances per dispatch, so the
        total is driven by the busiest chip, not the sum over chips.
        ``on_step(clock)`` runs at the top of every scheduler round — the
        injection hook benchmarks use to flip one chip's silicon mid-serve
        (``set_silicon``)."""
        if len(streams) != self.num_chips:
            raise ValueError(f"{self.num_chips} chips but {len(streams)} request streams")
        stats = ServeStats(
            num_slots=self.num_chips * self.num_slots, page_size=self.page_size
        )
        allocs = [PageAllocator(self.num_pages, self.page_size) for _ in range(self.num_chips)]
        tables = [
            _SlotTable(list(s), self.num_slots, allocs[c], self.max_pages_per_seq)
            for c, s in enumerate(streams)
        ]
        rec = self.obs
        tracers = [
            RequestTracer(rec, proc="fleet", track_prefix=f"chip{c}/")
            for c in range(self.num_chips)
        ]
        fleet_tracer = RequestTracer(rec, proc="fleet")
        pools = [
            PoolMonitor(rec, allocs[c], proc="fleet", track=f"chip{c}/pages",
                        name_prefix=f"kv.chip{c}.")
            for c in range(self.num_chips)
        ]

        N, S, V = self.num_chips, self.num_slots, self.cfg.vocab_size
        dtype = jnp.dtype(self.cfg.dtype)
        one = M.init_paged_cache(
            self.cfg, self.num_pages, self.page_size, S, self.max_pages_per_seq
        )
        cache = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (N,) + x.shape).copy(), one
        )
        cur = jnp.zeros((N, S, V), dtype)
        active = jnp.zeros((N, S), bool)
        remaining = jnp.zeros((N, S), jnp.int32)
        key = key if key is not None else jax.random.PRNGKey(0)
        keys = jax.random.split(key, N)  # one sample stream per chip
        temp = jnp.float32(temperature)
        eos = jnp.asarray(-1 if eos_id is None else eos_id, jnp.int32)

        buckets = self.prefill_buckets
        top = buckets[-1] if buckets else None

        def flush_pack(c, pack):
            nonlocal cache, cur, active, remaining
            if not pack:
                return
            total = sum(len(it.tokens) for it in pack)
            width = total if buckets is None else bucket_of(total, buckets)
            arrays = build_pack(
                pack, bucket=width, max_pack=self.max_pack,
                page_size=self.page_size, max_pages_per_seq=self.max_pages_per_seq,
                num_slots=self.num_slots, pad_id=self.pad_id,
            )
            t0 = rec.now() if rec else 0.0
            cache, cur, active, remaining = self._packed_admit(
                self.params_list[c], arrays["tokens"], arrays["positions"],
                arrays["segments"], self.ctxs[c], cache, cur, active, remaining,
                np.int32(c), arrays["page_ix"], arrays["page_off"],
                arrays["gather_pos"], arrays["slots"], arrays["rows"],
                arrays["seq_lens"], arrays["budgets"],
            )
            stats.prefill_dispatches += 1
            if rec:
                jax.block_until_ready(cur)
                t1 = rec.now()
                for it in pack:
                    tracers[c].admitted(
                        it.rid, it.slot, t0, t1,
                        args=dict(bucket=width, packed=len(pack), chip=c,
                                  prompt_len=len(it.tokens)),
                    )
            pack.clear()

        def run_chunks(c, slot, r, pages):
            nonlocal cache, cur, active, remaining
            steps = plan_prefill(
                len(r.tokens), buckets=buckets, chunk_size=self.chunk_size
            )
            toks = np.asarray(r.tokens, np.int32)
            row = np.zeros((self.max_pages_per_seq,), np.int32)
            row[: len(pages)] = pages
            for st in steps:
                maps = chunk_step_maps(st, pages, page_size=self.page_size)
                ct = np.full((st.size,), self.pad_id, np.int32)
                ct[: st.valid] = toks[st.start : st.start + st.valid]
                t0 = rec.now() if rec else 0.0
                cache, cur, active, remaining = self._prefill_chunk(
                    self.params_list[c], ct[None], self.ctxs[c], cache, cur,
                    active, remaining, np.int32(c), np.int32(slot), row,
                    maps["page_ix"], maps["page_off"], np.int32(st.start),
                    np.int32(st.valid), np.int32(r.max_new_tokens),
                    np.bool_(st.final),
                )
                stats.prefill_dispatches += 1
                stats.chunk_dispatches += 1
                if rec:
                    jax.block_until_ready(cur)
                    tracers[c].chunk(
                        r.rid, slot, t0, rec.now(), final=st.final,
                        args=dict(size=st.size, start=st.start, valid=st.valid),
                    )

        clock = 0
        while not all(t.done for t in tables):
            if on_step is not None:
                on_step(clock)
            for c, table in enumerate(tables):
                table.stamp_arrivals(clock)
                pack: list[PackItem] = []
                while True:
                    adm = table.pop_admission(clock)
                    if adm is None:
                        break
                    slot, r, pages = adm
                    table.outputs_admitted[r.rid] = clock
                    stats.admitted += 1
                    plen = len(r.tokens)
                    if top is not None and plen > top:
                        flush_pack(c, pack)
                        run_chunks(c, slot, r, pages)
                        continue
                    if pack and (
                        len(pack) >= self.max_pack
                        or (top is not None
                            and sum(len(i.tokens) for i in pack) + plen > top)
                    ):
                        flush_pack(c, pack)
                    pack.append(
                        PackItem(np.asarray(r.tokens, np.int32), slot,
                                 tuple(pages), r.max_new_tokens)
                    )
                flush_pack(c, pack)
            pages_in_use = sum(a.pages_in_use for a in allocs)
            stats.peak_resident_kv_bytes = max(
                stats.peak_resident_kv_bytes, pages_in_use * self._page_bytes
            )
            for p in pools:
                p.sample()
            if not any(t.active.any() for t in tables):
                arrivals = [t.next_arrival() for t in tables if t.next_arrival() is not None]
                assert arrivals, "no active slots and no pending arrivals"
                clock = max(clock + 1, min(arrivals))
                continue

            n_active = int(sum(t.active.sum() for t in tables))
            args = (self.params, cur, cache, keys)
            if self.ctx.ok is not None:
                args += (self.ctx.ok,)
            t0 = rec.now() if rec else 0.0
            emitted, tok_lp, cur, cache, keys, active, remaining = self._step(
                *args, temp, eos, active, remaining
            )
            clock += 1
            stats.decode_dispatches += 1
            stats.emitted_tokens += n_active
            stats.active_slot_steps += n_active
            stats.kv_byte_steps += pages_in_use * self._page_bytes
            em = np.asarray(emitted)  # forces the fused dispatch to completion
            lp = np.asarray(tok_lp)
            ac = np.asarray(active)
            if rec:
                t1 = rec.now()
                fleet_tracer.decode_dispatch(t0, t1, n_active=n_active, clock=clock)
            for c, table in enumerate(tables):
                if rec:
                    slot_of = {r.rid: s for s, r in enumerate(table.slots)
                               if r is not None}
                if self.health is not None:
                    msk = table.active  # the mask this dispatch computed under
                    self.health.observe_decode(
                        c, clock=clock,
                        mean_logprob=(
                            float(lp[c][msk].mean()) if msk.any() else None
                        ),
                        alloc_failures=allocs[c].alloc_failures,
                    )
                retired = table.record_step(em[c], lp[c], ac[c], clock, eos_id=eos_id)
                if rec and retired:
                    t1 = rec.now()
                    for rid in retired:
                        tracers[c].retired(table.outputs[rid], slot_of[rid], t1)
                    pools[c].sample()
            if self._probers is not None and clock % self.probe_every == 0:
                for c, prober in enumerate(self._probers):
                    t0p = rec.now() if rec else 0.0
                    res = prober.probe(clock=clock)
                    stats.probe_dispatches += res.dispatches
                    if rec:
                        rec.span("probe", proc="fleet", track=f"chip{c}/health",
                                 t0=t0p, t1=rec.now(), args=res.as_dict())
                        rec.count("probe.dispatches", res.dispatches)
                    self.health.observe_probe(c, res, clock=clock)
                if self.alerts:
                    self.alerts.evaluate(clock=clock)
        # peak residency is exact from the per-round samples: pages only
        # grow at admission (sampled) and shrink at retirement
        for p in pools:
            p.flush()  # close every chip's counter series at the final ts
        if self.health is not None:
            self.health.finalize()
        if self.alerts:
            self.alerts.evaluate(clock=clock)
        if rec:
            rec.instant("serve.end", proc="fleet", track="engine",
                        args=dict(chips=self.num_chips, **stats.as_dict()))
        return [t.outputs for t in tables], stats
