"""Kernel autotuning: lint-gated block search + persistent tuning cache.

* :mod:`repro.tune.cache` — versioned JSON tuning table (committed default
  + ``$REPRO_TUNING_CACHE`` user overlay) consulted by every ``ops.py``
  wrapper through ``kernels/common.py::tuned_block``;
* :mod:`repro.tune.tuner` — the autotuner (candidates statically gated by
  the ``repro.analysis.kernelgeom`` lint before anything compiles);
* :mod:`repro.tune.search` — powers-of-two lattice + greedy hillclimb;
* :mod:`repro.tune.roofline` — hardware constants and per-kernel analytic
  FLOP/byte models for achieved-vs-roofline fractions.

See ``src/repro/tune/README.md`` for the search space and cache format.
"""
from repro.tune.cache import (
    TuningCache,
    cache_key,
    get_tuning_cache,
    parse_key,
    reset_tuning_cache,
    set_tuning_cache,
)
from repro.tune.tuner import KERNELS, SHAPE_FIELDS, TuneResult, tune_kernel, tune_many

__all__ = [
    "TuningCache",
    "cache_key",
    "parse_key",
    "get_tuning_cache",
    "set_tuning_cache",
    "reset_tuning_cache",
    "KERNELS",
    "SHAPE_FIELDS",
    "TuneResult",
    "tune_kernel",
    "tune_many",
]
