"""The kernel autotuner: lint-gated block search over the four Pallas kernels.

For one ``(kernel, shape, dtype, backend)`` launch the tuner:

1. builds the powers-of-two block lattice (``repro.tune.search``), with
   every raw point **normalized** through the exact ``choose_block``/
   clamping rules the ``ops.py`` wrapper applies — the
   ``analysis.kernelgeom`` launch builders mirror those rules, so the
   normalized blocks are read straight off the built launch;
2. statically accepts or rejects each candidate through the kernel-geometry
   lint (KRN001–KRN004: divisibility, mask-period compatibility, grid
   bounds) plus the *double-buffered* analytic VMEM bound
   (``vmem_footprint(..., double_buffered=True)`` vs ``VMEM_LIMIT_BYTES``)
   — a rejected candidate is never compiled, never launched;
3. times the survivors (jit + warmup + ``block_until_ready``, min over
   ``iters``) under a greedy hillclimb seeded at the heuristic config, with
   recorder spans from :mod:`repro.obs` around every measurement;
4. records the winner with its speedup over the heuristic and its
   achieved-vs-roofline fraction (:mod:`repro.tune.roofline`), as a
   ready-to-commit tuning-cache entry.

Because the heuristic config is always the hillclimb seed, the winner beats
or ties the heuristic by construction — the cache can only speed launches
up. Numerics are untouched: block geometry changes reduction *blocking*
only, which every kernel's tolerance tests already pin.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.analysis.kernelgeom import (
    KernelLaunch,
    check_launch,
    decode_attention_launch,
    flash_attention_launch,
    masked_matmul_launch,
    mamba_scan_launch,
)
from repro.kernels.common import (
    VMEM_LIMIT_BYTES,
    backend_tag,
    is_tpu_backend,
    vmem_footprint,
)
from repro.obs.recorder import NULL_RECORDER
from repro.tune.cache import TuningCache, cache_key
from repro.tune.roofline import kernel_flops_bytes, roofline_fraction
from repro.tune.search import hillclimb, lattice_neighbors, pow2_lattice

__all__ = ["KERNELS", "SHAPE_FIELDS", "TuneResult", "tune_kernel", "tune_many"]


# shape-key fields per kernel, in canonical declaration order
SHAPE_FIELDS = {
    "masked_matmul": ("m", "k", "n", "r", "c"),
    "flash_attention": ("b", "hq", "hkv", "sq", "skv", "d", "causal"),
    "decode_attention": ("b", "hq", "hkv", "skv", "d"),
    "mamba_scan": ("b", "l", "d", "n"),
}

# today's ops.py heuristic defaults — the hillclimb seed and the fallback
HEURISTIC_BLOCKS = {
    "masked_matmul": dict(bm=512, bn=512, bk=512),
    "flash_attention": dict(bq=128, bkv=128),
    "decode_attention": dict(bkv=128),
    "mamba_scan": dict(bd=256, bl=128),
}


def _mm_launch(shape, dtype, blocks) -> KernelLaunch:
    return masked_matmul_launch(
        shape["m"], shape["k"], shape["n"], (shape["r"], shape["c"]),
        bm=blocks["bm"], bn=blocks["bn"], bk=blocks["bk"], dtype=dtype,
    )


def _fa_launch(shape, dtype, blocks) -> KernelLaunch:
    return flash_attention_launch(
        shape["b"], shape["hq"], shape["hkv"], shape["sq"], shape["skv"],
        shape["d"], bq=blocks["bq"], bkv=blocks["bkv"], dtype=dtype,
    )


def _da_launch(shape, dtype, blocks) -> KernelLaunch:
    return decode_attention_launch(
        shape["b"], shape["hq"], shape["hkv"], shape["skv"], shape["d"],
        bkv=blocks["bkv"],
    )


def _ms_launch(shape, dtype, blocks) -> KernelLaunch:
    return mamba_scan_launch(
        shape["b"], shape["l"], shape["d"], shape["n"],
        bd=blocks["bd"], bl=blocks["bl"], dtype=dtype,
    )


def _mm_runner(shape, dtype, interpret):
    from repro.kernels.masked_matmul.ops import masked_matmul

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (shape["m"], shape["k"]), dtype)
    w = jax.random.normal(k2, (shape["k"], shape["n"]), dtype)
    ok = (jax.random.uniform(k3, (shape["r"], shape["c"])) > 0.1).astype(jnp.float32)

    def call(blocks):
        return jax.jit(
            partial(masked_matmul, interpret=interpret, **blocks)
        )(x, w, ok)

    return call


def _fa_runner(shape, dtype, interpret):
    from repro.kernels.flash_attention.ops import flash_attention

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (shape["b"], shape["hq"], shape["sq"], shape["d"]), dtype)
    k = jax.random.normal(ks[1], (shape["b"], shape["hkv"], shape["skv"], shape["d"]), dtype)
    v = jax.random.normal(ks[2], k.shape, dtype)
    causal = bool(shape.get("causal", 1))

    def call(blocks):
        return jax.jit(
            partial(flash_attention, causal=causal, interpret=interpret, **blocks)
        )(q, k, v)

    return call


def _da_runner(shape, dtype, interpret):
    from repro.kernels.decode_attention.ops import decode_attention, quantize_kv

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (shape["b"], shape["hq"], 1, shape["d"]), dtype)
    kc = jax.random.normal(ks[1], (shape["b"], shape["hkv"], shape["skv"], shape["d"]))
    vc = jax.random.normal(ks[2], kc.shape)
    ki, ksc = quantize_kv(kc)
    vi, vsc = quantize_kv(vc)
    valid = shape["skv"]

    def call(blocks):
        return jax.jit(
            partial(decode_attention, interpret=interpret, **blocks)
        )(q, ki, ksc, vi, vsc, valid)

    return call


def _ms_runner(shape, dtype, interpret):
    from repro.kernels.mamba_scan.ops import selective_scan

    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    u = jax.random.normal(ks[0], (shape["b"], shape["l"], shape["d"]), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], u.shape, dtype))
    a = -jnp.exp(jax.random.normal(ks[2], (shape["d"], shape["n"])))
    b = jax.random.normal(ks[3], (shape["b"], shape["l"], shape["n"]), dtype)
    c = jax.random.normal(ks[4], b.shape, dtype)
    d = jax.random.normal(ks[5], (shape["d"],), dtype)

    def call(blocks):
        return jax.jit(
            lambda *xs: selective_scan(*xs, interpret=interpret, **blocks)[0]
        )(u, dt, a, b, c, d)

    return call


@dataclass(frozen=True)
class KernelSpace:
    """One kernel's tunable space: block params, their lattice axes, the
    geometry builder (mirroring ops.py via analysis.kernelgeom) and the
    measurement runner."""

    params: tuple
    # block param -> shape field giving the lattice's upper bound
    axes: Mapping[str, str]
    # param -> minimum lattice value (TPU sublane floor where relevant)
    floors: Mapping[str, int]
    build_launch: Callable[[Mapping, Any, Mapping], KernelLaunch]
    make_runner: Callable[[Mapping, Any, bool], Callable]
    # positions of each block param inside KernelLaunch.blocks
    launch_slots: Mapping[str, int]


KERNELS: dict[str, KernelSpace] = {
    "masked_matmul": KernelSpace(
        params=("bm", "bn", "bk"),
        axes=dict(bm="m", bn="n", bk="k"),
        floors=dict(bm=8, bn=8, bk=8),
        build_launch=_mm_launch,
        make_runner=_mm_runner,
        launch_slots=dict(bm=0, bn=1, bk=2),
    ),
    "flash_attention": KernelSpace(
        params=("bq", "bkv"),
        axes=dict(bq="sq", bkv="skv"),
        floors=dict(bq=8, bkv=8),
        build_launch=_fa_launch,
        make_runner=_fa_runner,
        launch_slots=dict(bq=1, bkv=2),
    ),
    "decode_attention": KernelSpace(
        params=("bkv",),
        axes=dict(bkv="skv"),
        floors=dict(bkv=8),
        build_launch=_da_launch,
        make_runner=_da_runner,
        launch_slots=dict(bkv=2),
    ),
    "mamba_scan": KernelSpace(
        params=("bd", "bl"),
        axes=dict(bd="d", bl="l"),
        floors=dict(bd=8, bl=8),
        build_launch=_ms_launch,
        make_runner=_ms_runner,
        launch_slots=dict(bd=1, bl=2),
    ),
}


@dataclass
class TuneResult:
    """Outcome of tuning one launch; ``entry`` is the cache-ready record."""

    kernel: str
    shape: dict
    dtype: str
    backend: str
    key: str
    heuristic_blocks: dict
    heuristic_s: float
    best_blocks: dict
    best_s: float
    speedup: float
    roofline_fraction: float
    vmem_bytes: int
    evaluated: int
    rejected: int
    rejected_configs: list = field(default_factory=list)

    @property
    def entry(self) -> dict:
        return dict(
            blocks=dict(self.best_blocks),
            time_us=round(self.best_s * 1e6, 3),
            heuristic_us=round(self.heuristic_s * 1e6, 3),
            speedup=round(self.speedup, 4),
            roofline_fraction=self.roofline_fraction,
            vmem_bytes=int(self.vmem_bytes),
            backend=self.backend,
            evaluated=self.evaluated,
            rejected=self.rejected,
        )


def normalize_blocks(kernel: str, shape: Mapping[str, int], blocks: Mapping[str, int]) -> dict:
    """Raw lattice point -> the blocks the wrapper would actually launch
    (read back off the kernelgeom launch, which applies the same
    ``choose_block``/clamp rules as ops.py)."""
    space = KERNELS[kernel]
    launch = space.build_launch(shape, jnp.float32, dict(blocks))
    return {p: int(launch.blocks[i]) for p, i in space.launch_slots.items()}


def lint_candidate(
    kernel: str,
    shape: Mapping[str, int],
    dtype: Any,
    blocks: Mapping[str, int],
    *,
    vmem_limit_bytes: int = VMEM_LIMIT_BYTES,
) -> tuple[list, int]:
    """Static accept/reject for one candidate: the KRN001–KRN004 geometry
    lint plus the tuner's conservative double-buffered VMEM bound.
    Returns (findings, double_buffered_vmem_bytes); empty findings = OK."""
    launch = KERNELS[kernel].build_launch(shape, dtype, dict(blocks))
    findings = list(check_launch(launch))
    vmem = vmem_footprint(launch.vmem_blocks, double_buffered=True)
    if vmem > vmem_limit_bytes:
        from repro.analysis.findings import Finding

        findings.append(
            Finding(
                code="KRN002",
                entry_point=launch.kernel,
                subject="vmem",
                message=(
                    f"double-buffered resident blocks need {vmem/2**20:.2f} MiB "
                    f"VMEM (tuner limit {vmem_limit_bytes/2**20:.2f} MiB)"
                ),
                bytes=vmem,
            )
        )
    return findings, vmem


def tune_kernel(
    kernel: str,
    shape: Mapping[str, int],
    dtype: Any = jnp.float32,
    *,
    iters: int = 3,
    max_evals: int = 24,
    interpret: Optional[bool] = None,
    vmem_limit_bytes: int = VMEM_LIMIT_BYTES,
    recorder=NULL_RECORDER,
) -> TuneResult:
    """Tune one launch; see the module docstring for the pipeline."""
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r} (have {sorted(KERNELS)})")
    space = KERNELS[kernel]
    shape = {k: int(v) for k, v in shape.items()}
    missing = [f for f in SHAPE_FIELDS[kernel] if f != "causal" and f not in shape]
    if missing:
        raise ValueError(f"{kernel} shape is missing fields {missing}")
    if interpret is None:
        interpret = not is_tpu_backend()
    backend = backend_tag(interpret)
    dtype_name = jnp.dtype(dtype).name

    lattices = {
        p: pow2_lattice(shape[space.axes[p]], lo=space.floors[p])
        for p in space.params
    }
    runner = space.make_runner(shape, dtype, interpret)

    timed: dict[tuple, float] = {}
    rejected: list[dict] = []

    def score(raw_blocks: Mapping[str, int]) -> Optional[float]:
        blocks = normalize_blocks(kernel, shape, raw_blocks)
        key = tuple(sorted(blocks.items()))
        if key in timed:
            return timed[key]
        findings, _ = lint_candidate(
            kernel, shape, dtype, blocks, vmem_limit_bytes=vmem_limit_bytes
        )
        if findings:
            recorder.count("tune.lint_rejected")
            rejected.append(dict(blocks=blocks, codes=[f.code for f in findings]))
            return None
        label = ",".join(f"{k}={v}" for k, v in sorted(blocks.items()))
        with recorder.timed(f"tune:{kernel}", proc="tune", track=kernel,
                            args=dict(blocks=dict(blocks))):
            fn = lambda: runner(blocks)  # noqa: E731
            jax.block_until_ready(fn())  # compile + warmup outside the clock
            best = float("inf")
            for _ in range(max(1, iters)):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                best = min(best, time.perf_counter() - t0)
        recorder.observe(
            f"tune.{kernel}.candidate_s",
            best,
            buckets=(1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0),
        )
        recorder.instant(
            f"tuned:{label}", proc="tune", track=kernel, args=dict(seconds=best)
        )
        timed[key] = best
        return best

    heuristic = normalize_blocks(kernel, shape, HEURISTIC_BLOCKS[kernel])
    heuristic_s = score(heuristic)
    if heuristic_s is None:
        raise ValueError(
            f"heuristic config {heuristic} for {kernel} {shape} fails the "
            "geometry lint — the launch is broken before tuning"
        )

    best, best_s, evals = hillclimb(
        heuristic,
        lambda b: lattice_neighbors(b, lattices),
        score,
        max_evals=max_evals,
    )
    _, best_vmem = lint_candidate(
        kernel, shape, dtype, best, vmem_limit_bytes=vmem_limit_bytes
    )
    flops, byts = kernel_flops_bytes(kernel, shape, dtype)
    return TuneResult(
        kernel=kernel,
        shape=dict(shape),
        dtype=dtype_name,
        backend=backend,
        key=cache_key(kernel, shape, dtype_name, backend),
        heuristic_blocks=heuristic,
        heuristic_s=heuristic_s,
        best_blocks=best,
        best_s=best_s,
        speedup=heuristic_s / best_s if best_s > 0 else float("inf"),
        roofline_fraction=roofline_fraction(flops, byts, best_s),
        vmem_bytes=best_vmem,
        evaluated=len(timed),
        rejected=len(rejected),
        rejected_configs=rejected,
    )


def tune_many(
    cells: list[tuple[str, Mapping[str, int]]],
    *,
    cache: Optional[TuningCache] = None,
    **kwargs,
) -> tuple[list[TuneResult], TuningCache]:
    """Tune a list of (kernel, shape) cells; winners land in ``cache``
    (a fresh one when None). Returns (results, cache)."""
    cache = cache if cache is not None else TuningCache()
    results = []
    for kernel, shape in cells:
        res = tune_kernel(kernel, shape, **kwargs)
        cache.put(res.key, res.entry)
        results.append(res)
    return results, cache
