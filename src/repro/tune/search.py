"""Candidate lattice + greedy neighborhood search for the kernel autotuner.

The search space per kernel is the powers-of-two block lattice; every raw
point is *normalized* through the same ``choose_block``/clamping rules the
``ops.py`` wrappers apply (via the ``analysis.kernelgeom`` launch builders,
which mirror them exactly), so distinct raw points that collapse to the
same launch are deduplicated before anything is timed.

:func:`hillclimb` is the generic skeleton of the SPerf loop in
``benchmarks/hillclimb.py`` — score a start point, walk one-parameter
neighbors, move on first improvement, stop when no neighbor improves —
lifted out so block-geometry search and launch-policy search share one
shape. Scoring here is *wall-clock of a lint-accepted candidate*; the lint
gate lives in the candidate generator, so a rejected config is never
scored (and therefore never compiled or launched).
"""
from __future__ import annotations

from typing import Callable, Iterable, Mapping, Optional, Sequence

__all__ = ["pow2_lattice", "lattice_neighbors", "hillclimb"]


def pow2_lattice(dim: int, *, lo: int = 8, hi: int = 4096) -> list[int]:
    """Powers of two in [lo, min(hi, next_pow2(dim))], plus ``dim`` itself —
    the whole-axis block is always a candidate (it is often the winner on
    small axes, and it's what the heuristics clamp to)."""
    dim = int(dim)
    out = []
    b = 1
    while b <= min(hi, 2 * dim):
        if lo <= b <= dim:
            out.append(b)
        b *= 2
    if dim not in out and dim >= 1:
        out.append(dim)
    return sorted(set(out))


def lattice_neighbors(
    blocks: Mapping[str, int], lattices: Mapping[str, Sequence[int]]
) -> Iterable[dict[str, int]]:
    """One-parameter moves: each block param steps to the adjacent lattice
    value (up first — larger blocks usually mean fewer grid steps)."""
    for name, lattice in lattices.items():
        cur = blocks[name]
        # position of the closest lattice point (cur itself when present)
        idx = min(range(len(lattice)), key=lambda i: (abs(lattice[i] - cur), i))
        for j in (idx + 1, idx - 1):
            if 0 <= j < len(lattice) and lattice[j] != cur:
                yield {**blocks, name: lattice[j]}


def hillclimb(
    start,
    neighbors: Callable[[dict], Iterable[dict]],
    score: Callable[[dict], Optional[float]],
    *,
    key: Callable[[dict], tuple] = lambda c: tuple(sorted(c.items())),
    max_evals: int = 32,
):
    """Greedy first-improvement neighborhood search.

    ``score`` returns a float (lower is better) or ``None`` for a candidate
    that must not be evaluated further (the tuner returns None for
    lint-rejected configs — they cost one static check, never a launch).
    Returns ``(best, best_score, evals)`` where ``evals`` counts scored
    candidates including the start.
    """
    seen = {key(start)}
    best_score = score(start)
    if best_score is None:
        raise ValueError(f"hillclimb start {start!r} is not scoreable")
    best = start
    evals = 1
    improved = True
    while improved and evals < max_evals:
        improved = False
        for cand in neighbors(best):
            k = key(cand)
            if k in seen:
                continue
            seen.add(k)
            s = score(cand)
            if s is None:
                continue
            evals += 1
            if s < best_score:
                best, best_score = cand, s
                improved = True
                break
            if evals >= max_evals:
                break
    return best, best_score, evals
