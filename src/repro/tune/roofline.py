"""Analytic roofline terms for the four Pallas kernels.

One home for the hardware constants (previously duplicated between
``benchmarks/roofline.py`` and ``benchmarks/hillclimb.py`` — both now
import from here) plus per-kernel FLOP/byte models so the autotuner can
record *achieved-vs-roofline fraction* next to every winner it caches:

    bound_s  = max(flops / PEAK_FLOPS, bytes / HBM_BW)
    fraction = bound_s / measured_s

On a real TPU the fraction is the genuine roofline headroom; in CPU
interpret mode (tests, CI) it is a tiny bookkeeping number — the *ordering*
of candidates is the signal there, and the committed snapshots record the
backend next to the fraction so the two regimes can't be confused.
"""
from __future__ import annotations

from typing import Mapping

import jax.numpy as jnp

__all__ = [
    "PEAK_FLOPS",
    "HBM_BW",
    "ICI_BW",
    "kernel_flops_bytes",
    "roofline_fraction",
]

# TPU v5e hardware constants (per chip) — the same numbers the dry-run
# roofline (benchmarks/roofline.py) and the SPerf hillclimb driver use.
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (conservative single-link budget)


def kernel_flops_bytes(kernel: str, shape: Mapping[str, int], dtype) -> tuple[float, float]:
    """(flops, hbm_bytes) of one logical kernel invocation.

    Shapes use the same field names as the tuning-cache keys (see
    ``repro.tune.tuner.SHAPE_FIELDS``). The models count the logical
    (unpadded) problem: 2mnk GEMM FLOPs, one HBM touch per operand —
    a *ceiling*, which is exactly what a roofline fraction wants.
    """
    s = {k: int(v) for k, v in shape.items()}
    isz = jnp.dtype(dtype).itemsize
    if kernel == "masked_matmul":
        m, k, n, r, c = s["m"], s["k"], s["n"], s["r"], s["c"]
        flops = 2.0 * m * k * n + k * n  # GEMM + the fused mask multiply
        byts = (m * k + k * n + m * n) * isz + r * c * 4
        return flops, byts
    if kernel == "flash_attention":
        b, hq, sq, skv, d = s["b"], s["hq"], s["sq"], s["skv"], s["d"]
        causal = s.get("causal", 1)
        flops = 4.0 * b * hq * sq * skv * d  # qk^T + pv
        if causal and sq == skv:
            flops /= 2.0  # masked half of the score matrix never lands
        byts = (b * hq * sq * d * 2 + b * s["hkv"] * skv * d * 2) * isz
        return flops, byts
    if kernel == "decode_attention":
        b, hq, hkv, skv, d = s["b"], s["hq"], s["hkv"], s["skv"], s["d"]
        flops = 4.0 * b * hq * skv * d
        # int8 K/V + f32 scales dominate; q and out are one token
        byts = 2.0 * b * hkv * skv * (d + 4) + 2.0 * b * hq * d * 4
        return flops, byts
    if kernel == "mamba_scan":
        b, length, d, n = s["b"], s["l"], s["d"], s["n"]
        # per (token, channel): dA=exp(dt*A) (~2n), dB*u (~2n), h update
        # (~2n), y=C.h (~2n) + D skip
        flops = b * length * d * (8.0 * n + 2.0)
        byts = (4.0 * b * length * d + 2.0 * b * length * n) * isz + d * n * 4 + d * 4
        return flops, byts
    raise ValueError(f"unknown kernel {kernel!r}")


def roofline_fraction(flops: float, hbm_bytes: float, measured_s: float) -> float:
    """Fraction of the compute/memory roofline the measured time achieves
    (1.0 = running exactly at the analytic bound; small = headroom)."""
    if measured_s <= 0:
        return 0.0
    bound_s = max(flops / PEAK_FLOPS, hbm_bytes / HBM_BW)
    return bound_s / measured_s
