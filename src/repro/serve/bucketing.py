"""Bucketed / chunked / packed prefill planning (host side).

Admission is rebuilt around a CLOSED set of prefill shapes so the compile
volume under real traffic is O(|buckets|), not O(|distinct prompt lengths|)
— the hazard the static analyzer pins as ``RCP001:*.prefill*:prompt_len``
(``repro.analysis.recompile``). Three mechanisms, following the MaxText
MLPerf offline-inference pattern (``prefill_buckets`` + packed prefill +
``aot_compile`` warmup):

* **bucketing** — a prompt of length ``p <= buckets[-1]`` is padded up to
  the smallest bucket that holds it; the pad tail is its own segment so it
  cannot attend into (or be attended from) real tokens;
* **chunking** — a prompt longer than the top bucket is split into
  fixed-size ``chunk_size`` steps that stream into the slot's page chain
  (``repro.models.model.prefill_chunk``), all sharing ONE compiled shape;
* **packing** — several short waiting prompts ride one bucket dispatch as
  consecutive *segments* of a single packed row: per-token restarting
  positions keep RoPE exact, a per-token page map scatters each prompt's KV
  into its own chain, and per-segment last-token gathers produce every
  packed request's first logits.

This module is pure host-side numpy: it decides shapes and builds the int32
index arrays the jitted admission programs consume. The jitted programs
live in ``repro.serve.continuous`` / ``repro.fleet.serve``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "DEFAULT_PREFILL_BUCKETS",
    "validate_buckets",
    "bucket_of",
    "ladder_rung",
    "PrefillStep",
    "plan_prefill",
    "PackItem",
    "build_pack",
    "chunk_step_maps",
]

DEFAULT_PREFILL_BUCKETS = (32, 64, 128, 256)


def validate_buckets(buckets: Sequence[int]) -> tuple[int, ...]:
    """Normalize + validate a bucket ladder: ints, strictly increasing."""
    out = tuple(int(b) for b in buckets)
    if not out:
        raise ValueError("prefill_buckets must be non-empty (or None to disable)")
    if any(b < 1 for b in out):
        raise ValueError(f"buckets must be positive, got {out}")
    if any(b >= c for b, c in zip(out, out[1:])):
        raise ValueError(f"buckets must be strictly increasing, got {out}")
    return out


def bucket_of(n: int, buckets: Sequence[int]) -> Optional[int]:
    """Smallest bucket holding ``n`` tokens; None when ``n`` exceeds the top
    bucket (the chunked path takes over)."""
    for b in buckets:
        if n <= b:
            return int(b)
    return None


def ladder_rung(n: int, buckets: Sequence[int]) -> int:
    """Like :func:`bucket_of` but on the ladder extended past the top bucket
    by doubling — always resolves. Used for static-engine KV capacity, where
    requests longer than the top bucket still need a quantized shape."""
    b = bucket_of(n, buckets)
    if b is not None:
        return b
    r = int(buckets[-1])
    while r < n:
        r *= 2
    return r


@dataclass(frozen=True)
class PrefillStep:
    """One prefill dispatch for a request: tokens ``[start, start+valid)``
    run at width ``size`` (pad tail past ``valid``). ``final`` marks the
    step that produces the request's first logits and activates its slot."""

    start: int
    size: int
    valid: int
    final: bool


def plan_prefill(
    plen: int, *, buckets: Optional[Sequence[int]], chunk_size: int
) -> list[PrefillStep]:
    """Admission plan for one prompt: a single bucket step when the prompt
    fits the ladder, else ``ceil(plen / chunk_size)`` equal-width chunk
    steps. With ``buckets=None`` (unbucketed baseline) the single step runs
    at the exact prompt length — one compiled program per distinct length,
    the hazard this module exists to remove."""
    if plen < 1:
        raise ValueError(f"prompt length must be >= 1, got {plen}")
    if buckets is None:
        return [PrefillStep(0, plen, plen, True)]
    b = bucket_of(plen, buckets)
    if b is not None:
        return [PrefillStep(0, b, plen, True)]
    n = -(-plen // chunk_size)
    return [
        PrefillStep(i * chunk_size, chunk_size, min(chunk_size, plen - i * chunk_size), i == n - 1)
        for i in range(n)
    ]


@dataclass(frozen=True)
class PackItem:
    """One request's share of a packed bucket dispatch."""

    tokens: np.ndarray  # (plen,) int token ids
    slot: int
    pages: tuple  # full allocated page chain (prompt + decode budget)
    budget: int  # max_new_tokens
    rid: int = -1  # request id, observability only (never enters a program)


def build_pack(
    items: Sequence[PackItem],
    *,
    bucket: int,
    max_pack: int,
    page_size: int,
    max_pages_per_seq: int,
    num_slots: int,
    pad_id: int = 0,
) -> dict:
    """Lay ``items`` out as ONE packed (1, bucket) prefill row.

    Returns int32 numpy arrays keyed for the jitted packed-admit program:

    * ``tokens``/``positions``/``segments`` ``(1, bucket)`` — prompts
      concatenated; positions restart at 0 per segment (RoPE-exact), real
      segments are 1-based, the pad tail is segment 0;
    * ``page_ix``/``page_off`` ``(bucket,)`` — per-token KV scatter targets
      into the page pool (pad tokens land on the reserved scratch page 0);
    * ``gather_pos`` ``(max_pack,)`` — packed-row index of each segment's
      last real token (first-logits gather);
    * ``slots``/``seq_lens``/``budgets`` ``(max_pack,)`` and ``rows``
      ``(max_pack, max_pages_per_seq)`` — per-slot state scatters; unused
      lanes carry ``slot == num_slots`` which jit scatter semantics drop as
      out-of-bounds, so one program serves every pack occupancy.
    """
    if not 1 <= len(items) <= max_pack:
        raise ValueError(f"pack holds 1..{max_pack} items, got {len(items)}")
    total = sum(len(it.tokens) for it in items)
    if total > bucket:
        raise ValueError(f"{total} packed tokens exceed bucket {bucket}")
    tokens = np.full((bucket,), pad_id, np.int32)
    positions = np.zeros((bucket,), np.int32)
    segments = np.zeros((bucket,), np.int32)
    page_ix = np.zeros((bucket,), np.int32)
    page_off = np.zeros((bucket,), np.int32)
    gather_pos = np.zeros((max_pack,), np.int32)
    slots = np.full((max_pack,), num_slots, np.int32)
    rows = np.zeros((max_pack, max_pages_per_seq), np.int32)
    seq_lens = np.zeros((max_pack,), np.int32)
    budgets = np.zeros((max_pack,), np.int32)
    off = 0
    for i, it in enumerate(items):
        n = len(it.tokens)
        t = np.arange(n)
        tokens[off : off + n] = np.asarray(it.tokens, np.int32)
        positions[off : off + n] = t
        segments[off : off + n] = i + 1
        page_ix[off : off + n] = np.asarray(it.pages, np.int32)[t // page_size]
        page_off[off : off + n] = t % page_size
        gather_pos[i] = off + n - 1
        slots[i] = it.slot
        rows[i, : len(it.pages)] = it.pages
        seq_lens[i] = n
        budgets[i] = it.budget
        off += n
    if off < bucket:  # pad tail: own segment, scratch page, benign positions
        positions[off:] = np.arange(bucket - off)
        page_off[off:] = np.arange(bucket - off) % page_size
    return dict(
        tokens=tokens[None],
        positions=positions[None],
        segments=segments[None],
        page_ix=page_ix,
        page_off=page_off,
        gather_pos=gather_pos,
        slots=slots,
        rows=rows,
        seq_lens=seq_lens,
        budgets=budgets,
    )


def chunk_step_maps(step: PrefillStep, pages: Sequence[int], *, page_size: int) -> dict:
    """Per-token page scatter maps for one chunk step. Chunk starts are
    multiples of ``chunk_size``; with ``chunk_size % page_size == 0`` every
    chunk begins page-aligned, so token ``t`` of the step lands on page
    ``pages[(start + t) // page_size]`` at offset ``t % page_size``. Pad
    tokens past ``valid`` go to the scratch page 0."""
    t = np.arange(step.size)
    g = step.start + t
    chain = np.asarray(pages, np.int32)
    ix = np.minimum(g // page_size, len(chain) - 1)  # pad tokens clamp, then mask
    page_ix = np.where(t < step.valid, chain[ix], 0).astype(np.int32)
    page_off = (g % page_size).astype(np.int32)
    return dict(page_ix=page_ix, page_off=page_off)
