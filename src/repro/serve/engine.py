"""Batched serving engine over the model's prefill/decode steps.

The engine runs a static-batch generate loop (prefill once, decode N) with
the chip's FaultContext applied — i.e. serving a fault-aware model ON the
faulty chip it was tuned for. Greedy or temperature sampling.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.masking import FaultContext, healthy
from repro.models import model as M


@dataclass
class GenerateResult:
    tokens: jax.Array  # (B, prompt + generated)
    logprobs: jax.Array  # (B, generated)


class ServeEngine:
    def __init__(self, cfg, params, ctx: Optional[FaultContext] = None, *, max_len: int = 4096):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or healthy()
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b, ctx: M.prefill(p, b, cfg, ctx, cache_len=max_len)
        )
        self._decode = jax.jit(
            lambda p, t, c, ctx: M.decode_step(p, t, c, cfg, ctx)
        )

    def generate(
        self,
        prompts: jax.Array,  # (B, S) token ids
        *,
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
    ) -> GenerateResult:
        logits, cache = self._prefill(self.params, {"tokens": prompts}, self.ctx)
        toks = [prompts]
        lps = []
        cur = logits
        key = key if key is not None else jax.random.PRNGKey(0)
        for i in range(max_new_tokens):
            lp = jax.nn.log_softmax(cur.astype(jnp.float32), axis=-1)
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, lp / temperature, axis=-1)
            else:
                nxt = jnp.argmax(lp, axis=-1)
            lps.append(jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0])
            toks.append(nxt[:, None])
            step_logits, cache = self._decode(self.params, nxt[:, None], cache, self.ctx)
            cur = step_logits[:, 0]
        return GenerateResult(
            tokens=jnp.concatenate(toks, axis=1), logprobs=jnp.stack(lps, axis=1)
        )
