"""Batched serving engine over the model's prefill/decode steps.

The engine runs a static-batch generate loop (prefill once, decode N) with
the chip's FaultContext applied — i.e. serving a fault-aware model ON the
faulty chip it was tuned for. Greedy or temperature sampling.

Sampling and decode are fused into ONE jitted step: log_softmax, the
greedy/categorical choice, the chosen-token logprob gather and the next
decode_step all run in a single dispatch per token, instead of a host
round-trip for each of them. Temperature is a traced scalar (one compile
covers greedy and every temperature); greedy token choice is exactly
``argmax`` — independent of the sampling key — so temperature=0.0
reproduces the unfused reference token-for-token.

The same fused step powers every serving tier (see README.md here):
``ServeEngine`` jits it, ``repro.fleet.serve.FleetServeEngine`` vmaps it
over chips, and the continuous-batching engines (``repro.serve.continuous``
and ``repro.fleet.serve.ShardedFleetServeEngine``) run its *masked* form —
per-slot ``active`` masking over a paged cache — so finished slots emit pad
tokens with logprob 0 and stop writing KV until the scheduler refills them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.masking import FaultContext, healthy
from repro.models import model as M
from repro.serve.bucketing import (
    DEFAULT_PREFILL_BUCKETS,
    ladder_rung,
    validate_buckets,
)
from repro.serve.kvcache import DEFAULT_PAGE_SIZE, round_up_to_page


@dataclass
class GenerateResult:
    tokens: jax.Array  # (B, prompt + generated)
    logprobs: jax.Array  # (B, generated)


def make_sample_decode(cfg, *, pad_id: int = 0):
    """Build the fused sampling+decode step for one chip.

    ``(params, cur_logits, cache, key, ctx, temperature) ->
    (next_token, token_logprob, next_logits, cache, key)`` — log_softmax,
    the greedy/categorical choice, the chosen-token logprob gather and the
    next ``decode_step`` in a single traced body. ``ServeEngine`` jits it
    directly (one dispatch per token); ``repro.fleet.serve.FleetServeEngine``
    vmaps it over N chips' (params, FaultContext) pairs first, so a whole
    fleet advances one token per dispatch.

    With ``active`` (a per-slot bool mask) the step runs in *masked* form
    and returns ``(emitted, token_logprob, next_logits, cache, key,
    new_active, new_remaining)``: inactive slots emit ``pad_id`` with
    logprob 0, a slot retires when it samples ``eos_id`` (scalar; pass -1
    to disable) or exhausts its per-slot ``remaining`` budget, and the mask
    is forwarded to ``decode_step`` so retired slots stop writing KV
    (paged caches redirect their writes to the scratch page). ``cache`` may
    be the dense cache or a paged one — ``decode_step`` dispatches on it.
    """

    def sample_decode(
        p, cur, cache, key, ctx, temperature, active=None, eos_id=None, remaining=None
    ):
        lp = jax.nn.log_softmax(cur.astype(jnp.float32), axis=-1)
        key, sub = jax.random.split(key)
        # temperature is traced: guard the division so the (unused)
        # sampled branch stays finite when temperature == 0
        safe_t = jnp.maximum(temperature, 1e-6)
        sampled = jax.random.categorical(sub, lp / safe_t, axis=-1)
        nxt = jnp.where(temperature > 0, sampled, jnp.argmax(lp, axis=-1))
        tok_lp = jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0]
        if active is None:
            step_logits, cache = M.decode_step(p, nxt[:, None], cache, cfg, ctx)
            return nxt, tok_lp, step_logits[:, 0], cache, key
        emitted = jnp.where(active, nxt, jnp.asarray(pad_id, nxt.dtype))
        tok_lp = jnp.where(active, tok_lp, 0.0)
        new_active = active
        if eos_id is not None:
            new_active = new_active & (nxt != eos_id)
        new_remaining = remaining
        if remaining is not None:
            new_remaining = remaining - active.astype(remaining.dtype)
            new_active = new_active & (new_remaining > 0)
        step_logits, cache = M.decode_step(
            p, emitted[:, None], cache, cfg, ctx, active=new_active
        )
        return emitted, tok_lp, step_logits[:, 0], cache, key, new_active, new_remaining

    return sample_decode


class ServeEngine:
    """Static-batch serving: one rectangular prompt batch, N decode steps.

    ``max_len`` is the KV capacity. ``max_len=None`` derives it per
    ``generate`` call as ``prompt_len + max_new_tokens`` rounded up the
    bucket ladder — explicit capacity instead of a 4096-slot default.

    Prompt widths are BUCKETED (``repro.serve.bucketing``): ``generate``
    pads the prompt up to the smallest ladder rung that holds it and runs
    prefill with a *traced* ``valid_len``, so the compiled prefill program
    set is one program per (rung, capacity) pair instead of one per
    distinct prompt length — the ``RCP001:serve.prefill:prompt_len`` hazard
    the static analyzer used to baseline. ``prefill_buckets=None`` restores
    the exact-length behaviour; non-causal families (SSM state scans,
    encoders) always take the exact path since pad tokens would corrupt
    their state.
    """

    def __init__(
        self,
        cfg,
        params,
        ctx: Optional[FaultContext] = None,
        *,
        max_len: Optional[int] = 4096,
        page_size: int = DEFAULT_PAGE_SIZE,
        pad_id: int = 0,
        prefill_buckets=DEFAULT_PREFILL_BUCKETS,
    ):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or healthy()
        self.max_len = max_len
        self.page_size = page_size
        self.pad_id = pad_id
        if prefill_buckets is not None and not (cfg.has_ssm or cfg.is_encoder):
            self.prefill_buckets = validate_buckets(prefill_buckets)
        else:
            self.prefill_buckets = None
        self._prefill_len = jax.jit(
            lambda p, b, ctx, cache_len, valid_len=None: M.prefill(
                p, b, cfg, ctx, cache_len=cache_len, valid_len=valid_len
            ),
            static_argnums=3,
        )
        self._prefill = self._prefill_fixed_len
        # the generate loop re-binds cache/cur/key from each dispatch's
        # outputs, so those operands are donated: XLA aliases the KV cache
        # in place instead of copying it every token (repro.analysis DON001)
        self._decode = jax.jit(
            lambda p, t, c, ctx: M.decode_step(p, t, c, cfg, ctx),
            donate_argnums=(2,),
        )

        self._sample_decode = jax.jit(
            make_sample_decode(cfg, pad_id=pad_id), donate_argnums=(1, 2, 3)
        )

    def _prefill_fixed_len(self, p, b, ctx):
        """Unfused-protocol prefill at the engine's fixed capacity. With
        ``max_len=None`` the capacity depends on the generation budget only
        ``generate`` knows — call ``_prefill_len`` with it explicitly."""
        if self.max_len is None:
            raise ValueError(
                "ServeEngine(max_len=None) derives KV capacity per generate "
                "call; use _prefill_len(params, batch, ctx, cache_len) with "
                "cache_len_for(prompt_len, max_new_tokens)"
            )
        return self._prefill_len(p, b, ctx, self.max_len)

    def cache_len_for(self, prompt_len: int, max_new_tokens: int) -> int:
        """KV capacity one generate call needs. Bucketed engines quantize it
        up the (doubling-extended) ladder so capacity, like prompt width,
        draws from a closed set; unbucketed engines round to the page."""
        if self.max_len is not None:
            return self.max_len
        need = prompt_len + max_new_tokens
        if self.prefill_buckets is not None:
            return ladder_rung(need, self.prefill_buckets)
        return round_up_to_page(need, self.page_size)

    def generate(
        self,
        prompts: jax.Array,  # (B, S) token ids
        *,
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
        eos_id: Optional[int] = None,
    ) -> GenerateResult:
        plen = prompts.shape[1]
        cache_len = self.cache_len_for(plen, max_new_tokens)
        if self.prefill_buckets is not None:
            # pad the prompt up to its ladder rung (never past capacity) and
            # trace the real length: one compiled prefill per (rung,
            # capacity) pair regardless of the traffic's prompt lengths
            width = min(ladder_rung(plen, self.prefill_buckets), cache_len)
            padded = prompts
            if width > plen:
                padded = jnp.concatenate(
                    [
                        prompts,
                        jnp.full((prompts.shape[0], width - plen), self.pad_id,
                                 prompts.dtype),
                    ],
                    axis=1,
                )
            logits, cache = self._prefill_len(
                self.params, {"tokens": padded}, self.ctx, cache_len,
                jnp.int32(plen),
            )
        else:
            logits, cache = self._prefill_len(
                self.params, {"tokens": prompts}, self.ctx, cache_len
            )
        toks = [prompts]
        lps = []
        cur = logits
        key = key if key is not None else jax.random.PRNGKey(0)
        temp = jnp.float32(temperature)
        if eos_id is None:
            for _ in range(max_new_tokens):
                nxt, tok_lp, cur, cache, key = self._sample_decode(
                    self.params, cur, cache, key, self.ctx, temp
                )
                lps.append(tok_lp)
                toks.append(nxt[:, None])
        else:
            # EOS masking: a finished sequence emits pad_id with logprob 0
            # for the rest of the batch — the same per-slot semantics the
            # continuous engine retires slots under.
            active = jnp.ones((prompts.shape[0],), bool)
            eos = jnp.asarray(eos_id, jnp.int32)
            for _ in range(max_new_tokens):
                nxt, tok_lp, cur, cache, key, active, _ = self._sample_decode(
                    self.params, cur, cache, key, self.ctx, temp, active, eos
                )
                lps.append(tok_lp)
                toks.append(nxt[:, None])
        return GenerateResult(
            tokens=jnp.concatenate(toks, axis=1), logprobs=jnp.stack(lps, axis=1)
        )
