"""Batched serving engine over the model's prefill/decode steps.

The engine runs a static-batch generate loop (prefill once, decode N) with
the chip's FaultContext applied — i.e. serving a fault-aware model ON the
faulty chip it was tuned for. Greedy or temperature sampling.

Sampling and decode are fused into ONE jitted step: log_softmax, the
greedy/categorical choice, the chosen-token logprob gather and the next
decode_step all run in a single dispatch per token, instead of a host
round-trip for each of them. Temperature is a traced scalar (one compile
covers greedy and every temperature); greedy token choice is exactly
``argmax`` — independent of the sampling key — so temperature=0.0
reproduces the unfused reference token-for-token.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.masking import FaultContext, healthy
from repro.models import model as M


@dataclass
class GenerateResult:
    tokens: jax.Array  # (B, prompt + generated)
    logprobs: jax.Array  # (B, generated)


def make_sample_decode(cfg):
    """Build the fused sampling+decode step for one chip.

    ``(params, cur_logits, cache, key, ctx, temperature) ->
    (next_token, token_logprob, next_logits, cache, key)`` — log_softmax,
    the greedy/categorical choice, the chosen-token logprob gather and the
    next ``decode_step`` in a single traced body. ``ServeEngine`` jits it
    directly (one dispatch per token); ``repro.fleet.serve.FleetServeEngine``
    vmaps it over N chips' (params, FaultContext) pairs first, so a whole
    fleet advances one token per dispatch.
    """

    def sample_decode(p, cur, cache, key, ctx, temperature):
        lp = jax.nn.log_softmax(cur.astype(jnp.float32), axis=-1)
        key, sub = jax.random.split(key)
        # temperature is traced: guard the division so the (unused)
        # sampled branch stays finite when temperature == 0
        safe_t = jnp.maximum(temperature, 1e-6)
        sampled = jax.random.categorical(sub, lp / safe_t, axis=-1)
        nxt = jnp.where(temperature > 0, sampled, jnp.argmax(lp, axis=-1))
        tok_lp = jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0]
        step_logits, cache = M.decode_step(p, nxt[:, None], cache, cfg, ctx)
        return nxt, tok_lp, step_logits[:, 0], cache, key

    return sample_decode


class ServeEngine:
    def __init__(self, cfg, params, ctx: Optional[FaultContext] = None, *, max_len: int = 4096):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or healthy()
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b, ctx: M.prefill(p, b, cfg, ctx, cache_len=max_len)
        )
        self._decode = jax.jit(
            lambda p, t, c, ctx: M.decode_step(p, t, c, cfg, ctx)
        )

        self._sample_decode = jax.jit(make_sample_decode(cfg))

    def generate(
        self,
        prompts: jax.Array,  # (B, S) token ids
        *,
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
    ) -> GenerateResult:
        logits, cache = self._prefill(self.params, {"tokens": prompts}, self.ctx)
        toks = [prompts]
        lps = []
        cur = logits
        key = key if key is not None else jax.random.PRNGKey(0)
        temp = jnp.float32(temperature)
        for _ in range(max_new_tokens):
            nxt, tok_lp, cur, cache, key = self._sample_decode(
                self.params, cur, cache, key, self.ctx, temp
            )
            lps.append(tok_lp)
            toks.append(nxt[:, None])
        return GenerateResult(
            tokens=jnp.concatenate(toks, axis=1), logprobs=jnp.stack(lps, axis=1)
        )
