"""Paged KV cache: page-pool layout, free-list allocation, byte accounting.

The dense serving cache allocates ``max_len`` KV slots per sequence up
front and holds them until the whole batch finishes. The paged layout
replaces that with a shared pool of fixed-size pages:

* the **pool** (``repro.models.model.init_paged_cache``) is a
  ``(L, num_pages, Hkv, page_size, hd)`` pair of zero-initialized arrays;
* each slot owns a **page chain** — a row of ``block_tables`` holding the
  page ids of its history in order, truncated to ``seq_lens[slot]`` tokens;
* the **allocator** (host-side, this module) hands page ids out of a free
  list at admission and takes them back at retirement, so a finished
  request's memory is reusable immediately, mid-flight.

Page 0 is *reserved*: it is never allocated, and the device-side write path
(``repro.models.layers.PagedKVView``) redirects masked-out slots' writes to
it, so a retired slot can never corrupt a page that has already been handed
to another request.

The device-side read path is a gather (``jnp.take`` over the pool by block
table) feeding per-slot masked dense attention — wired into
``models/model.py::decode_step``; the quantized TPU analog is
``repro.kernels.decode_attention.ops.paged_decode_attention``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "DEFAULT_PAGE_SIZE",
    "PageAllocator",
    "pages_needed",
    "round_up_to_page",
    "chain_layout",
    "dense_kv_bytes",
    "page_bytes",
]

DEFAULT_PAGE_SIZE = 8


def pages_needed(num_tokens: int, page_size: int) -> int:
    """Pages required to hold ``num_tokens`` KV entries."""
    return -(-int(num_tokens) // int(page_size))


def round_up_to_page(num_tokens: int, page_size: int) -> int:
    return pages_needed(num_tokens, page_size) * int(page_size)


@dataclass
class PageAllocator:
    """Host-side free-list allocator over a pool of ``num_pages`` pages.

    Page 0 is reserved as the scratch page for masked writes and is never
    handed out. Allocation is LIFO over the free list (freed pages are
    reused first — the pool stays compact); ``peak_pages`` tracks the
    high-water mark for resident-bytes accounting.

    The allocator tracks exactly which pages are outstanding (``_in_use``):
    freeing a page it never handed out — a double free OR a "foreign" free
    of a page owned by another chain, which the old in-free-list check
    could not see — raises instead of silently corrupting the free list
    with a page some other request is still writing.

    Observability counters consumed by :class:`repro.obs.hooks.PoolMonitor`:
    ``high_water`` (peak pages in use) and ``alloc_failures`` — the number
    of times an allocation was refused for lack of pages, counting both a
    failed :meth:`alloc` and a ``False`` answer from :meth:`can_alloc`
    (the admission loops probe ``can_alloc`` before committing, so each
    refusal is one backpressure stall).
    """

    num_pages: int
    page_size: int
    _free: list = field(default_factory=list)
    _in_use: set = field(default_factory=set)
    peak_pages: int = 0
    alloc_failures: int = 0

    def __post_init__(self):
        if self.num_pages < 2:
            raise ValueError(f"need >= 2 pages (page 0 is reserved), got {self.num_pages}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        # descending so pop() hands out low page ids first (stable tests)
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._in_use = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    @property
    def high_water(self) -> int:
        """Peak pages in use over the allocator's lifetime."""
        return self.peak_pages

    def can_alloc(self, n: int) -> bool:
        ok = n <= len(self._free)
        if not ok:
            self.alloc_failures += 1
        return ok

    def alloc(self, n: int) -> list[int]:
        if n < 1:
            raise ValueError(f"alloc needs n >= 1, got {n}")
        if n > len(self._free):
            self.alloc_failures += 1
            raise MemoryError(
                f"page pool exhausted: need {n} pages, {len(self._free)} free "
                f"of {self.num_pages - 1} allocatable"
            )
        out = [self._free.pop() for _ in range(n)]
        self._in_use.update(out)
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return out

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            p = int(p)
            if p in self._in_use:
                self._in_use.discard(p)
                self._free.append(p)
                continue
            if 0 < p < self.num_pages and p in self._free:
                raise ValueError(f"double free of page {p}")
            raise ValueError(
                f"free of page {p} this allocator never handed out "
                "(foreign page — reserved, outside the pool, or another "
                "allocator owns it)"
            )


def chain_layout(k_dense: jax.Array, page_size: int, chain_len: int) -> jax.Array:
    """Re-layout one sequence's dense KV ``(L, 1, Hkv, plen, hd)`` into page
    chain form ``(L, chain_len, Hkv, page_size, hd)`` for a one-shot scatter
    into the pool (``pool.at[:, page_ids].set(...)``). The tail page is
    zero-padded past ``plen``."""
    L, b, hkv, plen, hd = k_dense.shape
    if b != 1:
        raise ValueError(f"chain_layout takes one sequence, got batch {b}")
    total = chain_len * page_size
    if plen > total:
        raise ValueError(f"{plen} tokens exceed chain capacity {total}")
    k = jnp.pad(k_dense[:, 0], ((0, 0), (0, 0), (0, total - plen), (0, 0)))
    k = k.reshape(L, hkv, chain_len, page_size, hd)
    return jnp.moveaxis(k, 1, 2)  # (L, chain, Hkv, page, hd)


def _kv_entry_bytes(cfg) -> int:
    """Bytes of one token's K+V across all layers."""
    return 2 * cfg.num_layers * cfg.num_kv_heads * cfg.resolved_head_dim * jnp.dtype(cfg.dtype).itemsize


def page_bytes(cfg, page_size: int) -> int:
    """Resident bytes of ONE page (K+V, all layers)."""
    return _kv_entry_bytes(cfg) * int(page_size)


def dense_kv_bytes(cfg, batch: int, cache_len: int) -> int:
    """Resident bytes of a dense ``init_cache(cfg, batch, cache_len)``
    (window-bounded for SWA, mirroring ``model.cache_buffer_len``)."""
    buf = min(cfg.sliding_window, cache_len) if cfg.sliding_window else cache_len
    return _kv_entry_bytes(cfg) * int(batch) * int(buf)
