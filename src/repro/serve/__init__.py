"""repro.serve — the serving subsystem (see README.md in this directory).

Three engine tiers over one fused sampling+decode step:

* :mod:`repro.serve.engine` — :class:`ServeEngine`, static-batch generation
  (one rectangular prompt batch, dense KV cache, optional EOS masking).
* :mod:`repro.serve.continuous` — :class:`ContinuousBatchingEngine`,
  request queue + slot table over the paged KV cache
  (:mod:`repro.serve.kvcache`): admit into free slots, retire on EOS or
  budget, pages freed mid-flight.
* :mod:`repro.fleet.serve` — the fleet tiers: ``FleetServeEngine`` (vmap,
  shared prompts) and ``ShardedFleetServeEngine`` (shard_map over the pop
  mesh, one ragged request stream per chip).
"""
from repro.serve.continuous import (
    ContinuousBatchingEngine,
    Request,
    RequestOutput,
    ServeStats,
)
from repro.serve.engine import GenerateResult, ServeEngine, make_sample_decode
from repro.serve.kvcache import (
    DEFAULT_PAGE_SIZE,
    PageAllocator,
    dense_kv_bytes,
    page_bytes,
    pages_needed,
    round_up_to_page,
)

__all__ = [
    "ContinuousBatchingEngine",
    "DEFAULT_PAGE_SIZE",
    "GenerateResult",
    "PageAllocator",
    "Request",
    "RequestOutput",
    "ServeEngine",
    "ServeStats",
    "dense_kv_bytes",
    "make_sample_decode",
    "page_bytes",
    "pages_needed",
    "round_up_to_page",
]
