"""Continuous-batching serving engine over the paged KV cache.

The static engine (``repro.serve.engine.ServeEngine``) runs one rectangular
prompt batch to the longest request's horizon: a request that finishes at
token 5 burns a dispatch per token until the batch's longest request
finishes, and every sequence owns a dense ``max_len`` KV buffer for the
whole run. This module replaces that with the standard serving loop:

* a **request queue** of :class:`Request`\\ s (own prompt, own
  ``max_new_tokens``, own arrival step);
* a **slot table** of ``num_slots`` decode lanes; requests admit into free
  slots (prefill on arrival), retire on EOS or their own budget, and free
  their pages immediately so a waiting request refills the slot mid-flight;
* ONE fused jitted decode step for the whole slot table — the masked form
  of ``make_sample_decode`` (per-slot ``active`` masking, per-slot
  ``remaining`` budgets) over the paged cache from
  ``models/model.py::decode_step``.

Decode math per request is the same prefill + masked-attention math the
static engine runs, so greedy outputs are pinned token-for-token against
``ServeEngine`` on the same prompt with the same budget — including
requests admitted mid-flight (tests/test_serve_continuous.py).

Host/device split: sampling, masking and the paged read/write all live in
the one jitted step; the host loop only moves tiny per-slot flags (emitted
tokens, the active mask) to run admission/retirement between dispatches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masking import FaultContext, healthy
from repro.models import model as M
from repro.serve.engine import make_sample_decode
from repro.serve.kvcache import (
    DEFAULT_PAGE_SIZE,
    PageAllocator,
    chain_layout,
    page_bytes,
    pages_needed,
)

__all__ = [
    "Request",
    "RequestOutput",
    "ServeStats",
    "ContinuousBatchingEngine",
]


def prefill_to_chain(cfg, params, tokens, ctx, *, page_size: int, chain: int):
    """Prefill one request and lay its KV out as a page chain.

    Returns ``(logits (1, V), k_chain, v_chain)`` with the chains shaped
    ``(L, chain, Hkv, page_size, hd)`` for a one-shot pool scatter. Shared
    by the single-chip and fleet continuous engines.

    For sliding-window models whose prompt exceeds the window, prefill's
    cache is a ring buffer holding only the last ``window`` tokens: those
    are un-permuted back to linear order and placed at chain positions
    ``[plen - window, plen)`` — earlier positions stay zero, which is
    exact because the paged read path window-masks them out of every
    future query's softmax.
    """
    plen = tokens.shape[1]
    logits, dense = M.prefill(params, {"tokens": tokens}, cfg, ctx, cache_len=plen)
    win = cfg.sliding_window
    k, v = dense["k"], dense["v"]
    if win and plen > win:
        inv = jnp.asarray((np.arange(win) + plen) % win)  # undo the ring permutation
        pad = [(0, 0), (0, 0), (0, 0), (plen - win, 0), (0, 0)]
        k = jnp.pad(jnp.take(k, inv, axis=3), pad)
        v = jnp.pad(jnp.take(v, inv, axis=3), pad)
    return logits, chain_layout(k, page_size, chain), chain_layout(v, page_size, chain)


@dataclass(frozen=True)
class Request:
    """One generation request in a stream.

    ``arrival`` is the decode-dispatch index at (or after) which the request
    may be admitted — 0 means it is waiting before serving starts."""

    rid: int
    tokens: np.ndarray  # (prompt_len,) int token ids
    max_new_tokens: int
    arrival: int = 0

    def __post_init__(self):
        object.__setattr__(self, "tokens", np.asarray(self.tokens))
        if self.tokens.ndim != 1 or self.tokens.shape[0] < 1:
            raise ValueError(f"request {self.rid}: prompt must be a non-empty 1-D array")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >= 1")


@dataclass
class RequestOutput:
    rid: int
    prompt: np.ndarray
    tokens: np.ndarray  # (generated,) — includes the EOS token if hit
    logprobs: np.ndarray
    admitted_step: int  # dispatch index at admission (prefill time)
    finished_step: int  # dispatch index after the final token
    finish_reason: str  # "eos" | "length"

    @property
    def ttft(self) -> int:
        """Decode dispatches from serve start until this request's first
        token (its prefill emits no token; the next dispatch does)."""
        return self.admitted_step + 1


@dataclass
class ServeStats:
    decode_dispatches: int = 0
    prefill_dispatches: int = 0
    emitted_tokens: int = 0
    admitted: int = 0
    num_slots: int = 0
    page_size: int = 0
    active_slot_steps: int = 0  # sum over dispatches of active slots
    peak_resident_kv_bytes: int = 0
    kv_byte_steps: int = 0  # sum over dispatches of resident kv bytes

    @property
    def slot_utilization(self) -> float:
        if not self.decode_dispatches:
            return 0.0
        return self.active_slot_steps / (self.decode_dispatches * self.num_slots)

    def as_dict(self) -> dict:
        return dict(
            decode_dispatches=self.decode_dispatches,
            prefill_dispatches=self.prefill_dispatches,
            emitted_tokens=self.emitted_tokens,
            admitted=self.admitted,
            num_slots=self.num_slots,
            page_size=self.page_size,
            slot_utilization=self.slot_utilization,
            peak_resident_kv_bytes=self.peak_resident_kv_bytes,
            kv_byte_steps=self.kv_byte_steps,
        )


class _SlotTable:
    """Host-side slot bookkeeping for one chip's continuous-batch state.

    Owns the page allocator, the pending queue (arrival order, stable), the
    per-slot request records and the accumulating outputs. The device-side
    arrays live with the engine; this class only decides who sits where."""

    def __init__(self, requests: Sequence[Request], num_slots: int, allocator: PageAllocator,
                 max_pages_per_seq: int):
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError(f"duplicate request ids in stream: {sorted(rids)}")
        self.pending: list[Request] = sorted(
            requests, key=lambda r: (r.arrival, r.rid)
        )
        self.alloc = allocator
        self.max_pages_per_seq = max_pages_per_seq
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.slot_pages: list[list[int]] = [[] for _ in range(num_slots)]
        self.active = np.zeros(num_slots, bool)
        self.outputs: dict[int, RequestOutput] = {}
        self.outputs_admitted: dict[int, int] = {}  # rid -> admission clock
        self._tok: dict[int, list] = {}
        self._lp: dict[int, list] = {}
        for r in self.pending:
            need = pages_needed(len(r.tokens) + r.max_new_tokens, allocator.page_size)
            if need > max_pages_per_seq:
                raise ValueError(
                    f"request {r.rid} needs {need} pages "
                    f"(prompt {len(r.tokens)} + budget {r.max_new_tokens}) but "
                    f"max_pages_per_seq={max_pages_per_seq}"
                )

    @property
    def done(self) -> bool:
        return not self.pending and not self.active.any()

    def next_arrival(self) -> Optional[int]:
        return self.pending[0].arrival if self.pending else None

    def pop_admission(self, clock: int) -> Optional[tuple[int, Request, list[int]]]:
        """Admit the next arrived request into a free slot, allocating its
        full page chain. None when no slot/request/pages are available."""
        if not self.pending or self.pending[0].arrival > clock:
            return None
        free = [s for s, r in enumerate(self.slots) if r is None]
        if not free:
            return None
        r = self.pending[0]
        need = pages_needed(len(r.tokens) + r.max_new_tokens, self.alloc.page_size)
        if not self.alloc.can_alloc(need):
            if not self.active.any():
                raise MemoryError(
                    f"request {r.rid} needs {need} pages but only "
                    f"{self.alloc.free_pages} are free and no request is in "
                    "flight to retire — grow num_pages"
                )
            return None  # wait for a retirement to free pages
        self.pending.pop(0)
        slot = free[0]
        pages = self.alloc.alloc(need)
        self.slots[slot] = r
        self.slot_pages[slot] = pages
        self.active[slot] = True
        self._tok[r.rid] = []
        self._lp[r.rid] = []
        return slot, r, pages

    def record_step(
        self,
        emitted: np.ndarray,
        lps: np.ndarray,
        new_active: np.ndarray,
        clock: int,
        eos_id: Optional[int] = None,
    ) -> list[int]:
        """Record one dispatch's per-slot emissions; retire newly-finished
        slots (freeing their pages). Returns the retired rids."""
        retired = []
        for s, r in enumerate(self.slots):
            if r is None or not self.active[s]:
                continue
            self._tok[r.rid].append(int(emitted[s]))
            self._lp[r.rid].append(float(lps[s]))
            if not new_active[s]:
                toks = np.asarray(self._tok.pop(r.rid))
                # the EOS check wins even on the last budgeted token — it is
                # what actually cleared the slot's mask on the device
                reason = (
                    "eos"
                    if eos_id is not None and toks.size and toks[-1] == eos_id
                    else "length"
                )
                self.outputs[r.rid] = RequestOutput(
                    rid=r.rid,
                    prompt=np.asarray(r.tokens),
                    tokens=toks,
                    logprobs=np.asarray(self._lp.pop(r.rid)),
                    admitted_step=self.outputs_admitted[r.rid],
                    finished_step=clock,
                    finish_reason=reason,
                )
                self.alloc.free(self.slot_pages[s])
                self.slot_pages[s] = []
                self.slots[s] = None
                retired.append(r.rid)
        self.active = np.array(new_active, bool) & np.array(
            [r is not None for r in self.slots]
        )
        return retired


class ContinuousBatchingEngine:
    """Continuous batching on one chip: paged KV + slot table + one fused
    masked decode step per token across all in-flight requests."""

    def __init__(
        self,
        cfg,
        params,
        ctx: Optional[FaultContext] = None,
        *,
        num_slots: int = 4,
        page_size: int = DEFAULT_PAGE_SIZE,
        num_pages: int = 128,
        max_pages_per_seq: Optional[int] = None,
        pad_id: int = 0,
    ):
        if cfg.has_ssm:
            raise ValueError(
                f"continuous batching supports attention families only; "
                f"{cfg.family!r} carries unpaged SSM state"
            )
        if cfg.is_encoder:
            raise ValueError("encoder-only arch has no decode path")
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or healthy()
        self.num_slots = num_slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_pages_per_seq = max_pages_per_seq or (num_pages - 1)
        self.pad_id = pad_id
        self._page_bytes = page_bytes(cfg, page_size)
        # every loop-carried operand (cur logits, paged cache, key, active
        # mask, remaining budgets) is re-bound from the previous dispatch's
        # outputs — donate them all so the page pool never round-trips
        # through a copy (repro.analysis DON001); params/ctx/eos are reused
        # across dispatches and must stay undonated
        self._sample_decode = jax.jit(
            make_sample_decode(cfg, pad_id=pad_id), donate_argnums=(1, 2, 3, 6, 8)
        )
        self._prefill_admit = jax.jit(
            self._prefill_admit_fn,
            static_argnames=("chain",),
            donate_argnums=(3, 4, 5, 6),
        )

    # -- jitted pieces ------------------------------------------------------

    def _prefill_admit_fn(
        self, params, tokens, ctx, cache, cur, active, remaining, slot, pids, budget, *, chain
    ):
        """Prefill one request and splice it into the slot table: scatter its
        KV chain into the pool pages, write its block-table row, seed its
        logits/budget — one dispatch per admission."""
        plen = tokens.shape[1]
        logits, kc, vc = prefill_to_chain(
            self.cfg, params, tokens, ctx, page_size=self.page_size, chain=chain
        )
        row = jnp.zeros((self.max_pages_per_seq,), jnp.int32).at[:chain].set(pids)
        cache = dict(
            k_pages=cache["k_pages"].at[:, pids].set(kc.astype(cache["k_pages"].dtype)),
            v_pages=cache["v_pages"].at[:, pids].set(vc.astype(cache["v_pages"].dtype)),
            block_tables=cache["block_tables"].at[slot].set(row),
            seq_lens=cache["seq_lens"].at[slot].set(plen),
        )
        cur = cur.at[slot].set(logits[0].astype(cur.dtype))
        active = active.at[slot].set(True)
        remaining = remaining.at[slot].set(budget)
        return cache, cur, active, remaining

    # -- the serve loop -----------------------------------------------------

    def serve(
        self,
        requests: Sequence[Request],
        *,
        temperature: float = 0.0,
        eos_id: Optional[int] = None,
        key: Optional[jax.Array] = None,
    ) -> tuple[dict[int, RequestOutput], ServeStats]:
        """Serve a request stream to completion. Returns (outputs by rid,
        stats). Outputs include per-request TTFT and finish reason."""
        if not requests:
            return {}, ServeStats(num_slots=self.num_slots, page_size=self.page_size)
        alloc = PageAllocator(self.num_pages, self.page_size)
        table = _SlotTable(requests, self.num_slots, alloc, self.max_pages_per_seq)
        stats = ServeStats(num_slots=self.num_slots, page_size=self.page_size)

        V = self.cfg.vocab_size
        dtype = jnp.dtype(self.cfg.dtype)
        cache = M.init_paged_cache(
            self.cfg, self.num_pages, self.page_size, self.num_slots,
            self.max_pages_per_seq,
        )
        cur = jnp.zeros((self.num_slots, V), dtype)
        active = jnp.zeros((self.num_slots,), bool)
        remaining = jnp.zeros((self.num_slots,), jnp.int32)
        key = key if key is not None else jax.random.PRNGKey(0)
        temp = jnp.float32(temperature)
        eos = jnp.asarray(-1 if eos_id is None else eos_id, jnp.int32)

        clock = 0  # decode-dispatch index
        while not table.done:
            # admissions: fill free slots with every arrived request we can
            while True:
                adm = table.pop_admission(clock)
                if adm is None:
                    break
                slot, r, pages = adm
                cache, cur, active, remaining = self._prefill_admit(
                    self.params,
                    jnp.asarray(r.tokens, jnp.int32)[None],
                    self.ctx, cache, cur, active, remaining,
                    jnp.asarray(slot, jnp.int32),
                    jnp.asarray(pages, jnp.int32),
                    jnp.asarray(r.max_new_tokens, jnp.int32),
                    chain=len(pages),
                )
                table.outputs_admitted[r.rid] = clock
                stats.prefill_dispatches += 1
                stats.admitted += 1
                stats.peak_resident_kv_bytes = max(
                    stats.peak_resident_kv_bytes, alloc.pages_in_use * self._page_bytes
                )
            if not table.active.any():
                # idle: jump the clock to the next arrival (no dispatches)
                nxt = table.next_arrival()
                assert nxt is not None and nxt > clock
                clock = nxt
                continue

            n_active = int(table.active.sum())
            emitted, tok_lp, cur, cache, key, active, remaining = self._sample_decode(
                self.params, cur, cache, key, self.ctx, temp, active, eos, remaining
            )
            clock += 1
            stats.decode_dispatches += 1
            stats.emitted_tokens += n_active
            stats.active_slot_steps += n_active
            stats.kv_byte_steps += alloc.pages_in_use * self._page_bytes
            table.record_step(
                np.asarray(emitted), np.asarray(tok_lp), np.asarray(active), clock,
                eos_id=eos_id,
            )
        stats.peak_resident_kv_bytes = max(
            stats.peak_resident_kv_bytes, alloc.peak_pages * self._page_bytes
        )
        return table.outputs, stats
