"""Continuous-batching serving engine over the paged KV cache.

The static engine (``repro.serve.engine.ServeEngine``) runs one rectangular
prompt batch to the longest request's horizon: a request that finishes at
token 5 burns a dispatch per token until the batch's longest request
finishes, and every sequence owns a dense ``max_len`` KV buffer for the
whole run. This module replaces that with the standard serving loop:

* a **request queue** of :class:`Request`\\ s (own prompt, own
  ``max_new_tokens``, own arrival step);
* a **slot table** of ``num_slots`` decode lanes; requests admit into free
  slots (prefill on arrival), retire on EOS or their own budget, and free
  their pages immediately so a waiting request refills the slot mid-flight;
* ONE fused jitted decode step for the whole slot table — the masked form
  of ``make_sample_decode`` (per-slot ``active`` masking, per-slot
  ``remaining`` budgets) over the paged cache from
  ``models/model.py::decode_step``.

Admission runs over a CLOSED set of prefill shapes (``repro.serve.
bucketing``): prompts pad up to a small bucket ladder, several short
waiting prompts pack into one bucket dispatch as segment-masked rows of a
single packed sequence, and prompts longer than the top bucket stream into
their page chain in fixed-size chunks (``models/model.py::prefill_chunk``)
— so total prefill compile volume is O(|buckets|), independent of the
traffic's prompt-length mix, and :meth:`ContinuousBatchingEngine.warmup`
AOT-compiles every shape (``jit(...).lower().compile()``) before traffic
arrives. The static analyzer's recompile census
(``repro.analysis.recompile``) models exactly this signature set.

Decode math per request is the same prefill + masked-attention math the
static engine runs, so greedy outputs are pinned token-for-token against
``ServeEngine`` on the same prompt with the same budget — including
requests admitted mid-flight and packed/chunked admissions
(tests/test_serve_continuous.py).

Host/device split: sampling, masking and the paged read/write all live in
the jitted steps; the host loop only moves tiny per-slot flags (emitted
tokens, the active mask) to run admission/retirement between dispatches,
plus the int32 pack/chunk index maps built by ``repro.serve.bucketing``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masking import FaultContext, healthy
from repro.models import model as M
from repro.obs.alerts import AlertEngine, AlertRule
from repro.obs.health import HealthConfig, HealthTracker
from repro.obs.hooks import PoolMonitor, RequestTracer
from repro.obs.recorder import NULL_RECORDER, Recorder
from repro.serve.bucketing import (
    DEFAULT_PREFILL_BUCKETS,
    PackItem,
    bucket_of,
    build_pack,
    chunk_step_maps,
    plan_prefill,
    validate_buckets,
)
from repro.serve.engine import make_sample_decode
from repro.serve.kvcache import (
    DEFAULT_PAGE_SIZE,
    PageAllocator,
    page_bytes,
    pages_needed,
)

__all__ = [
    "Request",
    "RequestOutput",
    "ServeStats",
    "ContinuousBatchingEngine",
    "shape_structs",
]


def shape_structs(tree):
    """ShapeDtypeStruct mirror of a pytree — AOT lowering without arrays."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)), tree
    )


@dataclass(frozen=True)
class Request:
    """One generation request in a stream.

    ``arrival`` is the decode-dispatch index at (or after) which the request
    may be admitted — 0 means it is waiting before serving starts."""

    rid: int
    tokens: np.ndarray  # (prompt_len,) int token ids
    max_new_tokens: int
    arrival: int = 0

    def __post_init__(self):
        object.__setattr__(self, "tokens", np.asarray(self.tokens))
        if self.tokens.ndim != 1 or self.tokens.shape[0] < 1:
            raise ValueError(f"request {self.rid}: prompt must be a non-empty 1-D array")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >= 1")


@dataclass
class RequestOutput:
    rid: int
    prompt: np.ndarray
    tokens: np.ndarray  # (generated,) — includes the EOS token if hit
    logprobs: np.ndarray
    admitted_step: int  # dispatch index at admission (prefill time)
    finished_step: int  # dispatch index after the final token
    finish_reason: str  # "eos" | "length"
    queue_wait_steps: int = 0  # admitted_step - arrival (admission backpressure)
    ttft_wall_s: float = float("nan")  # arrival seen -> first token, wall clock

    @property
    def ttft(self) -> int:
        """Decode dispatches from serve start until this request's first
        token (its prefill emits no token; the next dispatch does)."""
        return self.admitted_step + 1


@dataclass
class ServeStats:
    decode_dispatches: int = 0
    prefill_dispatches: int = 0  # packed-bucket + chunk dispatches
    chunk_dispatches: int = 0  # chunked-prefill subset of the above
    probe_dispatches: int = 0  # ABFT canary/structured probe GEMMs
    emitted_tokens: int = 0
    admitted: int = 0
    num_slots: int = 0
    page_size: int = 0
    active_slot_steps: int = 0  # sum over dispatches of active slots
    peak_resident_kv_bytes: int = 0
    kv_byte_steps: int = 0  # sum over dispatches of resident kv bytes

    @property
    def slot_utilization(self) -> float:
        if not self.decode_dispatches:
            return 0.0
        return self.active_slot_steps / (self.decode_dispatches * self.num_slots)

    def as_dict(self) -> dict:
        return dict(
            decode_dispatches=self.decode_dispatches,
            prefill_dispatches=self.prefill_dispatches,
            chunk_dispatches=self.chunk_dispatches,
            probe_dispatches=self.probe_dispatches,
            emitted_tokens=self.emitted_tokens,
            admitted=self.admitted,
            num_slots=self.num_slots,
            page_size=self.page_size,
            slot_utilization=self.slot_utilization,
            peak_resident_kv_bytes=self.peak_resident_kv_bytes,
            kv_byte_steps=self.kv_byte_steps,
        )


class _SlotTable:
    """Host-side slot bookkeeping for one chip's continuous-batch state.

    Owns the page allocator, the pending queue (arrival order, stable), the
    per-slot request records and the accumulating outputs. The device-side
    arrays live with the engine; this class only decides who sits where."""

    def __init__(self, requests: Sequence[Request], num_slots: int, allocator: PageAllocator,
                 max_pages_per_seq: int):
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError(f"duplicate request ids in stream: {sorted(rids)}")
        self.pending: list[Request] = sorted(
            requests, key=lambda r: (r.arrival, r.rid)
        )
        self.alloc = allocator
        self.max_pages_per_seq = max_pages_per_seq
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.slot_pages: list[list[int]] = [[] for _ in range(num_slots)]
        self.active = np.zeros(num_slots, bool)
        self.outputs: dict[int, RequestOutput] = {}
        self.outputs_admitted: dict[int, int] = {}  # rid -> admission clock
        self._tok: dict[int, list] = {}
        self._lp: dict[int, list] = {}
        self._arrival_wall: dict[int, float] = {}  # rid -> wall time first eligible
        self._first_tok_wall: dict[int, float] = {}
        for r in self.pending:
            need = pages_needed(len(r.tokens) + r.max_new_tokens, allocator.page_size)
            if need > max_pages_per_seq:
                raise ValueError(
                    f"request {r.rid} needs {need} pages "
                    f"(prompt {len(r.tokens)} + budget {r.max_new_tokens}) but "
                    f"max_pages_per_seq={max_pages_per_seq}"
                )

    @property
    def done(self) -> bool:
        return not self.pending and not self.active.any()

    def next_arrival(self) -> Optional[int]:
        return self.pending[0].arrival if self.pending else None

    def stamp_arrivals(self, clock: int) -> None:
        """Record the wall time each pending request first became eligible
        (its arrival clock was reached) — the start of its queue wait."""
        now = time.perf_counter()
        for r in self.pending:
            if r.arrival > clock:
                break  # pending is arrival-sorted
            self._arrival_wall.setdefault(r.rid, now)

    def pop_admission(self, clock: int) -> Optional[tuple[int, Request, list[int]]]:
        """Admit the next arrived request into a free slot, allocating its
        full page chain. None when no slot/request/pages are available."""
        if not self.pending or self.pending[0].arrival > clock:
            return None
        free = [s for s, r in enumerate(self.slots) if r is None]
        if not free:
            return None
        r = self.pending[0]
        need = pages_needed(len(r.tokens) + r.max_new_tokens, self.alloc.page_size)
        if not self.alloc.can_alloc(need):
            if not self.active.any():
                raise MemoryError(
                    f"request {r.rid} needs {need} pages but only "
                    f"{self.alloc.free_pages} are free and no request is in "
                    "flight to retire — grow num_pages"
                )
            return None  # wait for a retirement to free pages
        self.pending.pop(0)
        slot = free[0]
        pages = self.alloc.alloc(need)
        self.slots[slot] = r
        self.slot_pages[slot] = pages
        self.active[slot] = True
        self._tok[r.rid] = []
        self._lp[r.rid] = []
        return slot, r, pages

    def record_step(
        self,
        emitted: np.ndarray,
        lps: np.ndarray,
        new_active: np.ndarray,
        clock: int,
        eos_id: Optional[int] = None,
    ) -> list[int]:
        """Record one dispatch's per-slot emissions; retire newly-finished
        slots (freeing their pages). Returns the retired rids."""
        retired = []
        now = time.perf_counter()
        for s, r in enumerate(self.slots):
            if r is None or not self.active[s]:
                continue
            self._tok[r.rid].append(int(emitted[s]))
            self._lp[r.rid].append(float(lps[s]))
            if len(self._tok[r.rid]) == 1:
                self._first_tok_wall[r.rid] = now
            if not new_active[s]:
                toks = np.asarray(self._tok.pop(r.rid))
                # the EOS check wins even on the last budgeted token — it is
                # what actually cleared the slot's mask on the device
                reason = (
                    "eos"
                    if eos_id is not None and toks.size and toks[-1] == eos_id
                    else "length"
                )
                admitted = self.outputs_admitted[r.rid]
                t0 = self._arrival_wall.get(r.rid)
                t1 = self._first_tok_wall.get(r.rid)
                self.outputs[r.rid] = RequestOutput(
                    rid=r.rid,
                    prompt=np.asarray(r.tokens),
                    tokens=toks,
                    logprobs=np.asarray(self._lp.pop(r.rid)),
                    admitted_step=admitted,
                    finished_step=clock,
                    finish_reason=reason,
                    queue_wait_steps=admitted - r.arrival,
                    ttft_wall_s=(t1 - t0) if t0 is not None and t1 is not None else float("nan"),
                )
                self.alloc.free(self.slot_pages[s])
                self.slot_pages[s] = []
                self.slots[s] = None
                retired.append(r.rid)
        self.active = np.array(new_active, bool) & np.array(
            [r is not None for r in self.slots]
        )
        return retired


class ContinuousBatchingEngine:
    """Continuous batching on one chip: paged KV + slot table + one fused
    masked decode step per token across all in-flight requests, admitted
    through the bucketed/packed/chunked planner (``repro.serve.bucketing``).

    ``prefill_buckets=None`` disables the planner (one exact-length
    admission program per distinct prompt length — the unbucketed baseline
    ``benchmarks/serve_bench.py --heavy-traffic`` measures against).
    """

    def __init__(
        self,
        cfg,
        params,
        ctx: Optional[FaultContext] = None,
        *,
        num_slots: int = 4,
        page_size: int = DEFAULT_PAGE_SIZE,
        num_pages: int = 128,
        max_pages_per_seq: Optional[int] = None,
        pad_id: int = 0,
        prefill_buckets: Optional[Sequence[int]] = DEFAULT_PREFILL_BUCKETS,
        chunk_size: Optional[int] = None,
        max_pack: int = 4,
        recorder: Optional[Recorder] = None,
        probe_every: Optional[int] = None,
        health_config: Optional[HealthConfig] = None,
        alert_rules: Optional[Sequence[AlertRule]] = None,
    ):
        if cfg.has_ssm:
            raise ValueError(
                f"continuous batching supports attention families only; "
                f"{cfg.family!r} carries unpaged SSM state"
            )
        if cfg.is_encoder:
            raise ValueError("encoder-only arch has no decode path")
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or healthy()
        self.num_slots = num_slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_pages_per_seq = max_pages_per_seq or (num_pages - 1)
        self.pad_id = pad_id
        # observability: every hook below is host-side and gated on the
        # recorder's truthiness, so an absent/disabled recorder costs one
        # check per dispatch and recording cannot touch traced code (greedy
        # parity with recorder on vs off is pinned in tests/test_obs.py)
        self.obs = recorder if recorder is not None else NULL_RECORDER
        self._page_bytes = page_bytes(cfg, page_size)
        if prefill_buckets is None:
            self.prefill_buckets = None
            self.chunk_size: Optional[int] = None
            self.max_pack = 1
        else:
            self.prefill_buckets = validate_buckets(prefill_buckets)
            self.chunk_size = int(chunk_size) if chunk_size else self.prefill_buckets[-1]
            if self.chunk_size < page_size or self.chunk_size % page_size:
                raise ValueError(
                    f"chunk_size {self.chunk_size} must be a positive multiple "
                    f"of page_size {page_size} (chunk starts must be page-aligned)"
                )
            if max_pack < 1:
                raise ValueError(f"max_pack must be >= 1, got {max_pack}")
            self.max_pack = int(max_pack)
        # every loop-carried operand (cur logits, paged cache, key, active
        # mask, remaining budgets) is re-bound from the previous dispatch's
        # outputs — donate them all so the page pool never round-trips
        # through a copy (repro.analysis DON001); params/ctx/eos and the
        # host-built pack/chunk index maps are reused or rebuilt per call
        # and stay undonated
        self._sample_decode = jax.jit(
            make_sample_decode(cfg, pad_id=pad_id), donate_argnums=(1, 2, 3, 6, 8)
        )
        self._packed_admit = jax.jit(
            self._packed_admit_fn, donate_argnums=(5, 6, 7, 8)
        )
        self._prefill_chunk = jax.jit(
            self._prefill_chunk_fn, donate_argnums=(3, 4, 5, 6)
        )
        # AOT-compiled executables by program key — see warmup(); dispatch
        # prefers these, falling back to the jit wrappers above (whose
        # _cache_size() then counts traffic-time compiles)
        self._aot: dict = {}
        self.used_programs: set = set()
        # fault detection (ROADMAP item 2): an ABFT prober dispatched every
        # probe_every decode dispatches, feeding the health state machine
        # and the alert engine. Probes are SEPARATE dispatches through a
        # separate jitted program (outside compile_counts()/used_programs)
        # and never touch the serve loop's carried state or key stream, so
        # the PR-8 guarantee holds: enabling them changes no sampled token.
        if probe_every is not None and probe_every < 1:
            raise ValueError(f"probe_every must be >= 1, got {probe_every}")
        self.probe_every = int(probe_every) if probe_every else None
        self.prober = None
        self.health: Optional[HealthTracker] = None
        self.alerts = AlertEngine(self.obs, alert_rules) if alert_rules else None
        if self.probe_every:
            self._init_prober(health_config)

    def _init_prober(self, health_config: Optional[HealthConfig]) -> None:
        from repro.kernels.masked_matmul.ops import masked_matmul_checksummed
        from repro.obs.abft import ChipProber, select_probe_weight

        cfg = self.cfg
        rows, cols = cfg.array_rows, cfg.array_cols
        name, w = select_probe_weight(self.params)
        probe_fn = jax.jit(masked_matmul_checksummed)
        ones = jnp.ones((rows, cols), jnp.float32)
        dtype = jnp.dtype(cfg.dtype)

        def dispatch(x):
            # the LIVE mask: re-read self.ctx so a set_silicon() change is
            # what the next probe computes through (same shape, no recompile)
            ok = self.ctx.ok if self.ctx.ok is not None else ones
            y, chk = probe_fn(jnp.asarray(x, dtype), w, ok)
            return np.asarray(y), np.asarray(chk)

        self._probe_weight = name
        # snapshotting compiles the probe program and records goldens under
        # the believed map — before traffic, so probes never jit mid-serve
        self.prober = ChipProber(
            dispatch, array_shape=(rows, cols), k_dim=int(w.shape[0])
        )
        self.health = HealthTracker(
            1, self.obs, config=health_config, proc="serve"
        )

    def set_silicon(self, ctx: FaultContext) -> None:
        """Simulate a mid-flight silicon change: swap the LIVE fault context
        every subsequent dispatch (decode, prefill, probes) computes
        through, WITHOUT rebasing the prober's golden snapshots — so the
        next probe sees the divergence. The engine must have been built
        with an ACTIVE context of the same mask shape (a zero-fault
        ``FaultMap`` context models pristine silicon): the AOT executables
        were compiled for that pytree structure and an ok=None ↔ ok=array
        flip would be a different program."""
        cur = self.ctx
        if cur.ok is None or ctx is None or ctx.ok is None:
            raise ValueError(
                "set_silicon needs ACTIVE fault contexts on both sides; "
                "construct the engine with an explicit (possibly zero-fault)"
                " FaultMap context so the mask is a live program input"
            )
        if cur.mode != ctx.mode or tuple(cur.ok.shape) != tuple(ctx.ok.shape):
            raise ValueError(
                f"silicon change must keep mode/shape: have "
                f"{cur.mode}/{tuple(cur.ok.shape)}, "
                f"got {ctx.mode}/{tuple(ctx.ok.shape)}"
            )
        self.ctx = ctx

    # -- jitted pieces ------------------------------------------------------

    def _packed_admit_fn(
        self, params, tokens, positions, segments, ctx, cache, cur, active,
        remaining, page_ix, page_off, gather_pos, slots, rows, seq_lens, budgets,
    ):
        """Admit a PACK of requests in one bucket-shaped dispatch: run the
        segment-masked prefill over the packed row, scatter every token's KV
        into its request's page chain (pad tokens hit the scratch page 0),
        gather each segment's last-token hidden state for its first logits,
        and splice per-slot state (unused pack lanes scatter out-of-bounds
        at ``slot == num_slots`` and are dropped). One compiled program per
        bucket, independent of pack occupancy and prompt lengths."""
        hidden, dense = M.prefill(
            params, {"tokens": tokens, "positions": positions}, self.cfg, ctx,
            full_kv=True, return_hidden=True, segments=segments, attn_impl="dense",
        )
        # (L, 1, Hkv, W, hd) -> (W, L, Hkv, hd): the advanced indices
        # (page_ix, page_off) around the Hkv slice put the token dim first
        k = jnp.transpose(dense["k"][:, 0], (2, 0, 1, 3))
        v = jnp.transpose(dense["v"][:, 0], (2, 0, 1, 3))
        kp = cache["k_pages"].at[:, page_ix, :, page_off].set(k.astype(cache["k_pages"].dtype))
        vp = cache["v_pages"].at[:, page_ix, :, page_off].set(v.astype(cache["v_pages"].dtype))
        h = hidden[0, gather_pos]  # (max_pack, d) — one last-token row per segment
        logits = M.unembed(self.cfg, params, h[None], ctx)[0]  # (max_pack, V)
        cache = dict(
            k_pages=kp,
            v_pages=vp,
            block_tables=cache["block_tables"].at[slots].set(rows),
            seq_lens=cache["seq_lens"].at[slots].set(seq_lens),
        )
        cur = cur.at[slots].set(logits.astype(cur.dtype))
        active = active.at[slots].set(True)
        remaining = remaining.at[slots].set(budgets)
        return cache, cur, active, remaining

    def _prefill_chunk_fn(
        self, params, tokens, ctx, cache, cur, active, remaining,
        slot, row, page_ix, page_off, prefix, valid, budget, activate,
    ):
        """One chunk of a long prompt: continue against the slot's paged
        prefix (``models/model.py::prefill_chunk``), scatter the chunk's KV
        into the chain, and — on the final chunk (``activate``) — seed the
        slot's logits/budget and flip it live. Prefix/valid are traced, so
        every chunk of every prompt shares one compiled program."""
        logits, kc, vc = M.prefill_chunk(
            params, tokens, self.cfg, ctx,
            k_pages=cache["k_pages"], v_pages=cache["v_pages"], row=row,
            prefix_len=prefix, valid_len=valid,
        )
        k = jnp.transpose(kc[:, 0], (2, 0, 1, 3))
        v = jnp.transpose(vc[:, 0], (2, 0, 1, 3))
        new_len = jnp.where(activate, prefix + valid, cache["seq_lens"][slot])
        cache = dict(
            k_pages=cache["k_pages"].at[:, page_ix, :, page_off].set(k.astype(cache["k_pages"].dtype)),
            v_pages=cache["v_pages"].at[:, page_ix, :, page_off].set(v.astype(cache["v_pages"].dtype)),
            block_tables=cache["block_tables"].at[slot].set(row),
            seq_lens=cache["seq_lens"].at[slot].set(new_len),
        )
        cur = cur.at[slot].set(jnp.where(activate, logits[0].astype(cur.dtype), cur[slot]))
        active = active.at[slot].set(active[slot] | activate)
        remaining = remaining.at[slot].set(jnp.where(activate, budget, remaining[slot]))
        return cache, cur, active, remaining

    # -- AOT warmup ---------------------------------------------------------

    def _state_structs(self):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        L, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        pool = jax.ShapeDtypeStruct((L, self.num_pages, hkv, self.page_size, hd), dtype)
        i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        cache = dict(
            k_pages=pool, v_pages=pool,
            block_tables=i32(self.num_slots, self.max_pages_per_seq),
            seq_lens=i32(self.num_slots),
        )
        cur = jax.ShapeDtypeStruct((self.num_slots, cfg.vocab_size), dtype)
        active = jax.ShapeDtypeStruct((self.num_slots,), jnp.bool_)
        remaining = i32(self.num_slots)
        return cache, cur, active, remaining

    def warmup(self) -> int:
        """AOT-precompile the closed program set before traffic arrives:
        one packed-admit program per bucket, the chunk program, and the
        fused decode step — ``jit(...).lower().compile()`` each, stored as
        executables the serve loop dispatches through directly. After
        warmup, traffic-time jit compiles (``compile_counts()``'s
        ``jit_fallback``) stay at zero. Returns the AOT program count."""
        if self.prefill_buckets is None:
            raise ValueError("warmup() needs bucketed prefill; prefill_buckets is None")
        params_s = shape_structs(self.params)
        ctx_s = shape_structs(self.ctx)
        cache, cur, active, remaining = self._state_structs()
        i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        K, maxp = self.max_pack, self.max_pages_per_seq
        for w in self.prefill_buckets:
            key = ("prefill_admit", w)
            if key not in self._aot:
                self._aot[key] = self._packed_admit.lower(
                    params_s, i32(1, w), i32(1, w), i32(1, w), ctx_s,
                    cache, cur, active, remaining,
                    i32(w), i32(w), i32(K), i32(K), i32(K, maxp), i32(K), i32(K),
                ).compile()
        c = self.chunk_size
        key = ("prefill_chunk", c)
        if key not in self._aot:
            self._aot[key] = self._prefill_chunk.lower(
                params_s, i32(1, c), ctx_s, cache, cur, active, remaining,
                i32(), i32(maxp), i32(c), i32(c), i32(), i32(), i32(),
                jax.ShapeDtypeStruct((), jnp.bool_),
            ).compile()
        key = ("decode",)
        if key not in self._aot:
            self._aot[key] = self._sample_decode.lower(
                params_s, cur, cache, shape_structs(jax.random.PRNGKey(0)), ctx_s,
                jax.ShapeDtypeStruct((), jnp.float32), active, i32(), remaining,
            ).compile()
        return len(self._aot)

    def compile_counts(self) -> dict:
        """Compile accounting: AOT executables (warmup), traffic-time jit
        fallback compiles, and the program keys actually dispatched."""
        jit_fallback = (
            self._packed_admit._cache_size()
            + self._prefill_chunk._cache_size()
            + self._sample_decode._cache_size()
        )
        return dict(
            aot=len(self._aot),
            jit_fallback=jit_fallback,
            total=len(self._aot) + jit_fallback,
            used=sorted(map(str, self.used_programs)),
        )

    # -- the serve loop -----------------------------------------------------

    def serve(
        self,
        requests: Sequence[Request],
        *,
        temperature: float = 0.0,
        eos_id: Optional[int] = None,
        key: Optional[jax.Array] = None,
        on_step: Optional[Callable[[int], None]] = None,
    ) -> tuple[dict[int, RequestOutput], ServeStats]:
        """Serve a request stream to completion. Returns (outputs by rid,
        stats). Outputs include per-request TTFT, queue wait and finish
        reason. ``on_step(clock)`` runs at the top of every scheduler
        round — the injection hook benchmarks use to flip silicon
        mid-serve (``set_silicon``)."""
        if not requests:
            return {}, ServeStats(num_slots=self.num_slots, page_size=self.page_size)
        alloc = PageAllocator(self.num_pages, self.page_size)
        table = _SlotTable(requests, self.num_slots, alloc, self.max_pages_per_seq)
        stats = ServeStats(num_slots=self.num_slots, page_size=self.page_size)
        rec = self.obs
        tracer = RequestTracer(rec, proc="serve")
        pool = PoolMonitor(rec, alloc, proc="serve")
        enqueued: set = set()

        V = self.cfg.vocab_size
        dtype = jnp.dtype(self.cfg.dtype)
        cache = M.init_paged_cache(
            self.cfg, self.num_pages, self.page_size, self.num_slots,
            self.max_pages_per_seq,
        )
        cur = jnp.zeros((self.num_slots, V), dtype)
        active = jnp.zeros((self.num_slots,), bool)
        remaining = jnp.zeros((self.num_slots,), jnp.int32)
        key = key if key is not None else jax.random.PRNGKey(0)
        temp = jnp.float32(temperature)
        eos = jnp.asarray(-1 if eos_id is None else eos_id, jnp.int32)
        buckets = self.prefill_buckets
        top = buckets[-1] if buckets else None
        pack: list[PackItem] = []

        def flush_pack():
            nonlocal cache, cur, active, remaining
            if not pack:
                return
            total = sum(len(it.tokens) for it in pack)
            width = total if buckets is None else bucket_of(total, buckets)
            arrays = build_pack(
                pack, bucket=width, max_pack=self.max_pack,
                page_size=self.page_size, max_pages_per_seq=self.max_pages_per_seq,
                num_slots=self.num_slots, pad_id=self.pad_id,
            )
            pkey = ("prefill_admit", width)
            fn = self._aot.get(pkey, self._packed_admit)
            t0 = rec.now() if rec else 0.0
            cache, cur, active, remaining = fn(
                self.params, arrays["tokens"], arrays["positions"],
                arrays["segments"], self.ctx, cache, cur, active, remaining,
                arrays["page_ix"], arrays["page_off"], arrays["gather_pos"],
                arrays["slots"], arrays["rows"], arrays["seq_lens"],
                arrays["budgets"],
            )
            self.used_programs.add(pkey)
            stats.prefill_dispatches += 1
            if rec:
                jax.block_until_ready(cur)
                t1 = rec.now()
                for it in pack:
                    tracer.admitted(
                        it.rid, it.slot, t0, t1,
                        args=dict(bucket=width, packed=len(pack),
                                  prompt_len=len(it.tokens)),
                    )
            pack.clear()

        def run_chunks(slot, r, pages):
            nonlocal cache, cur, active, remaining
            steps = plan_prefill(
                len(r.tokens), buckets=buckets, chunk_size=self.chunk_size
            )
            toks = np.asarray(r.tokens, np.int32)
            row = np.zeros((self.max_pages_per_seq,), np.int32)
            row[: len(pages)] = pages
            for st in steps:
                maps = chunk_step_maps(st, pages, page_size=self.page_size)
                ct = np.full((st.size,), self.pad_id, np.int32)
                ct[: st.valid] = toks[st.start : st.start + st.valid]
                ckey = ("prefill_chunk", st.size)
                fn = self._aot.get(ckey, self._prefill_chunk)
                t0 = rec.now() if rec else 0.0
                cache, cur, active, remaining = fn(
                    self.params, ct[None], self.ctx, cache, cur, active,
                    remaining, np.int32(slot), row, maps["page_ix"],
                    maps["page_off"], np.int32(st.start), np.int32(st.valid),
                    np.int32(r.max_new_tokens), np.bool_(st.final),
                )
                self.used_programs.add(ckey)
                stats.prefill_dispatches += 1
                stats.chunk_dispatches += 1
                if rec:
                    jax.block_until_ready(cur)
                    tracer.chunk(
                        r.rid, slot, t0, rec.now(), final=st.final,
                        args=dict(size=st.size, start=st.start, valid=st.valid),
                    )

        clock = 0  # decode-dispatch index
        while not table.done:
            if on_step is not None:
                on_step(clock)
            table.stamp_arrivals(clock)
            if rec:
                for r in table.pending:
                    if r.arrival > clock:
                        break  # pending is arrival-sorted
                    if r.rid not in enqueued:
                        enqueued.add(r.rid)
                        rec.instant("enqueue", proc="serve", track="engine",
                                    args=dict(rid=r.rid, arrival=r.arrival,
                                              clock=clock))
            # admissions: fill free slots with every arrived request we can,
            # packing short prompts into shared bucket dispatches
            while True:
                adm = table.pop_admission(clock)
                if adm is None:
                    break
                slot, r, pages = adm
                table.outputs_admitted[r.rid] = clock
                stats.admitted += 1
                plen = len(r.tokens)
                if top is not None and plen > top:
                    flush_pack()
                    run_chunks(slot, r, pages)
                    continue
                if pack and (
                    len(pack) >= self.max_pack
                    or (top is not None and sum(len(i.tokens) for i in pack) + plen > top)
                ):
                    flush_pack()
                pack.append(
                    PackItem(np.asarray(r.tokens, np.int32), slot, tuple(pages),
                             r.max_new_tokens, rid=r.rid)
                )
            flush_pack()
            stats.peak_resident_kv_bytes = max(
                stats.peak_resident_kv_bytes, alloc.pages_in_use * self._page_bytes
            )
            pool.sample()
            if not table.active.any():
                # idle: jump the clock to the next arrival (no dispatches)
                nxt = table.next_arrival()
                assert nxt is not None and nxt > clock
                clock = nxt
                continue

            n_active = int(table.active.sum())
            dfn = self._aot.get(("decode",), self._sample_decode)
            t0 = rec.now() if rec else 0.0
            emitted, tok_lp, cur, cache, key, active, remaining = dfn(
                self.params, cur, cache, key, self.ctx, temp, active, eos, remaining
            )
            self.used_programs.add(("decode",))
            clock += 1
            stats.decode_dispatches += 1
            stats.emitted_tokens += n_active
            stats.active_slot_steps += n_active
            stats.kv_byte_steps += alloc.pages_in_use * self._page_bytes
            em = np.asarray(emitted)  # forces the dispatch to completion
            lp = np.asarray(tok_lp)
            ac = np.asarray(active)
            if rec:
                t1 = rec.now()
                tracer.decode_dispatch(t0, t1, n_active=n_active, clock=clock)
                slot_of = {r.rid: s for s, r in enumerate(table.slots)
                           if r is not None}
            if self.health is not None:
                msk = table.active  # the mask this dispatch computed under
                self.health.observe_decode(
                    0, clock=clock,
                    mean_logprob=float(lp[msk].mean()) if msk.any() else None,
                    alloc_failures=alloc.alloc_failures,
                )
            retired = table.record_step(em, lp, ac, clock, eos_id=eos_id)
            if rec and retired:
                t1 = rec.now()
                for rid in retired:
                    tracer.retired(table.outputs[rid], slot_of[rid], t1)
                pool.sample()
            if self.prober is not None and clock % self.probe_every == 0:
                t0p = rec.now() if rec else 0.0
                res = self.prober.probe(clock=clock)
                stats.probe_dispatches += res.dispatches
                if rec:
                    rec.span("probe", proc="serve", track="health",
                             t0=t0p, t1=rec.now(), args=res.as_dict())
                    rec.count("probe.dispatches", res.dispatches)
                self.health.observe_probe(0, res, clock=clock)
                if self.alerts:
                    self.alerts.evaluate(clock=clock)
        stats.peak_resident_kv_bytes = max(
            stats.peak_resident_kv_bytes, alloc.peak_pages * self._page_bytes
        )
        pool.flush()  # close the counter series at the final timestamp
        if self.health is not None:
            self.health.finalize()
        if self.alerts:
            self.alerts.evaluate(clock=clock)
        if rec:
            cc = self.compile_counts()
            rec.gauge_set("serve.compiles.aot", cc["aot"])
            rec.gauge_set("serve.compiles.jit_fallback", cc["jit_fallback"])
            rec.gauge_set("serve.compiles.total", cc["total"])
            rec.instant("serve.end", proc="serve", track="engine",
                        args=stats.as_dict())
        return table.outputs, stats
