"""Deterministic synthetic data pipelines.

Two streams:
  * token_stream — LM batches with a learnable structure (a noisy k-order
    markov/copy task) so cross-entropy and accuracy actually improve with
    training; seekable by step for fault-tolerant resume.
  * cluster_classification — the CPU-scale classification task used by the
    paper-faithful eFAT experiments (stands in for CIFAR; steps-to-accuracy
    is measurable in seconds).

Everything is derived from (seed, step) — no state to checkpoint beyond the
step counter, which is exactly what makes deterministic data-skip resume and
straggler re-entry trivial (DESIGN.md S4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenStream", "ClusterData", "make_classification_task"]


@dataclass
class TokenStream:
    """Seekable LM batch stream.

    Sequences follow a 'noisy copy with shift' law: token[t] depends on
    token[t-1] via a fixed random permutation with noise — a next-token task
    a small LM learns quickly, so FAT dynamics are visible.
    """

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    noise: float = 0.1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.perm = jnp.asarray(rng.permutation(self.vocab_size))

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s, v = self.batch_size, self.seq_len, self.vocab_size

        first = jax.random.randint(k1, (b, 1), 0, v)
        noise_mask = jax.random.bernoulli(k2, self.noise, (b, s))
        noise_tok = jax.random.randint(k3, (b, s), 0, v)

        def step_fn(tok, i):
            nxt = self.perm[tok]
            nxt = jnp.where(noise_mask[:, i], noise_tok[:, i], nxt)
            return nxt, nxt

        _, toks = jax.lax.scan(step_fn, first[:, 0], jnp.arange(s))
        tokens = jnp.moveaxis(toks, 0, 1)  # (b, s)
        labels = jnp.concatenate([tokens[:, 1:], self.perm[tokens[:, -1:]]], axis=1)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class ClusterData:
    """Gaussian-cluster classification (paper-faithful experiment substrate).

    ``num_classes`` well-separated anisotropic clusters in ``dim`` dims; a
    small MLP reaches >95% accuracy in a few hundred steps on one CPU core,
    so the resilience analysis (steps-to-constraint at many fault rates x
    repeats) finishes in minutes, as the paper's CIFAR runs did on a GPU.
    """

    dim: int = 32
    num_classes: int = 16
    seed: int = 0
    spread: float = 0.3

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        centers = rng.normal(size=(self.num_classes, self.dim))
        self.centers = jnp.asarray(
            centers / np.linalg.norm(centers, axis=1, keepdims=True)
        )

    def batch_at(self, step: int, batch_size: int = 256, split: str = "train") -> dict:
        salt = 0 if split == "train" else 10_000_019
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + salt), step)
        k1, k2 = jax.random.split(key)
        y = jax.random.randint(k1, (batch_size,), 0, self.num_classes)
        x = self.centers[y] + self.spread * jax.random.normal(
            k2, (batch_size, self.dim)
        )
        return {"x": x, "labels": y}

    def eval_batches(self, n: int = 4, batch_size: int = 512):
        return [self.batch_at(i, batch_size, split="eval") for i in range(n)]


def make_classification_task(cfg, seed: int = 0) -> ClusterData:
    """Dataset sized to the paper_mlp config (vocab_size == num classes)."""
    return ClusterData(dim=cfg.d_model // 4, num_classes=cfg.vocab_size, seed=seed)
