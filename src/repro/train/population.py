"""Population FAT engines — train a fleet of fault maps as ONE program.

The whole point of eFAT is amortizing retraining over many faulty chips,
yet a naive pipeline trains one fault map at a time: the Step-1 resilience
sweep, Step-4 plan execution and every SIV-C baseline differ per job only
in a tiny (R, C) mask constant. ``FaultContext`` is a pytree whose single
leaf is that mask, so a population of N jobs is just a batched context
(leading population axis on ``ok``, shared static mode) plus per-member
``(params, opt_state)`` — which ``jax.vmap`` turns into one batched train
step and ``jax.lax`` loops turn into one compiled program:

* :class:`PopulationFATEngine` — ``fit_batch`` runs all members through a
  single ``fori_loop`` with per-member step budgets enforced by a select
  mask (a member stops receiving updates after its own budget, exactly as
  if it had been trained alone); ``steps_to_constraint_batch`` runs a
  ``while_loop`` of eval-period chunks with in-loop periodic eval and
  records each member's first constraint crossing via a ``lax`` mask,
  exiting early once every member has crossed. N fault maps cost one
  dispatch, not N Python loops of per-step dispatches.
* :class:`SerialFATEngine` — the reference implementation (one Python loop
  per member, jitted grad + eager optimizer), kept behind
  ``engine="serial"`` and used to prove numerical equivalence in tests.

Both engines share one interface so ``ClassifierFATTrainer`` /
``LMFATTrainer`` delegate their ``_fit`` / ``steps_to_constraint`` bodies
here unchanged. Memory scales linearly with the population, so batched
calls are chunked to ``population_size`` members; chunking only changes
how work is submitted, never per-member math.

A third engine, ``repro.fleet.sharding.ShardedPopulationEngine``
(``engine="sharded"``), subclasses the population engine and wraps the same
run bodies in ``shard_map`` over a "pop" mesh axis so each device trains a
sub-population — see ``src/repro/fleet/README.md``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masking import FaultContext, healthy, stack_contexts
from repro.obs.recorder import NULL_RECORDER, Recorder
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["PopulationFATEngine", "SerialFATEngine", "make_fat_engine"]

# steps-to-constraint bucket ladder (training steps, not seconds)
STEPS_BUCKETS = (0.0, 5.0, 10.0, 20.0, 40.0, 80.0, 160.0, 320.0, 640.0, 1280.0)

# batch_fn(step) -> batch dict; must be jax-traceable in ``step`` for the
# population engine (the deterministic (seed, step) streams in
# repro.data.synthetic are).
BatchFn = Callable[[Any], dict]


def _stack_trees(trees: Sequence[Any]):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _member_slice(tree, i: int):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


class PopulationFATEngine:
    """vmap + scan FAT over a population of fault maps.

    Parameters
    ----------
    loss_fn : ``(params, batch, ctx) -> (loss, metrics)`` — the per-member
        training objective; ``metrics[metric]`` is the constraint metric.
    opt_cfg : AdamW settings shared by every member.
    eval_batches : the fixed eval batches; stacked once and evaluated
        in-program.
    metric / higher_is_better : constraint metric key and its direction
        (``loss`` style metrics are negated so 'metric >= constraint' is
        uniform, matching the serial trainers' protocol).
    eval_every : periodic-eval interval inside ``steps_to_constraint_batch``.
    population_size : max members per compiled program; larger batches are
        chunked (memory / compile-shape trade-off, see train/README.md).
    param_axes : optional logical-axes pytree mirroring the params structure
        (``repro.launch.sharding`` names). Ignored by this engine and the
        serial reference; the fleet engine uses it to lay member params out
        over the "model" axis of a 2-D ``("pop", "model")`` mesh.
    recorder : optional :class:`repro.obs.Recorder`. Per-lane telemetry is
        collected host-side at chunk boundaries — chunk spans with lane
        widths and wasted lane-steps, per-member constraint-crossing
        instants, steps-consumed-vs-budget counters — so nothing enters the
        traced run bodies and the serial↔vmap↔sharded pins hold untouched.
    """

    kind = "population"

    def __init__(
        self,
        *,
        loss_fn,
        opt_cfg: AdamWConfig,
        eval_batches: Sequence[dict],
        metric: str = "accuracy",
        higher_is_better: bool = True,
        eval_every: int = 5,
        population_size: int = 16,
        param_axes: Optional[Any] = None,
        recorder: Optional[Recorder] = None,
    ):
        self.loss_fn = loss_fn
        self.opt_cfg = opt_cfg
        self.metric = metric
        self.higher_is_better = higher_is_better
        self.eval_every = int(eval_every)
        self.population_size = max(1, int(population_size))
        self.param_axes = param_axes
        self.obs = recorder if recorder is not None else NULL_RECORDER
        self._eval_stack = _stack_trees(list(eval_batches))
        self._grad = jax.value_and_grad(loss_fn, has_aux=True)
        # compiled programs are cached per (batch_fn, context mode): the
        # mode is a static part of the trace, and trainers create their
        # batch fns once, so each distinct data stream compiles once
        self._fit_programs: dict = {}
        self._steps_programs: dict = {}
        self._eval_programs: dict = {}

    # -- per-member building blocks (always traced under vmap) -----------
    # The contexts' shared mode is threaded through as a static closure
    # value, never rebuilt from engine state — a population of 'pallas'
    # contexts trains in pallas mode.

    @staticmethod
    def _ctx(ok, mode: str) -> FaultContext:
        return healthy() if ok is None else FaultContext(ok=ok, mode=mode)

    def _member_eval(self, params, ok, mode: str, eval_stack=None):
        ctx = self._ctx(ok, mode)
        stack = self._eval_stack if eval_stack is None else eval_stack

        def one(batch):
            v = self.loss_fn(params, batch, ctx)[1][self.metric]
            return v if self.higher_is_better else -v

        return jnp.mean(jax.vmap(one)(stack))

    def _member_update(self, params, opt, ok, batch, mode: str):
        (_, _m), g = self._grad(params, batch, self._ctx(ok, mode))
        params, opt, _ = adamw_update(g, opt, params, self.opt_cfg)
        return params, opt

    def _broadcast_members(self, params0, n: int):
        params_pop = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params0
        )
        opt_pop = jax.vmap(lambda p: adamw_init(p, self.opt_cfg))(params_pop)
        return params_pop, opt_pop

    # -- member-state layout hooks ---------------------------------------
    # The run bodies thread per-member (params, opt) through these at every
    # loop-carry boundary (stored layout) and before every update/eval
    # (compute layout). They are identity here — the fleet engine overrides
    # them to keep member state sharded over a 2-D mesh's "model" axis
    # between steps while gathering full-shape replicas for the math, so
    # per-member trajectories stay bit-identical to the single-device path.

    def _constrain_member_state(self, params_pop, opt_pop):
        """Persistent (loop-carry / program-output) layout of member state."""
        return params_pop, opt_pop

    def _gather_member_state(self, params_pop, opt_pop):
        """Layout member state for an update step (full-shape by default)."""
        return params_pop, opt_pop

    def _gather_member_params(self, params_pop):
        """Layout member params for evaluation (full-shape by default)."""
        return params_pop

    def _constrain_batch(self, tree):
        """Layout of non-member data entering the math (train/eval batches,
        stacked masks): identity here; the fleet engine pins these replicated
        along the model axis so compute stays at single-device shapes."""
        return tree

    def _eval_pop(self, params_pop, ok_pop, mode: str):
        params_pop = self._gather_member_params(params_pop)
        ok_pop = None if ok_pop is None else self._constrain_batch(ok_pop)
        stack = self._constrain_batch(self._eval_stack)
        ok_axis = None if ok_pop is None else 0
        return jax.vmap(
            lambda p, ok: self._member_eval(p, ok, mode, stack), in_axes=(0, ok_axis)
        )(params_pop, ok_pop)

    def _eval_run(self, mode: str):
        return lambda pp, ok: self._eval_pop(pp, ok, mode)

    def _make_eval(self, mode: str):
        return jax.jit(self._eval_run(mode))

    def _eval_program(self, mode: str):
        if mode not in self._eval_programs:
            self._eval_programs[mode] = self._make_eval(mode)
        return self._eval_programs[mode]

    # -- compiled programs ------------------------------------------------
    # Each program comes in two layers: ``_*_run`` builds the plain traced
    # function over a full population chunk, and ``_make_*`` wraps it for
    # execution (jit here; jit(shard_map(...)) in the fleet subclass, which
    # reuses the same run bodies so per-member math cannot diverge).

    def _fit_run(self, batch_fn: BatchFn, mode: str):
        """One fori_loop trains every member to its own step budget: updates
        are computed for the whole population and select-masked off once a
        member's budget is spent — identical trajectories to training each
        member alone for ``budgets[i]`` steps on the same batch schedule."""

        def run(params0, ok_pop, budgets):
            n = budgets.shape[0]
            ok_axis = None if ok_pop is None else 0
            if ok_pop is not None:
                ok_pop = self._constrain_batch(ok_pop)
            params_pop, opt_pop = self._broadcast_members(params0, n)
            params_pop, opt_pop = self._constrain_member_state(params_pop, opt_pop)
            update = jax.vmap(
                lambda p, o, ok, b: self._member_update(p, o, ok, b, mode),
                in_axes=(0, 0, ok_axis, None),
            )

            def body(i, state):
                params, opt = self._gather_member_state(*state)
                new_params, new_opt = update(
                    params, opt, ok_pop, self._constrain_batch(batch_fn(i))
                )
                active = i < budgets  # (n,)

                def sel(new, old):
                    a = active.reshape((n,) + (1,) * (new.ndim - 1))
                    return jnp.where(a, new, old)

                return self._constrain_member_state(
                    jax.tree_util.tree_map(sel, new_params, params),
                    jax.tree_util.tree_map(sel, new_opt, opt),
                )

            params_pop, _ = jax.lax.fori_loop(
                0, jnp.max(budgets), body, (params_pop, opt_pop)
            )
            return params_pop

        return run

    def _make_fit(self, batch_fn: BatchFn, mode: str):
        return jax.jit(self._fit_run(batch_fn, mode))

    def _steps_run(self, batch_fn: BatchFn, mode: str):
        """steps-to-constraint for the whole population as one while_loop of
        eval-period chunks. ``crossed[i]`` latches the first step at which
        member i's metric reached the constraint (sentinel max_steps+1 when
        never); the loop exits as soon as every member has crossed."""
        ee = self.eval_every

        def run(params0, ok_pop, constraint, max_steps):
            n = ok_pop.shape[0]
            max_steps = jnp.asarray(max_steps, jnp.int32)
            ok_pop = self._constrain_batch(ok_pop)
            params_pop, opt_pop = self._broadcast_members(params0, n)
            update = jax.vmap(
                lambda p, o, ok, b: self._member_update(p, o, ok, b, mode),
                in_axes=(0, 0, 0, None),
            )

            base = self._eval_pop(params_pop, ok_pop, mode)
            sentinel = max_steps + 1
            crossed = jnp.where(base >= constraint, jnp.int32(0), sentinel)
            params_pop, opt_pop = self._constrain_member_state(params_pop, opt_pop)

            def cond(carry):
                step, _params, _opt, cr = carry
                return (step < max_steps) & jnp.any(cr > max_steps)

            def body(carry):
                step, params, opt, cr = carry
                params, opt = self._gather_member_state(params, opt)

                def train_one(i, state):
                    p, o = state
                    return update(
                        p, o, ok_pop, self._constrain_batch(batch_fn(step + i + 1))
                    )

                params, opt = jax.lax.fori_loop(0, ee, train_one, (params, opt))
                step = step + ee
                metric = self._eval_pop(params, ok_pop, mode)
                # first crossing only; a chunk overshooting max_steps is a
                # step the serial reference never evaluated, so it can't hit
                hit = (metric >= constraint) & (cr > max_steps) & (step <= max_steps)
                cr = jnp.where(hit, step.astype(cr.dtype), cr)
                params, opt = self._constrain_member_state(params, opt)
                return step, params, opt, cr

            _, _, _, crossed = jax.lax.while_loop(
                cond, body, (jnp.int32(0), params_pop, opt_pop, crossed)
            )
            return crossed

        return run

    def _make_steps(self, batch_fn: BatchFn, mode: str):
        return jax.jit(self._steps_run(batch_fn, mode))

    # -- chunking ---------------------------------------------------------

    def _chunks(self, n: int):
        size = max(1, min(self.population_size, n))
        for lo in range(0, n, size):
            keep = min(size, n - lo)
            yield lo, keep, size

    # -- engine interface -------------------------------------------------

    def fit_batch(
        self,
        params0,
        contexts: Sequence[Optional[FaultContext]],
        budgets: Sequence[int],
        batch_fn: BatchFn,
    ) -> list:
        """Train one member per context from ``params0`` for its own budget
        of steps (batches ``batch_fn(0..budget-1)``); returns per-member
        params (NOT FAP-masked — shipping policy belongs to the trainer)."""
        if len(contexts) != len(budgets):
            raise ValueError("contexts and budgets must align")
        out: list = []
        for lo, keep, size in self._chunks(len(contexts)):
            chunk = list(contexts[lo : lo + keep])
            chunk_budgets = [int(b) for b in budgets[lo : lo + keep]]
            # pad with zero-budget copies: they ride along untouched
            chunk += [chunk[-1]] * (size - keep)
            chunk_budgets += [0] * (size - keep)
            stacked = stack_contexts([c or healthy() for c in chunk])
            key = (batch_fn, stacked.mode)
            if key not in self._fit_programs:
                self._fit_programs[key] = self._make_fit(batch_fn, stacked.mode)
            t0 = self.obs.now() if self.obs else 0.0
            trained = self._fit_programs[key](
                params0, stacked.ok, jnp.asarray(chunk_budgets, jnp.int32)
            )
            if self.obs:
                trained = jax.block_until_ready(trained)
                maxb = max(chunk_budgets) if chunk_budgets else 0
                lane_steps = size * maxb  # padding lanes occupy real width
                wasted = lane_steps - sum(chunk_budgets)
                self.obs.span(
                    "fit_chunk", proc="train", track="engine", t0=t0,
                    args=dict(members=keep, width=size, max_budget=maxb,
                              budget_steps=sum(chunk_budgets),
                              wasted_lane_steps=wasted),
                )
                self.obs.count("train.members_trained", keep)
                self.obs.count("train.lane_steps", lane_steps)
                self.obs.count("train.budget_steps", sum(chunk_budgets))
                self.obs.count("train.wasted_lane_steps", wasted)
            self._record_fit_output(trained, keep, size)
            out.extend(_member_slice(trained, i) for i in range(keep))
        return out

    def _record_fit_output(self, trained, keep: int, width: int) -> None:
        """Hook on each raw (still member-stacked) fit-program output before
        padding lanes are sliced off — the fleet engine records per-device
        resident-byte stats here; no-op otherwise."""

    def steps_to_constraint_batch(
        self,
        params0,
        contexts: Sequence[FaultContext],
        constraint: float,
        max_steps: int,
        batch_fn: BatchFn,
    ) -> list[Optional[int]]:
        """Per-member steps until metric >= constraint (eval every
        ``eval_every`` steps, batches ``batch_fn(1..max_steps)``), or None
        when not reached within ``max_steps`` — one compiled program per
        chunk instead of per-member Python loops."""
        out: list[Optional[int]] = []
        for lo, keep, size in self._chunks(len(contexts)):
            chunk = list(contexts[lo : lo + keep])
            chunk += [chunk[-1]] * (size - keep)
            stacked = stack_contexts(chunk)
            if stacked.ok is None:
                raise ValueError("steps_to_constraint needs fault contexts")
            key = (batch_fn, stacked.mode)
            if key not in self._steps_programs:
                self._steps_programs[key] = self._make_steps(batch_fn, stacked.mode)
            t0 = self.obs.now() if self.obs else 0.0
            crossed = np.asarray(
                self._steps_programs[key](params0, stacked.ok, constraint, max_steps)
            )
            if self.obs:
                # Every lane runs until the slowest member crosses (or
                # max_steps): realized lane-steps = width * max(realized).
                realized = [min(int(c), int(max_steps)) for c in crossed[:keep]]
                worst = max(realized) if realized else 0
                lane_steps = size * worst
                wasted = lane_steps - sum(realized)
                self.obs.span(
                    "probe_chunk", proc="train", track="engine", t0=t0,
                    args=dict(members=keep, width=size, max_steps=int(max_steps),
                              realized_steps=worst, wasted_lane_steps=wasted),
                )
                self.obs.count("train.probe_lane_steps", lane_steps)
                self.obs.count("train.probe_wasted_lane_steps", wasted)
                for i, c in enumerate(crossed[:keep]):
                    if int(c) > int(max_steps):
                        self.obs.count("train.members_never_crossed")
                    else:
                        self.obs.observe(
                            "train.steps_to_constraint", float(c),
                            buckets=STEPS_BUCKETS,
                        )
                        self.obs.instant(
                            "constraint_crossed", proc="train", track="engine",
                            args=dict(member=lo + i, steps=int(c)),
                        )
            out.extend(
                None if int(c) > int(max_steps) else int(c) for c in crossed[:keep]
            )
        return out

    def evaluate_batch(
        self, params_list: Sequence[Any], contexts: Sequence[Optional[FaultContext]]
    ) -> list[float]:
        """Signed constraint metric of params_list[i] under contexts[i],
        vmapped across the population (chunked like training)."""
        if len(params_list) != len(contexts):
            raise ValueError("params and contexts must align")
        out: list[float] = []
        for lo, keep, size in self._chunks(len(contexts)):
            chunk_params = list(params_list[lo : lo + keep])
            chunk_ctx = list(contexts[lo : lo + keep])
            chunk_params += [chunk_params[-1]] * (size - keep)
            chunk_ctx += [chunk_ctx[-1]] * (size - keep)
            stacked = stack_contexts([c or healthy() for c in chunk_ctx])
            vals = np.asarray(
                self._eval_program(stacked.mode)(_stack_trees(chunk_params), stacked.ok)
            )
            out.extend(float(v) for v in vals[:keep])
        return out

    def evaluate_one(self, params, ctx: Optional[FaultContext]) -> float:
        return self.evaluate_batch([params], [ctx])[0]


class SerialFATEngine:
    """Reference serial implementation of the engine interface — the exact
    one-map-at-a-time loops the trainers ran before the population refactor
    (jitted grad, eager optimizer, host-side periodic eval). Kept behind
    ``engine="serial"`` for equivalence tests and benchmarking."""

    kind = "serial"

    def __init__(
        self,
        *,
        loss_fn,
        opt_cfg: AdamWConfig,
        eval_batches: Sequence[dict],
        metric: str = "accuracy",
        higher_is_better: bool = True,
        eval_every: int = 5,
        population_size: int = 16,  # interface parity; serial chunks are 1-wide
        param_axes: Optional[Any] = None,  # interface parity; serial never shards
        recorder: Optional[Recorder] = None,  # interface parity with population
    ):
        self.population_size = 1  # one member at a time — schedulers see no packing
        self.param_axes = param_axes
        self.obs = recorder if recorder is not None else NULL_RECORDER
        self.loss_fn = loss_fn
        self.opt_cfg = opt_cfg
        self.metric = metric
        self.higher_is_better = higher_is_better
        self.eval_every = int(eval_every)
        self.eval_batches = list(eval_batches)
        self._grad = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
        self._eval = jax.jit(lambda p, b, ctx: loss_fn(p, b, ctx)[1])

    def evaluate_one(self, params, ctx: Optional[FaultContext]) -> float:
        ctx = ctx or healthy()
        vals = [float(self._eval(params, b, ctx)[self.metric]) for b in self.eval_batches]
        v = float(np.mean(vals))
        return v if self.higher_is_better else -v

    def _fit_one(self, params0, ctx: FaultContext, steps: int, batch_fn: BatchFn):
        params, opt = params0, adamw_init(params0, self.opt_cfg)
        for s in range(int(steps)):
            (_, _m), g = self._grad(params, batch_fn(s), ctx)
            params, opt, _ = adamw_update(g, opt, params, self.opt_cfg)
        return params

    def fit_batch(self, params0, contexts, budgets, batch_fn: BatchFn) -> list:
        return [
            self._fit_one(params0, ctx or healthy(), steps, batch_fn)
            for ctx, steps in zip(contexts, budgets)
        ]

    def steps_to_constraint_batch(
        self, params0, contexts, constraint, max_steps, batch_fn: BatchFn
    ) -> list[Optional[int]]:
        out: list[Optional[int]] = []
        for ctx in contexts:
            if self.evaluate_one(params0, ctx) >= constraint:
                out.append(0)  # paper Fig. 3: relaxed constraints may need no retraining
                continue
            params, opt = params0, adamw_init(params0, self.opt_cfg)
            found: Optional[int] = None
            for s in range(1, int(max_steps) + 1):
                (_, _m), g = self._grad(params, batch_fn(s), ctx)
                params, opt, _ = adamw_update(g, opt, params, self.opt_cfg)
                if s % self.eval_every == 0 and self.evaluate_one(params, ctx) >= constraint:
                    found = s
                    break
            out.append(found)
        return out

    def evaluate_batch(self, params_list, contexts) -> list[float]:
        return [self.evaluate_one(p, c) for p, c in zip(params_list, contexts)]


def make_fat_engine(kind: str, **kwargs):
    if kind == "population":
        return PopulationFATEngine(**kwargs)
    if kind == "serial":
        return SerialFATEngine(**kwargs)
    if kind == "sharded":
        # lazy: repro.fleet.sharding imports this module
        from repro.fleet.sharding import ShardedPopulationEngine

        return ShardedPopulationEngine(**kwargs)
    raise ValueError(
        f"unknown FAT engine {kind!r} (use 'population', 'serial', or 'sharded')"
    )
