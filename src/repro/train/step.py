"""train_step / eval_step builders (pure functions, pjit-ready).

Microbatch gradient accumulation runs as a lax.scan over microbatches with
a configurable accumulator dtype — ``bfloat16`` accumulation is the
gradient-compression knob (halves accumulator memory and the bytes moved
by the cross-replica reduction)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.masking import FaultContext
from repro.models import model as M
from repro.train.optimizer import AdamWConfig, adamw_update


def make_loss_fn(
    cfg, *, attn_impl="auto", moe_impl="einsum", moe_cf=1.25, remat="dots",
    fault_apply="per_use",
):
    def loss(params, batch, ctx):
        return M.loss_fn(
            params, batch, cfg, ctx,
            attn_impl=attn_impl, moe_impl=moe_impl, moe_cf=moe_cf, remat=remat,
            fault_apply=fault_apply,
        )

    return loss


def make_train_step(
    cfg,
    opt_cfg: AdamWConfig,
    *,
    attn_impl: str = "auto",
    moe_impl: str = "einsum",
    moe_cf: float = 1.25,
    remat: str = "dots",
    microbatches: int = 1,
    accum_dtype: str = "float32",
    fault_apply: str = "per_use",
) -> Callable:
    """Returns train_step(params, opt_state, batch, ctx) -> (params', opt', metrics)."""
    loss_fn = make_loss_fn(
        cfg, attn_impl=attn_impl, moe_impl=moe_impl, moe_cf=moe_cf, remat=remat,
        fault_apply=fault_apply,
    )
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, ctx: FaultContext):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch, ctx)
        else:
            adt = jnp.dtype(accum_dtype)

            def mb(i, batch=batch):
                return jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // microbatches), x.shape[0] // microbatches, 0
                    ),
                    batch,
                )

            def body(carry, i):
                acc, met_acc = carry
                (l, met), g = grad_fn(params, mb(i), ctx)
                acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(adt), acc, g
                )
                met_acc = jax.tree_util.tree_map(lambda a, x: a + x, met_acc, met)
                return (acc, met_acc), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.dtype(accum_dtype)), params
            )
            zero_m = dict(
                loss=jnp.zeros((), jnp.float32), ce=jnp.zeros((), jnp.float32),
                aux=jnp.zeros((), jnp.float32), accuracy=jnp.zeros((), jnp.float32),
            )
            (grads, msum), _ = jax.lax.scan(
                body, (zero_g, zero_m), jnp.arange(microbatches)
            )
            grads = jax.tree_util.tree_map(
                lambda g: (g / microbatches).astype(jnp.float32), grads
            )
            metrics = jax.tree_util.tree_map(lambda x: x / microbatches, msum)

        params, opt_state, info = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics)
        metrics.update(info)
        return params, opt_state, metrics

    return train_step


def make_jit_train_step(cfg, opt_cfg: AdamWConfig, **kw) -> Callable:
    """The canonical jitted train step: ``make_train_step`` under ``jax.jit``
    with the loop-carried ``(params, opt_state)`` operands donated, so the
    training loop's master weights and optimizer moments alias in place
    instead of round-tripping through a copy every step. This is the form
    the launcher runs and ``repro.analysis`` lints (DON001); callers that
    re-use a params buffer across calls (e.g. population sweeps fanning out
    from one ``params0``) must jit ``make_train_step`` themselves without
    donation."""
    return jax.jit(make_train_step(cfg, opt_cfg, **kw), donate_argnums=(0, 1))


def make_eval_step(cfg, **kw) -> Callable:
    loss_fn = make_loss_fn(cfg, **kw)

    def eval_step(params, batch, ctx: FaultContext):
        _, metrics = loss_fn(params, batch, ctx)
        return metrics

    return eval_step
