"""Fault-tolerant training loop.

Responsibilities: deterministic resume (checkpoint step -> data seek),
periodic async checkpointing, periodic eval, straggler detection (per-step
wall-clock watchdog -> logged + surfaced), and crash recovery (any
exception triggers restore-from-latest and continue, up to a retry budget —
the same path a preempted/failed node takes at cluster scale).
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt_lib

log = logging.getLogger("repro.train")


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    eval_every: int = 100
    log_every: int = 50
    keep_checkpoints: int = 3
    straggler_factor: float = 3.0  # step slower than factor x median => straggler
    max_restarts: int = 2


@dataclass
class LoopState:
    step: int = 0
    metrics_history: list = field(default_factory=list)
    straggler_events: list = field(default_factory=list)
    restarts: int = 0


def run_training(
    cfg: LoopConfig,
    *,
    train_step: Callable,  # (params, opt, batch, ctx) -> (params, opt, metrics)
    batch_at: Callable[[int], Any],
    params: Any,
    opt_state: Any,
    ctx: Any,
    eval_fn: Optional[Callable[[Any], dict]] = None,  # params -> metrics
    on_metrics: Optional[Callable[[int, dict], None]] = None,
) -> tuple[Any, Any, LoopState]:
    """Run (or resume) training to cfg.total_steps. Returns final
    (params, opt_state, loop_state)."""
    state = LoopState()
    saver = (
        ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep_checkpoints)
        if cfg.ckpt_dir
        else None
    )

    # ---- resume ---------------------------------------------------------
    if cfg.ckpt_dir and ckpt_lib.latest_step(cfg.ckpt_dir) is not None:
        step0, flat, meta = ckpt_lib.load_checkpoint(cfg.ckpt_dir)
        tree = ckpt_lib.restore_sharded({"params": params, "opt": opt_state}, flat)
        params, opt_state = tree["params"], tree["opt"]
        state.step = step0
        log.info("resumed from step %d", step0)

    step_times: list[float] = []

    while state.step < cfg.total_steps:
        try:
            batch = batch_at(state.step)  # deterministic seek: no data loss
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state, batch, ctx)
            jax.tree_util.tree_map(
                lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
                metrics,
            )
            dt = time.time() - t0
            state.step += 1

            # ---- straggler watchdog --------------------------------------
            if len(step_times) >= 8:
                med = float(np.median(step_times[-64:]))
                if dt > cfg.straggler_factor * med:
                    state.straggler_events.append((state.step, dt, med))
                    log.warning(
                        "straggler step %d: %.3fs vs median %.3fs", state.step, dt, med
                    )
            step_times.append(dt)

            if state.step % cfg.log_every == 0 or state.step == 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step_time_s"] = dt
                state.metrics_history.append((state.step, m))
                if on_metrics:
                    on_metrics(state.step, m)

            if eval_fn and state.step % cfg.eval_every == 0:
                em = eval_fn(params)
                state.metrics_history.append((state.step, {"eval_" + k: float(v) for k, v in em.items()}))
                if on_metrics:
                    on_metrics(state.step, {"eval_" + k: float(v) for k, v in em.items()})

            if saver and state.step % cfg.ckpt_every == 0:
                saver.save(state.step, {"params": params, "opt": opt_state})

        except (KeyboardInterrupt,):
            raise
        except Exception as e:  # crash -> restore-from-checkpoint path
            state.restarts += 1
            log.exception("step %d failed (%s); restart %d", state.step, e, state.restarts)
            if state.restarts > cfg.max_restarts or not cfg.ckpt_dir:
                raise
            if saver:
                saver.wait()
            last = ckpt_lib.latest_step(cfg.ckpt_dir)
            if last is None:
                raise
            _, flat, _ = ckpt_lib.load_checkpoint(cfg.ckpt_dir)
            tree = ckpt_lib.restore_sharded({"params": params, "opt": opt_state}, flat)
            params, opt_state = tree["params"], tree["opt"]
            state.step = last

    if saver:
        saver.save(state.step, {"params": params, "opt": opt_state})
        saver.wait()
    return params, opt_state, state
