"""Checkpointing: atomic, async, elastic.

* atomic   — write to ``<dir>/tmp.<step>`` then rename to ``step_<n>``.
* async    — a background thread serializes a host copy; the train loop
             never blocks on disk.
* elastic  — checkpoints store plain host numpy arrays keyed by pytree
             path; ``load_checkpoint`` + ``restore_sharded`` re-device-puts
             onto ANY mesh/sharding, so a job restarted with a different
             device count (node failure, elastic rescale) resumes cleanly.

A real multi-host deployment writes per-host shard files; this single-
process implementation writes the global view (the restore path is the
same either way).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "latest_step",
    "restore_sharded",
    "AsyncCheckpointer",
]

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "fiub?":  # ml_dtypes (bf16/fp8): npz would
            arr = arr.astype(np.float32)  # store them as void; upcast
        flat[key] = arr
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3, extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = dict(step=step, time=time.time(), keys=sorted(flat), extra=extra or {})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


def _all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _all_steps(ckpt_dir)
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: Optional[int] = None) -> tuple[int, dict[str, np.ndarray], dict]:
    """Returns (step, flat {path: np.ndarray}, meta)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    meta = json.load(open(os.path.join(d, "meta.json")))
    return step, flat, meta


def restore_sharded(template: Any, flat: dict[str, np.ndarray], shardings: Optional[Any] = None) -> Any:
    """Rebuild ``template``-structured tree from flat arrays; device_put with
    per-leaf shardings when given (elastic re-shard onto a new mesh)."""
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        key = _SEP.join(_key_str(k) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if arr.dtype.kind == "V" and hasattr(leaf, "dtype"):
            # legacy checkpoint: void-stored ml_dtype — reinterpret bits
            arr = arr.view(leaf.dtype)
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(paths[1], leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    return tree


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writer (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self.ckpt_dir, step, host_tree, keep=self.keep, extra=extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
