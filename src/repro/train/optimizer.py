"""AdamW on raw pytrees (no external deps), with the distributed-memory
knobs that matter at pod scale:

  * ``moment_dtype`` — bf16 first/second moments halve optimizer HBM (the
    difference between fitting and not fitting llama3-405b on 256 chips;
    see EXPERIMENTS.md SDry-run).
  * master params stay fp32; the forward casts to the compute dtype.
  * optimizer state inherits the params' logical sharding (ZeRO-style when
    the rules shard 'embed' over data).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: Union[float, Schedule] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"  # 'bfloat16' halves optimizer memory


def adamw_init(params, cfg: AdamWConfig) -> dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=mdt)
    return dict(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, state: dict, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, info dict)."""
    count = state["count"] + 1
    lr = cfg.learning_rate(count) if callable(cfg.learning_rate) else cfg.learning_rate
    gnorm = _global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = dict(m=new_m, v=new_v, count=count)
    return new_params, new_state, dict(grad_norm=gnorm, lr=jnp.asarray(lr))


def opt_state_specs(param_specs) -> dict:
    """Optimizer state inherits each param's logical axes (ZeRO sharding)."""
    return dict(m=param_specs, v=param_specs, count=())


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, peak * cos)

    return fn


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.full((), lr, jnp.float32)
