"""FATTrainer implementations — the bridge between the eFAT orchestrator
(repro.core.efat) and the training substrates.

``ClassifierFATTrainer`` — the paper-faithful CPU-scale trainer: a
pre-trained MLP on the Gaussian-cluster task; steps-to-constraint at a
given fault rate is measurable in seconds, so the full Step-1 resilience
sweep (rates x repeats) runs in minutes like the paper's CIFAR runs.

``LMFATTrainer`` — the same protocol over a (reduced) LM arch with the
TokenStream data pipeline; used by the examples and integration tests to
show FAT on the assigned transformer families.

Both trainers delegate every training loop to a FAT *engine*
(repro.train.population): ``engine="population"`` (default) trains a whole
batch of fault maps as one vmap+scan program; ``engine="serial"`` is the
one-map-at-a-time reference the population path is proven equivalent to.
On top of the single-map ``FATTrainerFull`` protocol they expose the batch
protocol (``steps_to_constraint_batch`` / ``train_batch`` /
``evaluate_batch``) that the Step-1 sweep and Step-4 plan execution use to
submit entire populations.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax

from repro.core.faults import FaultMap
from repro.core.masking import from_fault_map, healthy, mask_params
from repro.data.synthetic import TokenStream, make_classification_task
from repro.fleet.scheduler import FleetScheduler
from repro.models import model as M
from repro.models.classifier import classifier_loss, classifier_param_axes, init_classifier
from repro.train.optimizer import AdamWConfig
from repro.train.population import make_fat_engine


class _EngineBackedTrainer:
    """Shared protocol plumbing: single-map methods are the batch methods
    with a population of one; the engine decides how batches execute.

    Every batch submission routes through one :class:`FleetScheduler`
    (repro.fleet): jobs are packed into population chunks by cost — the
    prescribed step budget for ``train_batch`` (Step 4), the fault rate as
    cost proxy for ``steps_to_constraint_batch`` (Step 1) — then results are
    mapped back to caller order. Per-member results are chunk-invariant, so
    scheduling changes only wall-clock/wasted lanes, never the math."""

    # subclasses set: engine (FAT engine), scheduler, base_params, and the
    # batch fns
    #   _probe_batch_fn  — steps_to_constraint stream (batch_fn(1..max))
    #   _train_batch_fn  — consolidated-FAT stream (batch_fn(0..steps-1))

    def _make_scheduler(self, policy: str) -> FleetScheduler:
        # sharded engine chunks tile its pop-axis extent; waste accounting
        # must count the same padding lanes the compiled chunk actually runs
        return FleetScheduler.for_engine(self.engine, policy=policy)

    @staticmethod
    def _engine_kwargs(engine: str, cfg, param_axes, engine_kwargs: Optional[dict]) -> dict:
        """Thread the arch + param layout into the engine: every engine
        takes ``param_axes`` (vmap/serial ignore it); the sharded engine
        also needs ``cfg`` to build tensor-parallel rules for 2-D meshes."""
        kw = dict(engine_kwargs or {})
        kw.setdefault("param_axes", param_axes)
        if engine == "sharded":
            kw.setdefault("cfg", cfg)
        return kw

    def evaluate_params(self, params, ctx) -> float:
        return self.engine.evaluate_one(params, ctx)

    @property
    def grad_fn(self):
        """Jitted ``(params, batch, ctx) -> ((loss, metrics), grads)`` over
        this trainer's objective — for custom loops (e.g. the dual-fault
        projected-FAT sweep) that step outside the engine."""
        fn = getattr(self, "_grad_fn_cache", None)
        if fn is None:
            fn = jax.jit(jax.value_and_grad(self.engine.loss_fn, has_aux=True))
            self._grad_fn_cache = fn
        return fn

    def _obs_schedule(self, what: str, sched) -> None:
        """Scheduling decisions are host-side and cheap — surface each one
        as an instant on the engine's recorder (no-op when obs is off)."""
        rec = getattr(self.engine, "obs", None)
        if rec:
            rec.instant(
                "schedule", proc="train", track="scheduler",
                args=dict(what=what, policy=sched.policy, jobs=len(sched.order),
                          chunks=len(sched.chunks),
                          wasted_steps=sched.wasted_steps,
                          span_steps=sched.span_steps),
            )

    # ---- FATTrainerFull protocol (single map + batched) -----------------
    def steps_to_constraint(
        self, fault_map: FaultMap, constraint: float, max_steps: int
    ) -> Optional[int]:
        return self.steps_to_constraint_batch([fault_map], constraint, max_steps)[0]

    def steps_to_constraint_batch(
        self, fault_maps: Sequence[FaultMap], constraint: float, max_steps: int
    ) -> list[Optional[int]]:
        ctxs = [from_fault_map(fm) for fm in fault_maps]
        # required steps are what we're measuring — pack by fault rate, the
        # best prior (chunks run until their slowest member crosses)
        sched = self.scheduler.schedule([fm.fault_rate for fm in fault_maps])
        self._obs_schedule("probe", sched)
        out = self.engine.steps_to_constraint_batch(
            self.base_params, sched.permute(ctxs), constraint, max_steps,
            self._probe_batch_fn,
        )
        return sched.unpermute(out)

    def train(self, fault_map: FaultMap, steps: int):
        return self.train_batch([fault_map], [steps])[0]

    def train_batch(self, fault_maps: Sequence[FaultMap], steps: Sequence[int]) -> list:
        ctxs = [from_fault_map(fm) for fm in fault_maps]
        budgets = [int(s) for s in steps]
        sched = self.scheduler.schedule(budgets)
        self._obs_schedule("train", sched)
        trained = self.engine.fit_batch(
            self.base_params, sched.permute(ctxs), sched.permute(budgets),
            self._train_batch_fn,
        )
        trained = sched.unpermute(trained)
        # ship FAP'd weights: weights on faulty PEs are zero in the artifact
        return [mask_params(p, ctx) for p, ctx in zip(trained, ctxs)]

    def evaluate(self, params, fault_map: FaultMap) -> float:
        return self.evaluate_batch([params], [fault_map])[0]

    def evaluate_batch(
        self, params_list: Sequence[Any], fault_maps: Sequence[FaultMap]
    ) -> list[float]:
        ctxs = [from_fault_map(fm) for fm in fault_maps]
        return self.engine.evaluate_batch(list(params_list), ctxs)


class ClassifierFATTrainer(_EngineBackedTrainer):
    """Paper SIV setup: pre-trained classifier + FAT per fault map."""

    def __init__(
        self,
        cfg,
        *,
        seed: int = 0,
        batch_size: int = 256,
        lr: float = 3e-3,
        pretrain_steps: int = 400,
        eval_every: int = 5,
        eval_batches: int = 2,
        engine: str = "population",
        population_size: int = 16,
        schedule: str = "lpt",
        engine_kwargs: Optional[dict] = None,
    ):
        self.cfg = cfg
        self.data = make_classification_task(cfg, seed=seed)
        self.batch_size = batch_size
        self.eval_every = eval_every
        self.opt_cfg = AdamWConfig(learning_rate=lr, weight_decay=0.0, grad_clip_norm=1.0)
        self._evals = self.data.eval_batches(n=eval_batches)

        # stable batch fns (one compiled program per stream); salts match
        # the historical serial trainer so trajectories are reproducible
        def probe_batch(s):
            return self.data.batch_at(s, batch_size)

        def fat_batch(s):
            return self.data.batch_at(s + 1_000_003, batch_size)

        self._probe_batch_fn = probe_batch
        self._pretrain_batch_fn = probe_batch  # pretrain salt is 0
        self._train_batch_fn = fat_batch

        self.engine = make_fat_engine(
            engine,
            loss_fn=lambda p, b, ctx: classifier_loss(p, b, cfg, ctx),
            opt_cfg=self.opt_cfg,
            eval_batches=self._evals,
            metric="accuracy",
            higher_is_better=True,
            eval_every=eval_every,
            population_size=population_size,
            **self._engine_kwargs(engine, cfg, classifier_param_axes(cfg), engine_kwargs),
        )
        self.scheduler = self._make_scheduler(schedule)
        key = jax.random.PRNGKey(seed)
        self.base_params = init_classifier(cfg, key, in_dim=self.data.dim)
        # pre-train the healthy model (the user-provided pre-trained DNN)
        self.base_params = self.engine.fit_batch(
            self.base_params, [healthy()], [pretrain_steps], self._pretrain_batch_fn
        )[0]
        self.baseline_accuracy = self.evaluate_params(self.base_params, healthy())


class LMFATTrainer(_EngineBackedTrainer):
    """Same protocol over a language model (reduced arch for CPU tests)."""

    def __init__(
        self,
        cfg,
        *,
        seed: int = 0,
        batch_size: int = 8,
        seq_len: int = 64,
        lr: float = 1e-3,
        pretrain_steps: int = 150,
        eval_every: int = 10,
        eval_batches: int = 2,
        metric: str = "accuracy",
        engine: str = "population",
        population_size: int = 4,
        schedule: str = "lpt",
        engine_kwargs: Optional[dict] = None,
    ):
        self.cfg = cfg
        self.metric = metric
        self.stream = TokenStream(cfg.vocab_size, seq_len, batch_size, seed=seed)
        self.eval_every = eval_every
        self.opt_cfg = AdamWConfig(learning_rate=lr, weight_decay=0.0)

        def probe_batch(s):
            return self.stream.batch_at(s)

        def fat_batch(s):
            return self.stream.batch_at(s + 999_983)

        def pretrain_batch(s):
            return self.stream.batch_at(s + 999_983 * 7)

        self._probe_batch_fn = probe_batch
        self._train_batch_fn = fat_batch
        self._pretrain_batch_fn = pretrain_batch

        key = jax.random.PRNGKey(seed)
        self.base_params, self.specs = M.init_params(cfg, key)
        self._evals = [self.stream.batch_at(10_000_000 + i) for i in range(eval_batches)]
        self.engine = make_fat_engine(
            engine,
            loss_fn=lambda p, b, ctx: M.loss_fn(p, b, cfg, ctx, remat="none"),
            opt_cfg=self.opt_cfg,
            eval_batches=self._evals,
            metric=metric,
            higher_is_better=metric != "loss",  # higher-is-better protocol
            eval_every=eval_every,
            population_size=population_size,
            **self._engine_kwargs(engine, cfg, self.specs, engine_kwargs),
        )
        self.scheduler = self._make_scheduler(schedule)
        self.base_params = self.engine.fit_batch(
            self.base_params, [healthy()], [pretrain_steps], self._pretrain_batch_fn
        )[0]
        self.baseline_metric = self.evaluate_params(self.base_params, healthy())
