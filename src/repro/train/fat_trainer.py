"""FATTrainer implementations — the bridge between the eFAT orchestrator
(repro.core.efat) and the training substrates.

``ClassifierFATTrainer`` — the paper-faithful CPU-scale trainer: a
pre-trained MLP on the Gaussian-cluster task; steps-to-constraint at a
given fault rate is measurable in seconds, so the full Step-1 resilience
sweep (rates x repeats) runs in minutes like the paper's CIFAR runs.

``LMFATTrainer`` — the same protocol over a (reduced) LM arch with the
TokenStream data pipeline; used by the examples and integration tests to
show FAT on the assigned transformer families.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import FaultMap
from repro.core.masking import from_fault_map, healthy, mask_params
from repro.data.synthetic import ClusterData, TokenStream, make_classification_task
from repro.models import model as M
from repro.models.classifier import classifier_forward, classifier_loss, init_classifier
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


class ClassifierFATTrainer:
    """Paper SIV setup: pre-trained classifier + FAT per fault map."""

    def __init__(
        self,
        cfg,
        *,
        seed: int = 0,
        batch_size: int = 256,
        lr: float = 3e-3,
        pretrain_steps: int = 400,
        eval_every: int = 5,
        eval_batches: int = 2,
    ):
        self.cfg = cfg
        self.data = make_classification_task(cfg, seed=seed)
        self.batch_size = batch_size
        self.eval_every = eval_every
        self.opt_cfg = AdamWConfig(learning_rate=lr, weight_decay=0.0, grad_clip_norm=1.0)
        self._evals = self.data.eval_batches(n=eval_batches)
        key = jax.random.PRNGKey(seed)
        self.base_params = init_classifier(cfg, key, in_dim=self.data.dim)
        self._grad = jax.jit(jax.value_and_grad(
            lambda p, b, ctx: classifier_loss(p, b, cfg, ctx), has_aux=True
        ))
        self._eval = jax.jit(lambda p, b, ctx: classifier_loss(p, b, cfg, ctx)[1])
        # pre-train the healthy model (the user-provided pre-trained DNN)
        self.base_params = self._fit(self.base_params, healthy(), pretrain_steps, data_salt=0)
        self.baseline_accuracy = self.evaluate_params(self.base_params, healthy())

    # ------------------------------------------------------------------
    def _fit(self, params, ctx, steps: int, data_salt: int = 1):
        opt = adamw_init(params, self.opt_cfg)
        for s in range(steps):
            batch = self.data.batch_at(s + 1_000_003 * data_salt, self.batch_size)
            (_, _m), g = self._grad(params, batch, ctx)
            params, opt, _ = adamw_update(g, opt, params, self.opt_cfg)
        return params

    def evaluate_params(self, params, ctx) -> float:
        accs = [float(self._eval(params, b, ctx)["accuracy"]) for b in self._evals]
        return float(np.mean(accs))

    # ---- FATTrainerFull protocol ---------------------------------------
    def steps_to_constraint(self, fault_map: FaultMap, constraint: float, max_steps: int) -> Optional[int]:
        ctx = from_fault_map(fault_map)
        if self.evaluate_params(self.base_params, ctx) >= constraint:
            return 0  # paper Fig. 3: relaxed constraints may need no retraining
        params = self.base_params
        opt = adamw_init(params, self.opt_cfg)
        for s in range(1, max_steps + 1):
            batch = self.data.batch_at(s, self.batch_size)
            (_, _m), g = self._grad(params, batch, ctx)
            params, opt, _ = adamw_update(g, opt, params, self.opt_cfg)
            if s % self.eval_every == 0 and self.evaluate_params(params, ctx) >= constraint:
                return s
        return None

    def train(self, fault_map: FaultMap, steps: int):
        ctx = from_fault_map(fault_map)
        params = self._fit(self.base_params, ctx, steps)
        # ship FAP'd weights: weights on faulty PEs are zero in the artifact
        return mask_params(params, ctx)

    def evaluate(self, params, fault_map: FaultMap) -> float:
        return self.evaluate_params(params, from_fault_map(fault_map))


class LMFATTrainer:
    """Same protocol over a language model (reduced arch for CPU tests)."""

    def __init__(
        self,
        cfg,
        *,
        seed: int = 0,
        batch_size: int = 8,
        seq_len: int = 64,
        lr: float = 1e-3,
        pretrain_steps: int = 150,
        eval_every: int = 10,
        eval_batches: int = 2,
        metric: str = "accuracy",
    ):
        self.cfg = cfg
        self.metric = metric
        self.stream = TokenStream(cfg.vocab_size, seq_len, batch_size, seed=seed)
        self.eval_every = eval_every
        self.opt_cfg = AdamWConfig(learning_rate=lr, weight_decay=0.0)
        key = jax.random.PRNGKey(seed)
        self.base_params, self.specs = M.init_params(cfg, key)
        self._evals = [self.stream.batch_at(10_000_000 + i) for i in range(eval_batches)]
        self._grad = jax.jit(jax.value_and_grad(
            lambda p, b, ctx: M.loss_fn(p, b, cfg, ctx, remat="none"), has_aux=True
        ))
        self._eval = jax.jit(lambda p, b, ctx: M.loss_fn(p, b, cfg, ctx, remat="none")[1])
        self.base_params = self._fit(self.base_params, healthy(), pretrain_steps, salt=7)
        self.baseline_metric = self.evaluate_params(self.base_params, healthy())

    def _fit(self, params, ctx, steps: int, salt: int = 1):
        opt = adamw_init(params, self.opt_cfg)
        for s in range(steps):
            batch = self.stream.batch_at(s + 999_983 * salt)
            (_, _m), g = self._grad(params, batch, ctx)
            params, opt, _ = adamw_update(g, opt, params, self.opt_cfg)
        return params

    def evaluate_params(self, params, ctx) -> float:
        vals = [float(self._eval(params, b, ctx)[self.metric]) for b in self._evals]
        v = float(np.mean(vals))
        return v if self.metric != "loss" else -v  # higher-is-better protocol

    def steps_to_constraint(self, fault_map, constraint, max_steps) -> Optional[int]:
        ctx = from_fault_map(fault_map)
        if self.evaluate_params(self.base_params, ctx) >= constraint:
            return 0
        params = self.base_params
        opt = adamw_init(params, self.opt_cfg)
        for s in range(1, max_steps + 1):
            (_, _m), g = self._grad(params, self.stream.batch_at(s), ctx)
            params, opt, _ = adamw_update(g, opt, params, self.opt_cfg)
            if s % self.eval_every == 0 and self.evaluate_params(params, ctx) >= constraint:
                return s
        return None

    def train(self, fault_map, steps: int):
        ctx = from_fault_map(fault_map)
        params = self._fit(self.base_params, ctx, steps)
        return mask_params(params, ctx)

    def evaluate(self, params, fault_map) -> float:
        return self.evaluate_params(params, from_fault_map(fault_map))
