"""CPU-scale MLP classifier — the paper-faithful experiment substrate
(stands in for VGG11/ResNet18/MobileNetV2; every hidden matmul runs through
the systolic fault mapping exactly like the LM archs)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.masking import FaultContext, fault_linear, healthy


def init_classifier(cfg, key, in_dim: int):
    ks = jax.random.split(key, cfg.num_layers + 1)
    dims = [in_dim] + [cfg.d_ff] * (cfg.num_layers - 1) + [cfg.vocab_size]
    params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = jax.random.normal(ks[i], (a, b)) * (1.0 / math.sqrt(a))
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def classifier_param_axes(cfg) -> dict:
    """Logical-axes tree mirroring ``init_classifier``'s structure (see
    ``repro.launch.sharding``): each weight's output dim carries the
    shardable name ('mlp' / 'vocab' on the logits layer), the contraction
    dim stays replicated — the layout the fleet engine's 2-D meshes resolve
    per pop slice."""
    n = cfg.num_layers
    axes: dict = {}
    for i in range(n):
        out_ax = "vocab" if i == n - 1 else "mlp"
        axes[f"w{i}"] = ("embed", out_ax)
        axes[f"b{i}"] = (out_ax,)
    return axes


def classifier_forward(params, x, cfg, ctx: FaultContext | None = None):
    ctx = ctx or healthy()
    n = cfg.num_layers
    for i in range(n):
        x = fault_linear(x, params[f"w{i}"], ctx) + params[f"b{i}"]
        if i < n - 1:
            x = jax.nn.gelu(x)
    return x


def classifier_loss(params, batch, cfg, ctx=None):
    logits = classifier_forward(params, batch["x"], cfg, ctx).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, dict(loss=loss, accuracy=acc)
