"""Model assembly: init, forward (train/prefill), decode, loss.

One generic scan-over-layers transformer covering all assigned families:
dense / moe / ssm (mamba) / hybrid (parallel attn+ssm) / vlm / audio.
Per-layer params are stacked on a leading 'layers' dim and consumed by
``jax.lax.scan`` (compact HLO — one lowered block regardless of depth) with
a configurable remat policy. Every parameterized GEMM goes through
``fault_linear`` so the chip's FaultContext masks exactly the weights the
systolic mapping places on faulty PEs.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masking import FaultContext, fault_linear, healthy, mask_selected_params
from repro.launch.sharding import shard_activation
from repro.models.layers import (
    KVCache,
    PagedKVView,
    apply_norm,
    attention_block,
    mlp_block,
    rms_norm,
)
from repro.models.moe import moe_block
from repro.models.ssm import SSMCache, ssm_block

Array = jax.Array

AUDIO_FRAME_DIM = 512  # stub conv-frontend output width (wav2vec2-style)
VISION_PATCH_DIM = 1024  # stub InternViT patch-embedding width


# ---------------------------------------------------------------------------
# Initialization (+ logical-axis specs)
# ---------------------------------------------------------------------------


def _dense(key, shape, in_axis=-2, dtype=jnp.float32):
    fan_in = shape[in_axis]
    return jax.random.normal(key, shape, dtype) * (1.0 / math.sqrt(fan_in))


def _attn_specs(cfg):
    s = dict(
        wq=("embed", "qkv"),  # flattened heads*head_dim (unit = head_dim)
        wk=("embed", "kv"),
        wv=("embed", "kv"),
        wo=("qkv", "embed"),
    )
    if cfg.qk_norm:
        s["q_norm"] = (None,)
        s["k_norm"] = (None,)
    return s


def _init_attn(cfg, key):
    hq, hkv, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    p = dict(
        wq=_dense(ks[0], (d, hq * hd)),
        wk=_dense(ks[1], (d, hkv * hd)),
        wv=_dense(ks[2], (d, hkv * hd)),
        wo=_dense(ks[3], (hq * hd, d)),
    )
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p, _attn_specs(cfg)


def _mlp_specs(cfg):
    if cfg.activation == "swiglu":
        return dict(wg=("embed", "mlp"), wu=("embed", "mlp"), wd=("mlp", "embed"))
    return dict(wi=("embed", "mlp"), wd=("mlp", "embed"))


def _init_mlp(cfg, key):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        p = dict(wg=_dense(ks[0], (d, f)), wu=_dense(ks[1], (d, f)), wd=_dense(ks[2], (f, d)))
    else:
        p = dict(wi=_dense(ks[0], (d, f)), wd=_dense(ks[1], (f, d)))
    return p, _mlp_specs(cfg)


def _moe_specs(cfg):
    return dict(
        router=("embed", None),
        wg=("expert", "embed", "mlp"),
        wu=("expert", "embed", "mlp"),
        wd=("expert", "mlp", "embed"),
    )


def _init_moe(cfg, key):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = dict(
        router=_dense(ks[0], (d, e)),
        wg=_dense(ks[1], (e, d, f)),
        wu=_dense(ks[2], (e, d, f)),
        wd=_dense(ks[3], (e, f, d)),
    )
    return p, _moe_specs(cfg)


def _ssm_specs(cfg):
    return dict(
        in_proj=("embed", "inner"),
        conv_w=(None, "inner"),
        conv_b=("inner",),
        x_proj=("inner", None),
        dt_w=(None, "inner"),
        dt_b=("inner",),
        a_log=("inner", None),
        d_skip=("inner",),
        out_proj=("inner", "embed"),
    )


def _init_ssm(cfg, key):
    d, di, n, r, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    dt_init = jnp.exp(
        jax.random.uniform(ks[4], (di,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # softplus inverse
    p = dict(
        in_proj=_dense(ks[0], (d, 2 * di)),
        conv_w=jax.random.normal(ks[1], (k, di)) * (1.0 / math.sqrt(k)),
        conv_b=jnp.zeros((di,)),
        x_proj=_dense(ks[2], (di, r + 2 * n)),
        dt_w=_dense(ks[3], (r, di)),
        dt_b=dt_bias,
        a_log=jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        d_skip=jnp.ones((di,)),
        out_proj=_dense(ks[5], (di, d)),
    )
    return p, _ssm_specs(cfg)


def _norm_specs(cfg):
    s = dict(scale=(None,))
    if cfg.family == "audio":
        s["bias"] = (None,)
    return s


def _norm_param(cfg):
    p = dict(scale=jnp.ones((cfg.d_model,)))
    if cfg.family == "audio":  # hubert uses LayerNorm
        p["bias"] = jnp.zeros((cfg.d_model,))
    return p, _norm_specs(cfg)


def layer_specs(cfg) -> dict:
    """Logical-axes tree of one (unstacked) layer — no allocation."""
    s: dict = {"ln1": _norm_specs(cfg)}
    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        s["attn"] = _attn_specs(cfg)
    if cfg.family == "hybrid":
        s["ssm"] = _ssm_specs(cfg)
        s["alpha_attn"] = (None,)
        s["alpha_ssm"] = (None,)
    if cfg.family == "ssm":
        s["ssm"] = _ssm_specs(cfg)
    if cfg.family == "moe":
        s["ln2"] = _norm_specs(cfg)
        s["moe"] = _moe_specs(cfg)
    elif cfg.family in ("dense", "vlm", "audio", "hybrid"):
        s["ln2"] = _norm_specs(cfg)
        s["mlp"] = _mlp_specs(cfg)
    return s


def _init_layer(cfg, key):
    ks = jax.random.split(key, 4)
    p = {}
    p["ln1"], _ = _norm_param(cfg)
    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        p["attn"], _ = _init_attn(cfg, ks[0])
    if cfg.family == "hybrid":
        p["ssm"], _ = _init_ssm(cfg, ks[1])
        p["alpha_attn"] = jnp.ones((cfg.d_model,))
        p["alpha_ssm"] = jnp.ones((cfg.d_model,))
    if cfg.family == "ssm":
        p["ssm"], _ = _init_ssm(cfg, ks[1])
    if cfg.family == "moe":
        p["ln2"], _ = _norm_param(cfg)
        p["moe"], _ = _init_moe(cfg, ks[2])
    elif cfg.family in ("dense", "vlm", "audio", "hybrid"):
        p["ln2"], _ = _norm_param(cfg)
        p["mlp"], _ = _init_mlp(cfg, ks[2])
    return p, layer_specs(cfg)


def param_specs(cfg) -> dict:
    """Logical-axes tree mirroring init_params' structure — no allocation."""
    _is_leaf = lambda a: isinstance(a, tuple) and all(
        x is None or isinstance(x, str) for x in a
    )
    specs: dict = {"embed": ("vocab", "embed")}
    specs["layers"] = jax.tree_util.tree_map(
        lambda ax: ("layers",) + ax, layer_specs(cfg), is_leaf=_is_leaf
    )
    specs["final_ln"] = _norm_specs(cfg)
    if not cfg.tie_embeddings:
        specs["lm_head"] = ("embed", "vocab")
    if cfg.modality in ("audio", "vision"):
        specs["frontend"] = ("frame", "embed")
    return specs


def init_params(cfg, key) -> tuple[dict, dict]:
    """Returns (params, specs): params with [L, ...]-stacked layers, specs a
    mirror pytree of logical-axis tuples ('layers' prepended on stacks)."""
    k_emb, k_layers, k_head, k_front = jax.random.split(key, 4)
    params: dict = {}
    params["embed"] = jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * 0.02

    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    stacked = jax.vmap(lambda k: _init_layer(cfg, k)[0])(layer_keys)
    params["layers"] = stacked

    params["final_ln"], _ = _norm_param(cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(k_head, (cfg.d_model, cfg.vocab_size))
    if cfg.modality == "audio":
        params["frontend"] = _dense(k_front, (AUDIO_FRAME_DIM, cfg.d_model))
    elif cfg.modality == "vision":
        params["frontend"] = _dense(k_front, (VISION_PATCH_DIM, cfg.d_model))
    return params, param_specs(cfg)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block(
    lp: dict,
    x: Array,
    cfg,
    ctx: FaultContext,
    *,
    positions,
    attn_impl: str,
    moe_impl: str,
    moe_cf: float = 1.25,
    cache: Optional[dict] = None,
    build_cache: bool = False,
    cache_len: int = 0,
    segments: Optional[Array] = None,
):
    """One layer. Returns (x, new_cache (dict|None), aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    h = apply_norm(x, lp["ln1"], cfg.norm_eps)

    if cfg.family == "ssm":
        ssm_cache = (
            SSMCache(cache["conv"], cache["h"]) if cache is not None else None
        )
        y, sc = ssm_block(
            lp["ssm"], h, cfg, ctx, cache=ssm_cache, build_cache=build_cache
        )
        if cache is not None:
            new_cache = dict(conv=sc.conv, h=sc.h)
        elif build_cache:
            new_cache = dict(ssm=sc)
        x = x + y
        return x, (new_cache or None), aux

    if cfg.family == "hybrid":
        kv_cache = None
        ssm_cache = None
        if cache is not None:
            kv_cache = KVCache(cache["k"], cache["v"], cache_len)
            ssm_cache = SSMCache(cache["conv"], cache["h"])
        a, kv_out = attention_block(
            lp["attn"], h, cfg, ctx,
            positions=positions, impl=attn_impl, cache=kv_cache,
            return_kv=build_cache,
        )
        sres, sc = ssm_block(
            lp["ssm"], h, cfg, ctx, cache=ssm_cache, build_cache=build_cache
        )
        y = 0.5 * (a * lp["alpha_attn"].astype(a.dtype) + sres * lp["alpha_ssm"].astype(a.dtype))
        x = x + y
        if cache is not None or build_cache:
            if cache is not None:
                new_cache = dict(k=kv_out.k, v=kv_out.v, conv=sc.conv, h=sc.h)
            else:
                new_cache = dict(kv=kv_out, ssm=sc)
        h2 = apply_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_block(lp["mlp"], h2, cfg, ctx)
        return x, (new_cache or None), aux

    # attention families: dense / moe / vlm / audio
    paged = isinstance(cache, PagedKVView)
    kv_cache = None
    if paged:
        kv_cache = cache
    elif cache is not None:
        kv_cache = KVCache(cache["k"], cache["v"], cache_len)
    a, kv_out = attention_block(
        lp["attn"], h, cfg, ctx,
        positions=positions, impl=attn_impl, cache=kv_cache, return_kv=build_cache,
        segments=segments,
    )
    x = x + a
    if paged:
        new_cache = dict(kp=kv_out.k_pages, vp=kv_out.v_pages)
    elif cache is not None:
        new_cache = dict(k=kv_out.k, v=kv_out.v)
    elif build_cache:
        new_cache = dict(kv=kv_out)
    h2 = apply_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_block(lp["moe"], h2, cfg, ctx, impl=moe_impl, capacity_factor=moe_cf)
    else:
        y = mlp_block(lp["mlp"], h2, cfg, ctx)
    x = x + y
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_inputs(cfg, params, batch: dict, ctx: FaultContext) -> tuple[Array, Array]:
    """Returns (x (B, S, d) in compute dtype, positions (B, S))."""
    dtype = jnp.dtype(cfg.dtype)
    parts = []
    if cfg.modality == "audio":
        x = fault_linear(batch["embeds"].astype(dtype), params["frontend"], ctx)
        parts.append(x)
    else:
        if cfg.modality == "vision" and "embeds" in batch:
            pv = fault_linear(batch["embeds"].astype(dtype), params["frontend"], ctx)
            parts.append(pv)
        if "tokens" in batch:
            te = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dtype)
            parts.append(te)
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = shard_activation(x, ("batch", "seq_carry", "embed"))
    return x, positions


def unembed(cfg, params, x: Array, ctx: FaultContext) -> Array:
    if cfg.tie_embeddings:
        logits = fault_linear(x, params["embed"].T, ctx)
    else:
        logits = fault_linear(x, params["lm_head"], ctx)
    return shard_activation(logits, ("batch", "seq_carry", "vocab"))


# ---------------------------------------------------------------------------
# Forward (train / eval / prefill-without-cache)
# ---------------------------------------------------------------------------


_REMAT_POLICIES = {
    "none": None,
    "dots": "dots_with_no_batch_dims_saveable",
    "full": "nothing_saveable",
}


def forward(
    params: dict,
    batch: dict,
    cfg,
    ctx: Optional[FaultContext] = None,
    *,
    attn_impl: str = "auto",
    moe_impl: str = "einsum",
    moe_cf: float = 1.25,
    remat: str = "dots",
    fault_apply: str = "per_use",
) -> tuple[Array, Array]:
    """Full-sequence forward. Returns (logits (B, S, V), aux_loss).

    fault_apply: 'per_use' masks inside every matmul (paper-faithful);
    'per_step' pre-masks the array-mapped params once (identical math, one
    weight-sized pass per step instead of per use — see EXPERIMENTS SPerf).
    """
    ctx = ctx or healthy()
    ctx_unembed = ctx
    if fault_apply == "per_step" and ctx.active:
        params = mask_selected_params(params, ctx)
        ctx = healthy()
    x, positions = embed_inputs(cfg, params, batch, ctx)

    def body(carry, lp):
        h, aux = carry
        h, _, a = _block(
            lp, h, cfg, ctx,
            positions=positions, attn_impl=attn_impl, moe_impl=moe_impl,
            moe_cf=moe_cf,
        )
        h = shard_activation(h, ("batch", "seq_carry", "embed"))
        return (h, aux + a), None

    if remat != "none":
        policy = getattr(jax.checkpoint_policies, _REMAT_POLICIES[remat])
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = apply_norm(x, params["final_ln"], cfg.norm_eps)
    # tied unembed keeps its use-site mask (the lookup needs unmasked rows)
    logits = unembed(cfg, params, x, ctx_unembed if cfg.tie_embeddings else ctx)
    return logits, aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def loss_fn(
    params: dict,
    batch: dict,
    cfg,
    ctx: Optional[FaultContext] = None,
    *,
    attn_impl: str = "auto",
    moe_impl: str = "einsum",
    moe_cf: float = 1.25,
    remat: str = "dots",
    aux_weight: float = 0.01,
    fault_apply: str = "per_use",
) -> tuple[Array, dict]:
    logits, aux = forward(
        params, batch, cfg, ctx, attn_impl=attn_impl, moe_impl=moe_impl,
        moe_cf=moe_cf, remat=remat, fault_apply=fault_apply,
    )
    labels = batch["labels"]
    # frontends may prepend non-text positions (vlm): align to the tail
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1] :]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = nll.sum() / denom
    acc = (jnp.argmax(logits32, axis=-1) == labels).astype(jnp.float32)
    acc = (acc * mask).sum() / denom
    loss = ce + aux_weight * aux
    return loss, dict(loss=loss, ce=ce, aux=aux, accuracy=acc)


# ---------------------------------------------------------------------------
# KV/SSM cache: init, prefill, decode
# ---------------------------------------------------------------------------


def cache_buffer_len(cfg, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg, batch: int, seq_len: int) -> dict:
    """Zero cache able to hold ``seq_len`` history (window-bounded for SWA).

    Layout: stacked [L, ...] arrays + scalar 'index'."""
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.num_layers
    c: dict = {"index": jnp.zeros((), jnp.int32)}
    if cfg.has_attention:
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        s_buf = cache_buffer_len(cfg, seq_len)
        c["k"] = jnp.zeros((L, batch, hkv, s_buf, hd), dtype)
        c["v"] = jnp.zeros((L, batch, hkv, s_buf, hd), dtype)
    if cfg.has_ssm:
        c["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype)
        c["h"] = jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
    return c


def cache_specs(cfg) -> dict:
    """Logical axes for the cache pytree (for pjit in/out shardings)."""
    c: dict = {"index": ()}
    if cfg.has_attention:
        c["k"] = ("layers", "batch", "kv_heads", "kv_seq", None)
        c["v"] = ("layers", "batch", "kv_heads", "kv_seq", None)
    if cfg.has_ssm:
        c["conv"] = ("layers", "batch", None, "inner")
        c["h"] = ("layers", "batch", "inner", "state")
    return c


def _ring_perm(s_buf: int, total: int) -> np.ndarray:
    """inv_perm[slot] = index (into the last s_buf tokens) stored at slot."""
    return (np.arange(s_buf) - (total % s_buf)) % s_buf


def prefill(
    params: dict,
    batch: dict,
    cfg,
    ctx: Optional[FaultContext] = None,
    *,
    cache_len: Optional[int] = None,
    attn_impl: str = "auto",
    moe_impl: str = "einsum",
    moe_cf: float = 1.25,
    valid_len=None,
    full_kv: bool = False,
    return_hidden: bool = False,
    segments: Optional[Array] = None,
) -> tuple[Array, dict]:
    """Full-sequence forward that also builds the decode cache.

    Returns (logits_last (B, V), cache). With every new option at its
    default the function is byte-identical to the pre-bucketing prefill.

    ``valid_len`` (traced scalar or ``(B,)``) marks the real prompt length
    of a right-padded batch: logits come from position ``valid_len - 1``
    and the cache assembly keeps the *valid* tokens (the SWA ring
    permutation is computed from ``valid_len``, not the padded width), with
    ``cache["index"] = valid_len`` so decode overwrites the pad garbage.
    Pad columns never contaminate real rows — causality alone excludes
    right-pad keys from every real query.

    ``full_kv`` skips ring/tail truncation and returns the raw
    ``(L, B, Hkv, S, hd)`` KV as the cache's k/v — the paged-admission
    route, where window masking happens at the paged read instead.

    ``return_hidden`` returns the post-norm hidden states ``(B, S, d)`` in
    place of logits so the caller can gather arbitrary positions (packed
    prefill gathers one last-token row per segment) and unembed itself.

    ``segments`` (``(B, S)`` int, with per-segment restarting
    ``batch["positions"]``) packs several prompts into one row; attention
    is masked to same-segment tokens (``repro.models.layers``).
    """
    ctx = ctx or healthy()
    x, positions = embed_inputs(cfg, params, batch, ctx)
    b, s = x.shape[0], x.shape[1]
    cache_len = cache_len or s
    s_buf = cache_buffer_len(cfg, cache_len)
    if (full_kv or segments is not None or valid_len is not None) and (
        cfg.has_ssm or cfg.is_encoder
    ):
        # SSM state is a running scan — right-pad tokens would advance it —
        # and encoders attend bidirectionally, so pad keys aren't causal-masked
        raise ValueError("padded/packed prefill supports causal attention families only")

    def body(carry, lp):
        h, aux = carry
        h, piece, a = _block(
            lp, h, cfg, ctx,
            positions=positions, attn_impl=attn_impl, moe_impl=moe_impl,
            moe_cf=moe_cf, build_cache=True, segments=segments,
        )
        h = shard_activation(h, ("batch", "seq_carry", "embed"))
        return (h, aux + a), piece

    (x, _aux), pieces = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["layers"]
    )
    x = apply_norm(x, params["final_ln"], cfg.norm_eps)
    if return_hidden:
        out = x
    elif valid_len is None:
        out = unembed(cfg, params, x[:, -1:, :], ctx)[:, 0]
    else:
        vl = jnp.asarray(valid_len, jnp.int32)
        if vl.ndim == 0:
            last = jax.lax.dynamic_slice_in_dim(x, vl - 1, 1, axis=1)
        else:
            last = jnp.take_along_axis(x, (vl - 1)[:, None, None], axis=1)
        out = unembed(cfg, params, last, ctx)[:, 0]

    if full_kv:
        k_new, v_new = pieces["kv"]
        dt = jnp.dtype(cfg.dtype)
        index = jnp.asarray(s if valid_len is None else valid_len, jnp.int32)
        return out, dict(k=k_new.astype(dt), v=v_new.astype(dt), index=index)

    cache = init_cache(cfg, b, cache_len)
    if cfg.has_attention:
        k_new, v_new = pieces["kv"]  # (L, B, Hkv, S, hd)
        if s >= s_buf:
            if valid_len is None:
                tail_k, tail_v = k_new[..., -s_buf:, :], v_new[..., -s_buf:, :]
                perm = jnp.asarray(_ring_perm(s_buf, s)) if cfg.sliding_window and s_buf == cfg.sliding_window else jnp.arange(s_buf)
            else:
                # padded prompt: the last s_buf VALID tokens end at valid_len
                vl = jnp.asarray(valid_len, jnp.int32)
                start = jnp.clip(vl - s_buf, 0, s - s_buf)
                tail_k = jax.lax.dynamic_slice_in_dim(k_new, start, s_buf, axis=3)
                tail_v = jax.lax.dynamic_slice_in_dim(v_new, start, s_buf, axis=3)
                if cfg.sliding_window and s_buf == cfg.sliding_window:
                    # generalizes _ring_perm to a traced total: before the
                    # ring wraps (vl < s_buf) the layout is linear
                    shift = jnp.where(vl >= s_buf, vl % s_buf, 0)
                    perm = (jnp.arange(s_buf) - shift) % s_buf
                else:
                    perm = jnp.arange(s_buf)
            cache["k"] = jnp.take(tail_k, perm, axis=3).astype(cache["k"].dtype)
            cache["v"] = jnp.take(tail_v, perm, axis=3).astype(cache["v"].dtype)
        else:
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), 0, axis=3
            )
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), 0, axis=3
            )
    if cfg.has_ssm:
        sc = pieces["ssm"]
        cache["conv"] = sc.conv.astype(cache["conv"].dtype)
        cache["h"] = sc.h
    cache["index"] = jnp.asarray(s if valid_len is None else valid_len, jnp.int32)
    return out, cache


def prefill_chunk(
    params: dict,
    tokens: Array,  # (1, C) — one chunk of one request's prompt
    cfg,
    ctx: Optional[FaultContext] = None,
    *,
    k_pages: Array,  # (L, P, Hkv, page, hd) shared pool
    v_pages: Array,
    row: Array,  # (max_pages_per_seq,) int32 — this slot's page chain
    prefix_len,  # traced scalar: tokens already prefilled (multiple of C)
    valid_len,  # traced scalar: real tokens in this chunk (== C except last)
    moe_impl: str = "einsum",
    moe_cf: float = 1.25,
) -> tuple[Array, Array, Array]:
    """One chunked-prefill step: continue a prompt against its paged prefix.

    Gathers the slot's page chain into a dense buffer, runs the chunk as a
    multi-token continuation (causal attention at ``q_offset=prefix_len``
    over ``prefix + chunk`` valid keys — sliding windows are handled by the
    dense window mask, never the ring buffer, so chunk boundaries crossing
    the window are exact), and returns
    ``(logits (1, V) at valid_len - 1, k_chunk, v_chunk (L, 1, Hkv, C, hd))``
    for the caller to scatter into the pool. ONE compiled shape covers every
    chunk of every prompt: prefix/valid are traced, the chain width is the
    engine-wide ``max_pages_per_seq``.
    """
    ctx = ctx or healthy()
    if cfg.has_ssm or cfg.is_encoder:
        raise ValueError("chunked prefill supports causal attention families only")
    b, s = tokens.shape
    if b != 1:
        raise ValueError(f"chunked prefill is one request per dispatch, got batch {b}")
    L, _, hkv, page, hd = k_pages.shape
    cap = row.shape[0] * page
    # buffer must fit any chunk write at a chunk-aligned prefix, and must
    # dodge the ring-buffer branch in attention_block (its causal=False
    # shortcut is decode-only — wrong for multi-token chunks)
    w_buf = -(-cap // s) * s
    if cfg.sliding_window and w_buf == cfg.sliding_window:
        w_buf += page
    prefix = jnp.asarray(prefix_len, jnp.int32)
    vl = jnp.asarray(valid_len, jnp.int32)
    positions = jnp.broadcast_to(prefix + jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = shard_activation(x, ("batch", "seq", "embed"))

    def chain_dense(pool):  # (L, P, Hkv, page, hd) -> (L, 1, Hkv, w_buf, hd)
        g = jnp.transpose(jnp.take(pool, row, axis=1), (0, 2, 1, 3, 4))
        g = g.reshape(L, hkv, cap, hd)
        return jnp.pad(g, ((0, 0), (0, 0), (0, w_buf - cap), (0, 0)))[:, None]

    layer_cache = {"k": chain_dense(k_pages), "v": chain_dense(v_pages)}

    def body(carry, xs):
        h, aux = carry
        lp, lc = xs
        h, nc, a = _block(
            lp, h, cfg, ctx,
            positions=positions, attn_impl="dense", moe_impl=moe_impl,
            moe_cf=moe_cf, cache=lc, cache_len=prefix,
        )
        return (h, aux + a), nc

    (x, _aux), new_layer_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["layers"], layer_cache)
    )
    x = apply_norm(x, params["final_ln"], cfg.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(x, vl - 1, 1, axis=1)
    logits = unembed(cfg, params, last, ctx)[:, 0]
    k_chunk = jax.lax.dynamic_slice_in_dim(new_layer_cache["k"], prefix, s, axis=3)
    v_chunk = jax.lax.dynamic_slice_in_dim(new_layer_cache["v"], prefix, s, axis=3)
    return logits, k_chunk, v_chunk


def decode_step(
    params: dict,
    tokens: Array,  # (B, s_new) — usually s_new == 1
    cache: dict,
    cfg,
    ctx: Optional[FaultContext] = None,
    *,
    moe_impl: str = "einsum",
    moe_cf: float = 1.25,
    active: Optional[Array] = None,
) -> tuple[Array, dict]:
    """One autoregressive step against the cache. Returns (logits, cache').

    ``cache`` is either the dense cache from :func:`prefill`/:func:`init_cache`
    or a paged cache (``repro.serve.kvcache.init_paged_cache``), detected by
    its ``k_pages`` key. The paged path reads each slot's page chain with a
    gather and supports per-slot positions — slot ``b`` sits at its own
    ``seq_lens[b]`` — plus ``active`` masking: inactive slots neither write
    KV (their token lands on the reserved scratch page) nor advance their
    length. ``active`` is ignored on the dense path, whose single scalar
    index always advances.
    """
    ctx = ctx or healthy()
    if "k_pages" in cache:
        return _decode_step_paged(
            params, tokens, cache, cfg, ctx,
            moe_impl=moe_impl, moe_cf=moe_cf, active=active,
        )
    b, s = tokens.shape
    index = cache["index"]
    positions = index + jnp.arange(s, dtype=jnp.int32)[None]
    positions = jnp.broadcast_to(positions, (b, s))
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = shard_activation(x, ("batch", "seq", "embed"))

    layer_cache = {k: v for k, v in cache.items() if k != "index"}

    def body(carry, xs):
        h, aux = carry
        lp, lc = xs
        h, nc, a = _block(
            lp, h, cfg, ctx,
            positions=positions, attn_impl="dense", moe_impl=moe_impl,
            moe_cf=moe_cf, cache=lc, cache_len=index,
        )
        return (h, aux + a), nc

    (x, _aux), new_layer_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["layers"], layer_cache)
    )
    x = apply_norm(x, params["final_ln"], cfg.norm_eps)
    logits = unembed(cfg, params, x, ctx)
    new_cache = dict(new_layer_cache)
    new_cache["index"] = index + s
    return logits, new_cache


def init_paged_cache(
    cfg, num_pages: int, page_size: int, num_slots: int, max_pages_per_seq: int
) -> dict:
    """Zero paged KV cache: a shared page pool + per-slot block tables.

    Layout: ``k_pages``/``v_pages`` are ``(L, num_pages, Hkv, page_size, hd)``
    pools (page 0 reserved as the scratch page — see
    ``repro.serve.kvcache.PageAllocator``), ``block_tables`` is
    ``(num_slots, max_pages_per_seq)`` int32 page ids and ``seq_lens`` is the
    per-slot cached-token count. Attention-family models only: SSM/hybrid
    state is O(1) per slot and needs no paging, and encoders have no decode.
    """
    if cfg.has_ssm:
        raise ValueError(
            f"paged KV cache supports attention families only; {cfg.family!r} "
            "carries SSM state (which is O(1) per slot and needs no paging)"
        )
    if cfg.is_encoder:
        raise ValueError("encoder-only arch has no decode path to page")
    dtype = jnp.dtype(cfg.dtype)
    L, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k_pages": jnp.zeros((L, num_pages, hkv, page_size, hd), dtype),
        "v_pages": jnp.zeros((L, num_pages, hkv, page_size, hd), dtype),
        "block_tables": jnp.zeros((num_slots, max_pages_per_seq), jnp.int32),
        "seq_lens": jnp.zeros((num_slots,), jnp.int32),
    }


def _decode_step_paged(
    params: dict,
    tokens: Array,  # (S, 1) — one token per slot
    cache: dict,
    cfg,
    ctx: FaultContext,
    *,
    moe_impl: str = "einsum",
    moe_cf: float = 1.25,
    active: Optional[Array] = None,
) -> tuple[Array, dict]:
    """Gather-based paged decode: per-slot positions, shared page pool."""
    if cfg.has_ssm:
        raise ValueError(f"paged decode supports attention families only, not {cfg.family!r}")
    b, s = tokens.shape
    lens = cache["seq_lens"]
    bt = cache["block_tables"]
    positions = jnp.broadcast_to(lens[:, None] + jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = shard_activation(x, ("batch", "seq", "embed"))

    def body(carry, xs):
        h, aux = carry
        lp, (kp, vp) = xs
        view = PagedKVView(kp, vp, bt, lens, active)
        h, nc, a = _block(
            lp, h, cfg, ctx,
            positions=positions, attn_impl="dense", moe_impl=moe_impl,
            moe_cf=moe_cf, cache=view,
        )
        return (h, aux + a), nc

    (x, _aux), new_pages = jax.lax.scan(
        body,
        (x, jnp.zeros((), jnp.float32)),
        (params["layers"], (cache["k_pages"], cache["v_pages"])),
    )
    x = apply_norm(x, params["final_ln"], cfg.norm_eps)
    logits = unembed(cfg, params, x, ctx)
    advanced = lens + s if active is None else jnp.where(active, lens + s, lens)
    return logits, dict(
        k_pages=new_pages["kp"],
        v_pages=new_pages["vp"],
        block_tables=bt,
        seq_lens=advanced,
    )
