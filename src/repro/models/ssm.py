"""Mamba-1 selective-SSM block (falcon-mamba / hymba's SSM branch).

Train/prefill uses the selective scan (Pallas kernel on TPU, lax.scan
reference elsewhere); decode carries (conv_state, ssm_state) — O(1) memory
in sequence length, which is what makes the long_500k cells runnable.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.masking import FaultContext, fault_linear
from repro.kernels.mamba_scan.ops import selective_scan, selective_step
from repro.launch.sharding import shard_activation

Array = jax.Array


class SSMCache(NamedTuple):
    conv: Array  # (B, K-1, d_inner) last inputs to the causal conv
    h: Array  # (B, d_inner, N) SSM state


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv via shift-and-add (K is tiny, typically 4).

    x: (B, L, D); w: (K, D); b: (D,). Elementwise formulation shards
    cleanly (no conv op in the HLO)."""
    k = w.shape[0]
    w = w.astype(x.dtype)
    b = b.astype(x.dtype)
    out = x * w[-1][None, None, :]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + shifted * w[k - 1 - i][None, None, :]
    return out + b[None, None, :]


def ssm_block(
    p: dict,
    x: Array,  # (B, S, d_model)
    cfg,
    ctx: FaultContext,
    *,
    cache: Optional[SSMCache] = None,
    build_cache: bool = False,
):
    """Returns (y (B, S, d_model), new_cache).

    ``build_cache`` (prefill): run the full scan and emit the decode cache
    (conv-input tail + final SSM state)."""
    b, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = fault_linear(x, p["in_proj"], ctx)  # (B, S, 2*di)
    xb, z = jnp.split(xz, 2, axis=-1)
    xb = shard_activation(xb, ("batch", "seq", "inner"))

    new_cache = None
    if cache is None:
        xc = _causal_conv(xb, p["conv_w"], p["conv_b"])
        if build_cache:
            kc = cfg.ssm_conv - 1
            hist = xb if s >= kc else jnp.pad(xb, ((0, 0), (kc - s, 0), (0, 0)))
            new_conv = hist[:, -kc:, :]
    else:
        # decode: prepend the conv state, run the conv, keep the tail
        hist = jnp.concatenate([cache.conv.astype(xb.dtype), xb], axis=1)
        xc = _causal_conv(hist, p["conv_w"], p["conv_b"])[:, -s:, :]
        new_conv = hist[:, -(cfg.ssm_conv - 1) :, :]
    xc = jax.nn.silu(xc)

    dbc = fault_linear(xc, p["x_proj"], ctx)  # (B, S, r + 2N)
    r = cfg.resolved_dt_rank
    dt, bmat, cmat = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(fault_linear(dt, p["dt_w"], ctx) + p["dt_b"])  # (B,S,di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, N)

    if cache is None:
        y, h_last = selective_scan(xc, dt, a, bmat, cmat, p["d_skip"])
        if build_cache:
            new_cache = SSMCache(conv=new_conv, h=h_last)
    else:
        h = cache.h
        ys = []
        for i in range(s):  # decode steps are 1 (or a small static number)
            y_i, h = selective_step(
                h, xc[:, i], dt[:, i], a, bmat[:, i], cmat[:, i], p["d_skip"]
            )
            ys.append(y_i)
        y = jnp.stack(ys, axis=1)
        new_cache = SSMCache(conv=new_conv, h=h)

    y = y * jax.nn.silu(z)
    return fault_linear(y, p["out_proj"], ctx), new_cache


def init_ssm_cache(cfg, batch: int, dtype) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        h=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    )
