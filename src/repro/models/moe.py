"""Mixture-of-Experts block: top-k token-choice routing with static
capacity, in two interchangeable implementations.

``einsum``  — GShard/t5x-faithful one-hot dispatch/combine einsums. Simple,
              robust, but the dispatch matmuls add O(T*E*C*d) FLOPs.
``scatter`` — position-in-expert via cumsum + scatter-add dispatch and
              gather combine: zero extra matmul FLOPs, same semantics.
              (The beyond-paper optimization; see EXPERIMENTS.md SPerf.)

Experts shard over the mesh 'model' axis when E divides it (expert
parallelism — llama4's 128 experts); otherwise the expert FFN dims shard
over 'model' (tensor parallelism inside experts — mixtral's 8).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.masking import FaultContext, fault_einsum, fault_linear
from repro.launch.sharding import shard_activation

Array = jax.Array


def _router(p, x2d, cfg, ctx):
    """Returns (weights (T,k), expert_idx (T,k), aux_loss scalar)."""
    t, d = x2d.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = fault_linear(x2d, p["router"], ctx).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(logits, k)  # (T, k)
    weights = jax.nn.softmax(gate_vals, axis=-1)  # renormalize over selected
    # Switch load-balance loss: E * sum_e f_e * P_e
    sel_onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum(axis=1)  # (T, E)
    f_e = sel_onehot.mean(axis=0) / k
    p_e = probs.mean(axis=0)
    aux = e * jnp.sum(f_e * p_e)
    # router z-loss (numerics guard at scale)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return weights, expert_idx, aux + 1e-3 * z


def _expert_ffn(p, h, cfg, ctx):
    """h: (E, C*, d) -> (E, C*, f) -> (E, C*, d), per-expert GEMMs."""
    if cfg.activation == "swiglu":
        g = fault_einsum("ecd,edf->ecf", h, p["wg"], ctx)
        u = fault_einsum("ecd,edf->ecf", h, p["wu"], ctx)
        z = jax.nn.silu(g) * u
    else:
        z = jax.nn.gelu(fault_einsum("ecd,edf->ecf", h, p["wi"], ctx))
    z = shard_activation(z, ("expert", None, "mlp"))
    return fault_einsum("ecf,efd->ecd", z, p["wd"], ctx)


def moe_block(
    p: dict,
    x: Array,  # (B, S, d)
    cfg,
    ctx: FaultContext,
    *,
    impl: str = "einsum",
    capacity_factor: float = 1.25,
):
    """Returns (y (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    x2d = x.reshape(b * s, d)
    t = b * s
    weights, expert_idx, aux = _router(p, x2d, cfg, ctx)
    cap = max(k, int(s * k / e * capacity_factor)) if t >= e else k
    # capacity is per (batch-row group): groups of size s keep dispatch
    # tensors bounded and make the a2a pattern explicit under pjit.
    g, gs = b, s

    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (T, k, E)
    # position of each token within its expert queue, per group
    oh_g = onehot.reshape(g, gs, k, e)
    pos_in_expert = (
        jnp.cumsum(oh_g.reshape(g, gs * k, e), axis=1).reshape(g, gs, k, e) - 1
    )
    keep = (pos_in_expert < cap) & (oh_g > 0)  # (g, gs, k, E)
    w_g = weights.reshape(g, gs, k)

    if impl == "einsum":
        # dispatch (g, gs, E, cap) one-hot over capacity slots
        pos_clamped = jnp.clip(pos_in_expert, 0, cap - 1)
        cap_oh = jax.nn.one_hot(pos_clamped, cap, dtype=x.dtype)  # (g,gs,k,E,cap)
        dispatch = jnp.einsum(
            "gskec,gske->gsec", cap_oh, keep.astype(x.dtype)
        )  # (g, gs, E, cap)
        combine = jnp.einsum("gsec,gsk,gske->gsec", dispatch, w_g.astype(x.dtype), keep.astype(x.dtype))
        xg = x2d.reshape(g, gs, d)
        h = jnp.einsum("gsec,gsd->gecd", dispatch, xg)  # (g, E, cap, d)
        h = h.reshape(g, e, cap, d).swapaxes(0, 1).reshape(e, g * cap, d)
        h = shard_activation(h, ("expert", "moe_slots", None))
        out = _expert_ffn(p, h, cfg, ctx)  # (E, g*cap, d)
        out = out.reshape(e, g, cap, d).swapaxes(0, 1)  # (g, E, cap, d)
        y = jnp.einsum("gsec,gecd->gsd", combine, out)
        y = shard_activation(y.reshape(b, s, d), ("batch", "seq_carry", "embed"))
        return y, aux

    if impl == "scatter":
        # slot id for each (token, k): e * cap + pos; dropped -> dumped into
        # a zero-weight contribution via keep mask
        slot = (
            jnp.argmax(oh_g, axis=-1) * cap + jnp.clip((pos_in_expert * oh_g).sum(-1), 0, cap - 1)
        )  # (g, gs, k)
        keep_tok = keep.any(axis=-1)  # (g, gs, k)
        xg = x2d.reshape(g, gs, d)

        def per_group(xg_i, slot_i, keep_i, w_i):
            # scatter-add tokens into their expert slots
            contrib = xg_i[:, None, :] * keep_i[..., None].astype(xg_i.dtype)  # (gs,k,d)
            h = jnp.zeros((e * cap, d), xg_i.dtype).at[slot_i.reshape(-1)].add(
                contrib.reshape(-1, d)
            )
            return h  # (e*cap, d)

        h = jax.vmap(per_group)(xg, slot, keep_tok, w_g)  # (g, e*cap, d)
        h = h.reshape(g, e, cap, d).swapaxes(0, 1).reshape(e, g * cap, d)
        h = shard_activation(h, ("expert", "moe_slots", None))
        out = _expert_ffn(p, h, cfg, ctx)
        out = out.reshape(e, g, cap, d).swapaxes(0, 1).reshape(g, e * cap, d)

        def per_group_combine(out_i, slot_i, keep_i, w_i):
            gathered = out_i[slot_i.reshape(-1)].reshape(gs, k, d)
            wk = (w_i * keep_i.astype(w_i.dtype))[..., None].astype(gathered.dtype)
            return (gathered * wk).sum(axis=1)  # (gs, d)

        y = jax.vmap(per_group_combine)(out, slot, keep_tok, w_g)
        y = shard_activation(y.reshape(b, s, d), ("batch", "seq_carry", "embed"))
        return y, aux

    raise ValueError(f"unknown moe impl {impl!r}")
