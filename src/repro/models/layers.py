"""Model building blocks — pure JAX, fault-aware, shard-annotated.

Every parameterized matmul routes through ``fault_linear``/``fault_einsum``
so a chip's fault map (FaultContext) masks exactly the weights that the
systolic mapping places on faulty PEs (DESIGN.md S2).

Attention has three interchangeable implementations:
  dense      — materializes scores; for short q (decode) and tiny smoke tests
  blockwise  — pure-JAX flash (scan over q chunks, online softmax over kv
               chunks); memory-safe at 32k+, lowers on any backend
  pallas     — the TPU kernel (repro.kernels.flash_attention)
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.masking import FaultContext, fault_einsum, fault_linear
from repro.launch.sharding import shard_activation

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x: Array, p: dict, eps: float) -> Array:
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p["scale"], eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, H, S, D); positions: (B, S) absolute token positions."""
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)  # (D/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * inv  # (B,1,S,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention implementations
# ---------------------------------------------------------------------------


def dense_attention(
    q: Array, k: Array, v: Array, *, causal: bool, window: Optional[int],
    q_offset, kv_valid_len=None, scale: Optional[float] = None,
    segments: Optional[Array] = None,
) -> Array:
    """Materializing attention; q_offset may be a traced scalar (decode).

    ``q_offset`` / ``kv_valid_len`` may also be per-sequence ``(B,)`` arrays
    (the continuous-batching decode path, where every slot sits at its own
    position in its own KV chain); the scalar path is left byte-identical.

    ``segments`` is a ``(B, S)`` int array for packed prefill (several
    prompts in one row, ``repro.serve.bucketing``): tokens may only attend
    within their own segment. Requires ``sq == skv`` — the ids describe
    queries and keys at once. Causal/window masks stay in packed-row index
    space, which equals per-segment position space within a segment because
    packed positions restart per segment.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s *= scale
    off = jnp.asarray(q_offset)
    vld = None if kv_valid_len is None else jnp.asarray(kv_valid_len)
    if segments is not None and sq != skv:
        raise ValueError(f"segment masking needs sq == skv, got {sq} vs {skv}")
    if off.ndim or (vld is not None and vld.ndim) or segments is not None:
        # per-sequence offsets/lengths: mask is (B, sq, skv)
        rows = jnp.broadcast_to(off, (b,))[:, None, None] + jnp.arange(sq)[None, :, None]
        cols = jnp.arange(skv)[None, None, :]
        mask = jnp.ones((b, sq, skv), dtype=bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        if vld is not None:
            mask = mask & (cols < jnp.broadcast_to(vld, (b,))[:, None, None])
        if segments is not None:
            mask = mask & (segments[:, :, None] == segments[:, None, :])
        s = jnp.where(mask[:, None, None], s, -1e30)
    else:
        rows = jnp.arange(sq)[:, None] + q_offset
        cols = jnp.arange(skv)[None, :]
        mask = jnp.ones((sq, skv), dtype=bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        if kv_valid_len is not None:
            mask = mask & (cols < kv_valid_len)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def blockwise_attention(
    q: Array, k: Array, v: Array, *, causal: bool, window: Optional[int],
    q_offset: int = 0, q_chunk: int = 1024, kv_chunk: int = 1024,
    scale: Optional[float] = None, mixed: bool = False, unroll: bool = False,
) -> Array:
    """Pure-JAX flash attention: O(S * w) for sliding windows via dynamic
    kv slices, online softmax over kv chunks otherwise. Lowers on all
    backends with flat memory; the HLO is a 2-level scan.

    mixed=True keeps the QK/PV dots in the input dtype with fp32
    accumulation (halves score-buffer traffic; softmax stats stay fp32).
    unroll=True unrolls the causal q-chunk loop with STATIC per-chunk kv
    extents, eliminating the 2x fully-masked-block waste of the scan form.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    group = hq // hkv
    q_chunk = min(q_chunk, sq)
    while sq % q_chunk:
        q_chunk //= 2
    kv_chunk = min(kv_chunk, skv)
    while skv % kv_chunk:
        kv_chunk //= 2
    nq = sq // q_chunk

    dot_dtype = q.dtype if mixed else jnp.float32
    kg = k.astype(dot_dtype)
    vg = v.astype(dot_dtype)

    if unroll and causal and window is None and q_offset == 0 and sq == skv:
        # static causal extents: chunk qi attends kv[0 : (qi+1)*q_chunk]
        outs = []
        for qi in range(nq):
            qs = qi * q_chunk
            qc = q[:, :, qs : qs + q_chunk].astype(dot_dtype)
            qcg = qc.reshape(b, hkv, group, q_chunk, d)
            kc = kg[:, :, : qs + q_chunk]
            vc = vg[:, :, : qs + q_chunk]
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qcg, kc, preferred_element_type=jnp.float32
            ) * scale
            rows = qs + jnp.arange(q_chunk)[:, None]
            cols = jnp.arange(qs + q_chunk)[None, :]
            s = jnp.where((cols <= rows)[None, None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(dot_dtype), vc,
                preferred_element_type=jnp.float32,
            )
            outs.append(o.reshape(b, hq, q_chunk, d).astype(q.dtype))
        return jnp.concatenate(outs, axis=2)

    if window is not None:
        # SWA: each q chunk only needs kv span [qs - window + 1, qs + q_chunk)
        span = window + q_chunk
        span = min(span, skv)

        def one_chunk(qi):
            qs = qi * q_chunk
            qc = jax.lax.dynamic_slice_in_dim(q, qs, q_chunk, axis=2).astype(dot_dtype)
            start = jnp.clip(qs + q_offset - window + 1, 0, skv - span)
            kc = jax.lax.dynamic_slice_in_dim(kg, start, span, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vg, start, span, axis=2)
            qcg = qc.reshape(b, hkv, group, q_chunk, d)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qcg, kc, preferred_element_type=jnp.float32
            ) * scale
            rows = qs + q_offset + jnp.arange(q_chunk)[:, None]
            cols = start + jnp.arange(span)[None, :]
            m = (cols <= rows) if causal else jnp.ones_like(cols <= rows)
            m = m & (cols > rows - window)
            s = jnp.where(m[None, None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(dot_dtype), vc,
                preferred_element_type=jnp.float32,
            )
            return o.reshape(b, hq, q_chunk, d).astype(q.dtype)

        chunks = jax.lax.map(one_chunk, jnp.arange(nq))
        return jnp.moveaxis(chunks, 0, 2).reshape(b, hq, sq, d)

    nk = skv // kv_chunk

    def one_q_chunk(qi):
        qs = qi * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(q, qs, q_chunk, axis=2).astype(dot_dtype)
        qcg = qc.reshape(b, hkv, group, q_chunk, d)

        def inner(carry, ki):
            acc, m_run, l_run = carry
            ks = ki * kv_chunk
            kc = jax.lax.dynamic_slice_in_dim(kg, ks, kv_chunk, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vg, ks, kv_chunk, axis=2)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qcg, kc, preferred_element_type=jnp.float32
            ) * scale
            if causal:
                rows = qs + q_offset + jnp.arange(q_chunk)[:, None]
                cols = ks + jnp.arange(kv_chunk)[None, :]
                s = jnp.where((cols <= rows)[None, None, None], s, -1e30)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_run, m_cur)
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha[..., 0][..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(dot_dtype), vc,
                preferred_element_type=jnp.float32,
            )
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, hkv, group, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, hkv, group, q_chunk, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, group, q_chunk, 1), jnp.float32)
        (acc, m_run, l_run), _ = jax.lax.scan(inner, (acc0, m0, l0), jnp.arange(nk))
        o = acc / jnp.maximum(l_run[..., 0][..., None], 1e-30)
        return o.reshape(b, hq, q_chunk, d).astype(q.dtype)

    chunks = jax.lax.map(one_q_chunk, jnp.arange(nq))
    return jnp.moveaxis(chunks, 0, 2).reshape(b, hq, sq, d)


def attention_impl(
    q, k, v, *, causal, window, q_offset=0, impl="auto", kv_valid_len=None, scale=None,
    segments=None,
):
    sq = q.shape[2]
    if impl == "auto":
        impl = (
            "dense"
            if (sq <= 512 or kv_valid_len is not None or segments is not None)
            else "blockwise"
        )
    if impl == "dense":
        return dense_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            kv_valid_len=kv_valid_len, scale=scale, segments=segments,
        )
    if segments is not None:
        raise ValueError(f"segment-packed attention is dense-only, got impl {impl!r}")
    if impl.startswith("blockwise"):
        return blockwise_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale,
            mixed="_mx" in impl, unroll="_unroll" in impl,
        )
    if impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(
            q, k, v, causal=causal, window=window, q_offset=int(q_offset), scale=scale
        )
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# Attention block (GQA + RoPE + qk_norm + SWA) with optional KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: Array  # (B, Hkv, S_buf, D)
    v: Array
    index: Array  # scalar int32: absolute position of next token


class PagedKVView(NamedTuple):
    """One layer's slice of a paged KV cache (repro.serve.kvcache).

    The pool holds ``P`` pages of ``page_size`` tokens each; slot ``b``'s
    history is the page chain ``block_tables[b]`` truncated to
    ``seq_lens[b]`` tokens. Page 0 is reserved as a scratch page: writes of
    masked-out slots (``write_mask`` False — retired slots between
    retirement and re-admission) are redirected there so they can never
    corrupt pages the allocator has already handed to another slot.
    """

    k_pages: Array  # (P, Hkv, page_size, D)
    v_pages: Array
    block_tables: Array  # (S, max_pages) int32 page ids
    seq_lens: Array  # (S,) int32 tokens already cached per slot
    write_mask: Optional[Array]  # (S,) bool; None = every slot writes


def attention_block(
    p: dict,
    x: Array,  # (B, S, d_model)
    cfg,
    ctx: FaultContext,
    *,
    positions: Array,
    impl: str = "auto",
    cache: Optional[KVCache] = None,
    return_kv: bool = False,
    segments: Optional[Array] = None,
):
    """Returns (out, new_cache). With ``return_kv`` (prefill) the second
    element is the raw (k, v) pair (B, Hkv, S, D) for cache assembly.
    ``segments`` (packed prefill, cache-free path only) restricts attention
    to same-segment tokens — see ``dense_attention``."""
    b, s, _ = x.shape
    if segments is not None and cache is not None:
        raise ValueError("segment-packed attention is a cache-free prefill path")
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = fault_linear(x, p["wq"], ctx).reshape(b, s, hq, hd)
    k = fault_linear(x, p["wk"], ctx).reshape(b, s, hkv, hd)
    v = fault_linear(x, p["wv"], ctx).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = jnp.moveaxis(q, 1, 2)  # (B, H, S, D)
    k = jnp.moveaxis(k, 1, 2)
    v = jnp.moveaxis(v, 1, 2)
    if not cfg.is_encoder:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_activation(q, ("batch", "heads", "seq", None))

    new_cache = None
    if isinstance(cache, PagedKVView):
        # paged decode: scatter the new token into each slot's current page,
        # gather the slot's page chain, attend with per-slot positions
        if s != 1:
            raise ValueError(f"paged decode is one token per step, got s={s}")
        page = cache.k_pages.shape[2]
        maxp = cache.block_tables.shape[1]
        pos = cache.seq_lens  # (S,)
        chain_ix = jnp.clip(pos // page, 0, maxp - 1)
        page_ix = jnp.take_along_axis(cache.block_tables, chain_ix[:, None], axis=1)[:, 0]
        if cache.write_mask is not None:
            page_ix = jnp.where(cache.write_mask, page_ix, 0)  # page 0 = scratch
        off = pos % page
        k_pages = cache.k_pages.at[page_ix, :, off].set(k[:, :, 0].astype(cache.k_pages.dtype))
        v_pages = cache.v_pages.at[page_ix, :, off].set(v[:, :, 0].astype(cache.v_pages.dtype))
        kg = jnp.moveaxis(jnp.take(k_pages, cache.block_tables, axis=0), 2, 1)
        vg = jnp.moveaxis(jnp.take(v_pages, cache.block_tables, axis=0), 2, 1)
        kg = kg.reshape(b, hkv, maxp * page, hd)  # (S, Hkv, maxp*page, D)
        vg = vg.reshape(b, hkv, maxp * page, hd)
        o = dense_attention(
            q, kg, vg, causal=True, window=cfg.sliding_window,
            q_offset=pos, kv_valid_len=pos + 1, scale=None,
        )
        new_cache = PagedKVView(
            k_pages, v_pages, cache.block_tables, cache.seq_lens, cache.write_mask
        )
    elif cache is not None:
        s_buf = cache.k.shape[2]
        window = cfg.sliding_window
        # rolling buffer for SWA; linear buffer otherwise
        slot = cache.index % s_buf if (window and s_buf == window) else cache.index
        k_new = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, axis=2)
        v_new = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, axis=2)
        new_cache = KVCache(k_new, v_new, cache.index + s)
        if window and s_buf == window:
            # ring buffer: re-order not needed — attend to all valid slots
            valid = jnp.minimum(cache.index + s, s_buf)
            o = dense_attention(
                q, k_new, v_new, causal=False, window=None,
                q_offset=0, kv_valid_len=valid, scale=None,
            )
        else:
            o = dense_attention(
                q, k_new, v_new, causal=True, window=window,
                q_offset=cache.index, kv_valid_len=cache.index + s, scale=None,
            )
    else:
        o = attention_impl(
            q, k, v, causal=not cfg.is_encoder, window=cfg.sliding_window,
            q_offset=0, impl=impl, segments=segments,
        )
        if return_kv:
            new_cache = (k, v)
    o = jnp.moveaxis(o, 1, 2).reshape(b, s, hq * hd)
    out = fault_linear(o, p["wo"], ctx)
    # steer the partitioner to reduce-scatter (not all-reduce + slice) the
    # TP partial sums straight into the carry layout
    out = shard_activation(out, ("batch", "seq_carry", "embed"))
    return out, new_cache


# NOTE on the SWA ring buffer: attention over the ring ignores token order
# because softmax is permutation-invariant given correct masking; with a
# full ring every slot is a valid in-window key. RoPE is applied before
# caching, so positional geometry is preserved. During the first ``window``
# tokens the kv_valid_len mask hides unwritten slots.


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_block(p: dict, x: Array, cfg, ctx: FaultContext) -> Array:
    if cfg.activation == "swiglu":
        g = fault_linear(x, p["wg"], ctx)
        u = fault_linear(x, p["wu"], ctx)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(fault_linear(x, p["wi"], ctx))
    h = shard_activation(h, ("batch", None, "mlp"))
    out = fault_linear(h, p["wd"], ctx)
    return shard_activation(out, ("batch", "seq_carry", "embed"))
