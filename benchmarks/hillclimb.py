"""SPerf hillclimb driver: lower+compile named variants of one dry-run cell
and compare the three roofline terms (requires the 512-device flag, so run
via the CLI below, not inside pytest).

    PYTHONPATH=src:. python -m benchmarks.hillclimb --cell llama3_405b:train_4k \
        --variants baseline,nomask,per_step ...
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import time

# variant name -> (fault_mode, policy overrides, extra)
VARIANTS = {
    "baseline": dict(fault_mode="fap", overrides={}),
    "nomask": dict(fault_mode="none", overrides={}),
    "per_step": dict(fault_mode="fap", overrides=dict(fault_apply="per_step")),
    "remat_dots": dict(fault_mode="fap", overrides=dict(remat="dots")),
    "remat_none": dict(fault_mode="fap", overrides=dict(remat="none")),
    "no_seqshard": dict(fault_mode="fap", overrides=dict(seq_shard=False)),
    "seqshard": dict(fault_mode="fap", overrides=dict(seq_shard=True)),
    "mb_half": dict(fault_mode="fap", overrides=None, mb_scale=0.5),
    "mb_quarter": dict(fault_mode="fap", overrides=None, mb_scale=0.25),
    "moe_scatter": dict(fault_mode="fap", moe_impl="scatter", overrides={}),
    "per_step+dots": dict(
        fault_mode="fap", overrides=dict(fault_apply="per_step", remat="dots")
    ),
    "per_step+scatter": dict(
        fault_mode="fap", moe_impl="scatter",
        overrides=dict(fault_apply="per_step"),
    ),
    "per_step+dots+mbhalf": dict(
        fault_mode="fap",
        overrides=dict(fault_apply="per_step", remat="dots"), mb_scale=0.5,
    ),
    "remat_dots_mb_quarter": dict(
        fault_mode="fap", overrides=dict(remat="dots"), mb_scale=0.25,
    ),
    "scatter+mbhalf": dict(
        fault_mode="fap", moe_impl="scatter",
        overrides=dict(fault_apply="per_step"), mb_scale=0.5,
    ),
    # attention variants (smollm/hubert-class cells)
    "attn_mixed": dict(fault_mode="fap", overrides=dict(attn_impl="blockwise_mx")),
    "attn_mixed_unroll": dict(
        fault_mode="fap", overrides=dict(attn_impl="blockwise_mx_unroll")
    ),
    "attn_seqshard": dict(
        fault_mode="fap",
        overrides=dict(attn_impl="blockwise_mx_unroll", seq_rule=True),
    ),
    "moe_slotshard": dict(
        fault_mode="fap", moe_impl="scatter",
        overrides=dict(fault_apply="per_step", moe_slot_shard=True),
    ),
    "moe_slotshard_mbhalf": dict(
        fault_mode="fap", moe_impl="scatter",
        overrides=dict(fault_apply="per_step", moe_slot_shard=True), mb_scale=0.5,
    ),
    "attn_unroll_dots_mbq": dict(
        fault_mode="fap",
        overrides=dict(attn_impl="blockwise_mx_unroll", fault_apply="per_step",
                       remat="dots"),
        mb_scale=0.25,
    ),
    "attn_all": dict(
        fault_mode="fap",
        overrides=dict(
            attn_impl="blockwise_mx_unroll", seq_rule=True,
            fault_apply="per_step", remat="dots",
        ),
    ),
}


def run_variant(arch, shape, name, spec, out_dir):
    from repro.configs import SHAPES, get_arch
    from repro.launch.dryrun_lib import run_cell
    from repro.launch.policy import launch_policy

    overrides = spec.get("overrides") or {}
    if spec.get("mb_scale"):
        pol = launch_policy(get_arch(arch), SHAPES[shape])
        overrides = dict(overrides or {},
                         microbatches=max(1, int(pol.microbatches * spec["mb_scale"])))
    t0 = time.time()
    info = run_cell(
        arch, shape,
        fault_mode=spec.get("fault_mode", "fap"),
        moe_impl=spec.get("moe_impl", "einsum"),
        overrides=overrides or None,
        out_dir=None,
    )
    info["variant"] = name
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(info, f, indent=1, default=str)
    return info


def describe(info):
    # hardware terms come from the shared constants in repro.tune.roofline
    from repro.tune.roofline import HBM_BW as HBM
    from repro.tune.roofline import ICI_BW as ICI
    from repro.tune.roofline import PEAK_FLOPS as PEAK

    if info.get("status") != "ok":
        return f"FAILED: {info.get('error')}"
    hc = info.get("hlo_cost", {})
    c = hc.get("flops", 0) / PEAK
    m = hc.get("bytes", 0) / HBM
    n = hc.get("collective_bytes", 0) / ICI
    dom = max((c, "compute"), (m, "memory"), (n, "collective"))[1]
    return (
        f"compute={c:9.3f}s memory={m:9.3f}s coll={n:9.3f}s  bound={max(c,m,n):9.3f}s "
        f"dominant={dom}"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variants", required=True)
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    out_dir = os.path.join(args.out, f"{arch}__{shape}")
    for name in args.variants.split(","):
        spec = VARIANTS[name]
        t0 = time.time()
        info = run_variant(arch, shape, name, spec, out_dir)
        print(f"{name:18s} {describe(info)}  [{time.time()-t0:.0f}s]", flush=True)


if __name__ == "__main__":
    main()
