"""Roofline analysis over the dry-run artifacts (deliverable g).

Three terms per (arch x shape) cell on the single-pod mesh, all in seconds
per step, from the compiled dry-run's per-device statistics:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_wire_bytes_per_device / ICI_BW

Plus MODEL_FLOPS (6ND train / 2ND forward) and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs, which exposes remat recompute, masking overhead,
causal-waste and dispatch overhead. The dominant term is the bottleneck the
SPerf loop iterates on; roofline_fraction = ideal_time / max(terms).
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

from repro.configs import SHAPES, get_arch

# TPU v5e hardware constants (per chip) — single source of truth in
# repro.tune.roofline, shared with the kernel autotuner's per-winner
# achieved-vs-roofline fractions
from repro.tune.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

CHIPS = {"pod1": 256, "pod2": 512}


@dataclass
class CellRoofline:
    arch: str
    shape: str
    kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_dev: float
    hlo_flops_per_dev: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def ideal_s(self) -> float:
        return self.model_flops_per_dev / PEAK_FLOPS

    @property
    def roofline_fraction(self) -> float:
        return self.ideal_s / self.bound_time if self.bound_time > 0 else 0.0

    @property
    def useful_compute_ratio(self) -> float:
        return (
            self.model_flops_per_dev / self.hlo_flops_per_dev
            if self.hlo_flops_per_dev
            else 0.0
        )


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    """6ND for training, 2ND for forward passes; MoE counts active params;
    decode processes 1 token per sequence."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / chips


def load_cell(path: str):
    info = json.load(open(path))
    if info.get("status") != "ok":
        return None
    tag = "pod2" if info.get("multi_pod") else "pod1"
    chips = CHIPS[tag]
    hc = info.get("hlo_cost") or {}
    ca = info.get("cost_analysis", {})
    # loop-aware HLO walk (repro.launch.hlo_cost); entry-level XLA numbers
    # as fallback (undercount while bodies)
    flops = float(hc.get("flops") or ca.get("flops", 0.0))
    byts = float(hc.get("bytes") or ca.get("bytes accessed", 0.0))
    coll = float(
        hc.get("collective_bytes")
        or info.get("collectives", {}).get("total_bytes", 0.0)
    )
    return CellRoofline(
        arch=info["arch"],
        shape=info["shape"],
        kind=info.get("kind", SHAPES[info["shape"]].kind),
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll / ICI_BW,
        model_flops_per_dev=model_flops_per_device(info["arch"], info["shape"], chips),
        hlo_flops_per_dev=flops,
    ), info


def analyze(dryrun_dir: str = "experiments/dryrun", tag: str = "pod1"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{tag}.json"))):
        got = load_cell(path)
        if got is None:
            continue
        cell, info = got
        rows.append(cell)
    return rows


def table(rows) -> str:
    hdr = (
        f"{'arch':28s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
        f"{'collect_s':>10s} {'dominant':>10s} {'roofline%':>9s} {'useful%':>8s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:28s} {r.shape:12s} {r.compute_s:10.4g} {r.memory_s:10.4g} "
            f"{r.collective_s:10.4g} {r.dominant:>10s} "
            f"{100 * r.roofline_fraction:8.1f}% {100 * r.useful_compute_ratio:7.1f}%"
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="pod1")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    rows = analyze(args.dir, args.tag)
    print(table(rows))
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(
                "arch,shape,kind,compute_s,memory_s,collective_s,dominant,"
                "roofline_fraction,useful_compute_ratio,model_flops_dev,hlo_flops_dev\n"
            )
            for r in rows:
                f.write(
                    f"{r.arch},{r.shape},{r.kind},{r.compute_s},{r.memory_s},"
                    f"{r.collective_s},{r.dominant},{r.roofline_fraction},"
                    f"{r.useful_compute_ratio},{r.model_flops_per_dev},{r.hlo_flops_per_dev}\n"
                )
        print(f"\nwrote {args.csv}")


if __name__ == "__main__":
    main()
