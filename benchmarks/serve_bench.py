"""Serving harness: static batching vs continuous batching on a skewed trace.

The static engine (``ServeEngine``) drains FCFS batches of ``num_slots``
requests to the LONGEST request's horizon — a request that finishes at
token 5 burns a dispatch per token until its batchmates finish, and every
sequence holds a dense KV buffer for the whole batch. The continuous engine
(``ContinuousBatchingEngine``) retires each request at its own budget and
frees its pages immediately, so a waiting request refills the slot
mid-flight.

Both engines serve the SAME skewed-generation-length trace with the same
greedy math, and the harness verifies on the way that per-request tokens
are identical — the savings are only real if the outputs are unchanged.
The run FAILS (exit 1) unless continuous batching strictly reduces BOTH
total decode dispatches and peak resident KV bytes.

``--fleet`` adds the sharded tier: N chips' independent ragged streams
through ``ShardedFleetServeEngine`` (shard_map over the pop mesh — force
host devices via XLA_FLAGS, as the CI serve job does), re-verifying that
per-chip outputs match per-chip continuous engines and that fused fleet
dispatches stay at busiest-chip scale rather than fleet-sum scale.

``--heavy-traffic`` adds the production-shaped admission benchmark: a
Poisson-arrival, Zipfian-prompt-length request stream served through the
continuous engine — once UNBUCKETED (exact-length prefill: one compiled
program per distinct prompt length, the `RCP001` hazard) and once through
the bucketed/packed/chunked planner with AOT warmup. Both runs share one
BOUNDED page pool (admission backpressure via ``PageAllocator.can_alloc``
— queue-wait is reported alongside TTFT). The run FAILS unless the
bucketed run's greedy tokens match the unbucketed run's (and a sampled
subset matches per-request ``ServeEngine``), its prefill program count is
O(|buckets|) and equals the planner-census prediction, zero jit compiles
happen after warmup, and its p99 wall-clock TTFT beats the unbucketed run.

Every instrumented run carries a ``repro.obs`` :class:`Recorder`: the
TTFT / queue-wait / TPOT percentiles in the report come from its
histograms (the same aggregates production would scrape), not from ad-hoc
arrays. ``--heavy-traffic`` additionally serves the bucketed trace
recorder-OFF and gates the observability overhead: recorder-on throughput
must stay within ``OBS_OVERHEAD_FLOOR`` of recorder-off (one re-run is
allowed to damp wall-clock flake) and the sampled tokens must be BITWISE
identical — instrumentation is host-side only and may not touch the math.
``--trace-out FILE`` exports the recorded spans (serve + fleet) as a
schema-validated Chrome trace viewable in https://ui.perfetto.dev.

``--inject-fault`` adds the online fault-DETECTION benchmark (ROADMAP
item 2): a fleet serves with the ABFT checksum-probe / health-scoring /
alert stack on, one chip's silicon changes mid-serve under the engine, and
the run FAILS unless the victim chip is detected within a bounded number
of decode dispatches with a correctly localized fault delta, zero false
positives anywhere else (including a probed control run with no
injection), a fired detection alert in the trace, and bitwise-unchanged
tokens on every healthy chip. The recorder-on heavy-traffic arm also
carries probes, so the overhead/parity gates cover the detection stack.
``--health-out FILE`` writes the per-chip health + alert summary JSON.

Output is JSON (tokens/sec, time-to-first-token in dispatches, slot
utilization, resident KV bytes) so CI can parse it; ``--smoke`` shrinks the
trace to CI scale. ``--out`` with no value writes the canonical in-tree
snapshot ``benchmarks/BENCH_serve.json``.

Usage:
    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--fleet]
        [--heavy-traffic] [--inject-fault] [--health-out FILE]
        [--trace-out FILE] [--out [FILE]]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

CANONICAL_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_serve.json")

# recorder-on throughput must stay within this fraction of recorder-off
OBS_OVERHEAD_FLOOR = 0.95


def _obs_percentiles(recorder, wall: float) -> dict:
    """Latency percentiles read off the recorder's histograms — the single
    computation path the report and production scrapes share."""
    m = recorder.summary()["metrics"]

    def pct(name, q):
        h = m.get(name)
        return h[f"p{q}"] if h else None

    return dict(
        ttft_wall_p50_s=pct("serve.ttft_wall_s", 50),
        ttft_wall_p99_s=pct("serve.ttft_wall_s", 99),
        queue_wait_p50_steps=pct("serve.queue_wait_steps", 50),
        queue_wait_p99_steps=pct("serve.queue_wait_steps", 99),
        tpot_p50_s=pct("serve.tpot_s", 50),
        tpot_p99_s=pct("serve.tpot_s", 99),
        obs=dict(
            events=recorder.summary()["events"],
            events_dropped=recorder.events.dropped,
            self_time_s=recorder.self_time_s,
            self_time_fraction=recorder.self_time_s / wall if wall else 0.0,
        ),
    )


def _trace_complete(recorder, rids: set, *, chunked_traffic: bool) -> bool:
    """Every retired request must carry its full lifecycle in the trace:
    an admit span (packed/bucketed) or chunk spans (chunked), a decode
    span and a retire instant — plus page-pool counter samples."""
    evs = recorder.event_list()
    by = lambda n: {e.args["rid"] for e in evs if e.name == n and e.args}  # noqa: E731
    admit, chunk, decode, retire = by("admit"), by("chunk"), by("decode"), by("retire")
    pages_sampled = any(
        e.kind == "sample" and e.name.endswith("free_pages") for e in evs
    )
    return (
        decode == rids
        and retire == rids
        and (admit | chunk) == rids
        and bool(chunk) == chunked_traffic
        and pages_sampled
    )


def build_trace(cfg, *, smoke: bool):
    """Skewed-length request trace: rectangular prompts (so the static
    engine can batch them at all), budgets spanning ~10x."""
    import jax
    import numpy as np

    from repro.serve import Request

    # long/short interleaved (the arrival pattern FCFS batching suffers on:
    # every static batch inherits its longest member's horizon)
    budgets = [4, 24, 4, 12, 6, 16, 6, 8] if smoke else [
        4, 64, 4, 24, 6, 32, 8, 48, 6, 12, 8, 16, 12, 4,
    ]
    plen = 8
    key = jax.random.PRNGKey(42)
    reqs = []
    for i, b in enumerate(budgets):
        toks = np.asarray(
            jax.random.randint(jax.random.fold_in(key, i), (plen,), 0, cfg.vocab_size)
        )
        reqs.append(Request(i, toks, max_new_tokens=b))
    return reqs, plen


def run_static(cfg, params, trace, plen, *, num_slots, page_size):
    """FCFS static batching: batches of ``num_slots``, each run to its
    longest member's horizon, per-request tokens truncated to own budget."""
    import jax.numpy as jnp
    import numpy as np

    from repro.serve import ServeEngine, dense_kv_bytes

    eng = ServeEngine(cfg, params, max_len=None, page_size=page_size)
    compiled_fns = (eng._prefill_len, eng._sample_decode)
    outputs = {}
    dispatches = 0
    peak_bytes = 0
    byte_steps = 0
    emitted = 0
    wasted = 0  # slot-steps burned past a request's own budget
    ttft = {}
    t0 = time.time()
    for lo in range(0, len(trace), num_slots):
        batch = trace[lo : lo + num_slots]
        horizon = max(r.max_new_tokens for r in batch)
        prompts = jnp.stack([jnp.asarray(r.tokens) for r in batch])
        out = eng.generate(prompts, max_new_tokens=horizon)
        cache_len = eng.cache_len_for(plen, horizon)
        batch_bytes = dense_kv_bytes(cfg, len(batch), cache_len)
        peak_bytes = max(peak_bytes, batch_bytes)
        byte_steps += horizon * batch_bytes
        for j, r in enumerate(batch):
            outputs[r.rid] = np.asarray(out.tokens[j, plen : plen + r.max_new_tokens])
            ttft[r.rid] = dispatches + 1
            emitted += r.max_new_tokens
            wasted += horizon - r.max_new_tokens
        dispatches += horizon
    wall = time.time() - t0
    return outputs, dict(
        decode_dispatches=dispatches,
        emitted_tokens=emitted,
        wasted_slot_steps=wasted,
        peak_resident_kv_bytes=peak_bytes,
        kv_byte_steps=byte_steps,
        mean_ttft_dispatches=float(np.mean(list(ttft.values()))),
        # real compile count over the run: the jit caches of the engine's
        # prefill + fused decode (the recompile census predicts these)
        compiles=sum(f._cache_size() for f in compiled_fns),
        wall_s=wall,
        tokens_per_s=emitted / wall if wall else float("inf"),
    )


def run_continuous(cfg, params, trace, *, num_slots, page_size, num_pages):
    import numpy as np

    from repro.obs import Recorder
    from repro.serve import ContinuousBatchingEngine

    rec = Recorder()
    eng = ContinuousBatchingEngine(
        cfg, params, num_slots=num_slots, page_size=page_size,
        num_pages=num_pages, recorder=rec,
    )
    t0 = time.time()
    outs, stats = eng.serve(trace)
    wall = time.time() - t0
    d = stats.as_dict()
    d.update(
        mean_ttft_dispatches=float(np.mean([o.ttft for o in outs.values()])),
        compiles=eng.compile_counts()["total"],
        wall_s=wall,
        tokens_per_s=stats.emitted_tokens / wall if wall else float("inf"),
        **_obs_percentiles(rec, wall),
    )
    return {r: o.tokens for r, o in outs.items()}, d, rec


def run_fleet(cfg, params, trace, *, chips, num_slots, page_size, num_pages):
    """Sharded ragged fleet serving vs per-chip continuous engines."""
    import numpy as np

    from repro.core import from_fault_map, healthy, random_fault_map
    from repro.fleet import ShardedFleetServeEngine
    from repro.obs import Recorder
    from repro.serve import ContinuousBatchingEngine, Request

    ctxs = [healthy()] + [
        from_fault_map(random_fault_map(c, cfg.array_rows, cfg.array_cols, 0.1 + 0.05 * c))
        for c in range(1, chips)
    ]
    # ragged: chip c serves a rotated slice of the trace (different budgets)
    streams = []
    for c in range(chips):
        rot = trace[c:] + trace[:c]
        streams.append([
            Request(r.rid, r.tokens, r.max_new_tokens, arrival=(i % 3))
            for i, r in enumerate(rot[: max(3, len(trace) // 2)])
        ])
    rec = Recorder()
    eng = ShardedFleetServeEngine(
        cfg, [params] * chips, ctxs,
        num_slots=num_slots, page_size=page_size, num_pages=num_pages,
        recorder=rec,
    )
    t0 = time.time()
    outs, stats = eng.serve(streams)
    wall = time.time() - t0
    pinned = True
    per_chip_dispatches = 0
    for c in range(chips):
        ref_eng = ContinuousBatchingEngine(
            cfg, params, ctxs[c],
            num_slots=num_slots, page_size=page_size, num_pages=num_pages,
        )
        ref, ref_stats = ref_eng.serve(streams[c])
        per_chip_dispatches += ref_stats.decode_dispatches
        for rid in ref:
            if not np.array_equal(outs[c][rid].tokens, ref[rid].tokens):
                pinned = False
    d = stats.as_dict()
    d.update(
        chips=chips,
        mesh_extent=int(eng.mesh.shape[eng.axis_name]),
        pinned_vs_per_chip_engines=pinned,
        per_chip_engine_dispatches_total=per_chip_dispatches,
        fused_dispatch_amortization=(
            per_chip_dispatches / stats.decode_dispatches
            if stats.decode_dispatches else float("inf")
        ),
        wall_s=wall,
        **_obs_percentiles(rec, wall),
    )
    # per-chip track census: Perfetto should draw one lane per chip slot
    d["obs"]["chip_tracks"] = sorted(
        {e.track for e in rec.event_list() if e.track.startswith("chip")}
    )
    return d, rec


def build_heavy_trace(cfg, *, smoke: bool, buckets):
    """Poisson arrivals, Zipfian prompt lengths: many short prompts, a heavy
    tail of distinct lengths, and a slice past the top bucket so the
    chunked path carries real traffic."""
    import numpy as np

    from repro.serve import Request

    rng = np.random.default_rng(1234)
    n = 48 if smoke else 400
    top = buckets[-1]
    lens = np.clip(rng.zipf(1.3, size=n), 1, top + top // 2).astype(int)
    # guarantee chunked traffic regardless of the zipf draw
    lens[:: max(1, n // 6)] = rng.integers(top + 1, top + top // 2, size=len(lens[:: max(1, n // 6)]))
    budgets = rng.integers(4, 13 if smoke else 33, size=n)
    arrivals = np.cumsum(rng.poisson(1.0, size=n))
    reqs = []
    for i in range(n):
        toks = np.asarray(rng.integers(0, cfg.vocab_size, size=int(lens[i])))
        reqs.append(Request(i, toks, max_new_tokens=int(budgets[i]),
                            arrival=int(arrivals[i])))
    return reqs


def run_heavy(cfg, params, trace, *, num_slots, page_size, num_pages,
              max_pages_per_seq, buckets, warmup, recorder=None,
              probe_every=None, alert_rules=None):
    """One heavy-traffic serve: bucketed planner when ``buckets`` is set
    (AOT-warmed when ``warmup``), exact-length admission when None. Latency
    percentiles are recorder-derived; a ``recorder=None`` run reports raw
    throughput only (the overhead baseline). ``probe_every`` turns the ABFT
    probe/health/alert stack on — the zero-token-impact gate then covers
    probes too."""
    from repro.serve import ContinuousBatchingEngine

    eng = ContinuousBatchingEngine(
        cfg, params, num_slots=num_slots, page_size=page_size,
        num_pages=num_pages, max_pages_per_seq=max_pages_per_seq,
        prefill_buckets=buckets, recorder=recorder,
        probe_every=probe_every, alert_rules=alert_rules,
    )
    warm_s = 0.0
    if warmup:
        t0 = time.time()
        eng.warmup()
        warm_s = time.time() - t0
    t0 = time.time()
    outs, stats = eng.serve(trace)
    wall = time.time() - t0
    d = stats.as_dict()
    d.update(
        warmup_s=warm_s,
        wall_s=wall,
        tokens_per_s=stats.emitted_tokens / wall if wall else float("inf"),
        compiles=eng.compile_counts(),
    )
    if recorder is not None:
        d.update(_obs_percentiles(recorder, wall))
    return {r: o.tokens for r, o in outs.items()}, d, eng


def run_heavy_traffic(cfg, params, *, smoke, num_slots, page_size):
    """The bucketed-vs-unbucketed admission benchmark (see module doc)."""
    import numpy as np

    from repro.obs import (
        HEALTHY,
        Recorder,
        chrome_trace,
        detection_rules,
        validate_chrome_trace,
    )
    from repro.serve import ServeEngine, pages_needed
    from repro.serve.bucketing import DEFAULT_PREFILL_BUCKETS, bucket_of

    buckets = DEFAULT_PREFILL_BUCKETS
    probe_every = 8  # the recorder-on arm carries the full detection stack
    trace = build_heavy_trace(cfg, smoke=smoke, buckets=buckets)
    # BOUNDED pool: room for num_slots maximal requests, NOT the whole
    # trace at once — admission waits on PageAllocator.can_alloc and the
    # queue-wait percentiles below measure that backpressure
    max_pages_per_seq = max(
        pages_needed(len(r.tokens) + r.max_new_tokens, page_size) for r in trace
    )
    num_pages = 1 + num_slots * max_pages_per_seq
    kw = dict(num_slots=num_slots, page_size=page_size, num_pages=num_pages,
              max_pages_per_seq=max_pages_per_seq)

    un_rec = Recorder()
    un_out, un, _ = run_heavy(cfg, params, trace, buckets=None, warmup=False,
                              recorder=un_rec, **kw)

    # observability overhead gate: bucketed trace recorder-OFF vs recorder-ON.
    # Throughput on a shared CI box flakes (single-arm wall clock swings
    # ~10% run to run), so a below-floor attempt earns re-runs of both
    # arms (best ratio kept, up to three attempts); tokens must be bitwise
    # identical always.
    best = None
    attempts = 0
    for _ in range(3):
        attempts += 1
        # BOTH arms carry the probe stack so the ratio isolates recorder
        # cost (the PR-8 overhead budget); probe zero-token-impact is
        # separately pinned by heavy_tokens_match_unbucketed — the
        # unbucketed arm runs probe-free and must agree bitwise
        off_out, off, _ = run_heavy(cfg, params, trace, buckets=buckets,
                                    warmup=True, recorder=None,
                                    probe_every=probe_every, **kw)
        rec = Recorder()
        bk_out, bk, eng = run_heavy(cfg, params, trace, buckets=buckets,
                                    warmup=True, recorder=rec,
                                    probe_every=probe_every,
                                    alert_rules=detection_rules(), **kw)
        ratio = (bk["tokens_per_s"] / off["tokens_per_s"]
                 if off["tokens_per_s"] else 0.0)
        if best is None or ratio > best[0]:
            best = (ratio, off_out, off, bk_out, bk, eng, rec)
        if ratio >= OBS_OVERHEAD_FLOOR:
            break
    ratio, off_out, off, bk_out, bk, eng, rec = best

    obs_parity = set(off_out) == set(bk_out) and all(
        np.array_equal(off_out[r], bk_out[r]) for r in off_out
    )
    trace_obj = chrome_trace(rec)  # the bucketed production-path recording
    trace_problems = validate_chrome_trace(trace_obj)
    chunked_rids = {r.rid for r in trace
                    if bucket_of(len(r.tokens), buckets) is None}

    # planner census: the CLOSED program set — the same signature model the
    # static analyzer's recompile pass uses for this entry. Packing may
    # merge prompts into a larger bucket than any one of them needs, so the
    # census is the full ladder, not the per-request buckets.
    predicted = {("prefill_admit", b) for b in buckets}
    predicted |= {("prefill_chunk", eng.chunk_size), ("decode",)}
    chunked_traffic = any(bucket_of(len(r.tokens), buckets) is None for r in trace)
    tokens_match = set(un_out) == set(bk_out) and all(
        np.array_equal(un_out[r], bk_out[r]) for r in un_out
    )
    # per-request ServeEngine reference on a length-spread sample (the full
    # trace would re-run the model once per request)
    sample = sorted(trace, key=lambda r: len(r.tokens))
    sample = sample[:: max(1, len(sample) // 8)]
    ref = ServeEngine(cfg, params, max_len=None, page_size=page_size)
    serve_match = True
    for r in sample:
        import jax.numpy as jnp

        res = ref.generate(jnp.asarray(r.tokens)[None],
                           max_new_tokens=r.max_new_tokens)
        want = np.asarray(res.tokens[0, len(r.tokens):])
        serve_match &= np.array_equal(want, bk_out[r.rid])
    checks = dict(
        heavy_tokens_match_unbucketed=bool(tokens_match),
        heavy_tokens_match_serve_engine=bool(serve_match),
        # O(|buckets|): the whole run compiles at most one program per
        # bucket + the chunk program + decode, vs one per distinct length
        heavy_compile_bounded=bk["compiles"]["total"] <= len(buckets) + 2,
        heavy_zero_jit_after_warmup=bk["compiles"]["jit_fallback"] == 0,
        # measured compiles land exactly on the census set, and every
        # program actually dispatched is one the census predicts
        heavy_census_match=(
            bk["compiles"]["total"] == len(predicted)
            and set(eng.used_programs) <= predicted
            and ("decode",) in eng.used_programs
            and (("prefill_chunk", eng.chunk_size) in eng.used_programs)
            == chunked_traffic
        ),
        heavy_p99_ttft_reduced=bk["ttft_wall_p99_s"] < un["ttft_wall_p99_s"],
        # observability gates: host-side hooks change zero tokens, cost
        # under (1 - OBS_OVERHEAD_FLOOR) of throughput, and the exported
        # trace is schema-valid and lifecycle-complete
        heavy_obs_zero_token_impact=bool(obs_parity),
        heavy_obs_overhead_ok=ratio >= OBS_OVERHEAD_FLOOR,
        heavy_trace_valid=not trace_problems,
        heavy_trace_complete=_trace_complete(
            rec, set(bk_out), chunked_traffic=bool(chunked_rids)
        ),
        # detection gates on a HEALTHY run: the probe/health/alert stack
        # rode along the whole recorder-on serve and must stay silent —
        # golden-snapshot probing makes false positives a structural bug
        heavy_probe_zero_false_positives=(
            eng.health is not None
            and eng.health.detections == 0
            and eng.health.state(0) == HEALTHY
        ),
        heavy_alerts_quiet=eng.alerts is not None and eng.alerts.fired_total == 0,
    )
    report = dict(
        requests=len(trace),
        distinct_prompt_lens=len({len(r.tokens) for r in trace}),
        buckets=list(buckets),
        chunk_size=eng.chunk_size,
        num_pages=num_pages,
        max_pages_per_seq=max_pages_per_seq,
        serve_engine_sample=len(sample),
        predicted_programs=sorted(map(str, predicted)),
        used_programs=sorted(map(str, eng.used_programs)),
        unbucketed=un,
        bucketed=bk,
        overhead=dict(
            floor=OBS_OVERHEAD_FLOOR,
            attempts=attempts,
            tokens_per_s_recorder_off=off["tokens_per_s"],
            tokens_per_s_recorder_on=bk["tokens_per_s"],
            throughput_ratio=ratio,
            recorder_self_time_fraction=bk["obs"]["self_time_fraction"],
            trace_problems=trace_problems,
        ),
        detection=dict(
            probe_every=probe_every,
            probe_dispatches=bk.get("probe_dispatches", 0),
            health=eng.health.summary() if eng.health else None,
            alerts=eng.alerts.summary() if eng.alerts else None,
        ),
        checks=checks,
    )
    return report, checks, rec


def run_inject_fault(cfg, params, *, smoke, chips, num_slots, page_size):
    """Mid-serve fault-injection detection benchmark (ROADMAP item 2).

    A fleet of ``chips`` chips — every one constructed with an ACTIVE
    (possibly zero-fault) FaultMap context so the stacked ok mask is a live
    program input — serves ragged streams with the ABFT probe / health /
    alert stack on. Mid-serve, one chip's silicon changes under the engine
    (``set_silicon``: new faults appear beyond the believed map). Gates:

    * the victim chip leaves ``healthy`` within a bounded number of decode
      dispatches of the injection (probe cadence x debounce);
    * the reconstructed fault delta is nonempty and a subset of the TRUE
      newly-faulty PEs (syndrome localization, not just divergence);
    * no other chip transitions (zero cross-chip false positives) and a
      control run without injection detects nothing at all;
    * the detection alert fires into the recorder (Perfetto lane);
    * every non-victim chip's tokens are bitwise identical to the control
      run — detection rides along without touching healthy chips' math.
    """
    import numpy as np

    from repro.core import from_fault_map, random_fault_map
    from repro.core.faults import FaultMap
    from repro.fleet import ShardedFleetServeEngine
    from repro.obs import HEALTHY, Recorder, detection_rules
    from repro.obs.health import HealthConfig
    from repro.serve import Request

    R, C = cfg.array_rows, cfg.array_cols
    victim = 1 if chips > 1 else 0
    probe_every = 4
    hc = HealthConfig()
    # believed silicon at engine build: chip 0 pristine, the rest lightly
    # faulty (their FAP masks absorb those) — all ACTIVE contexts
    base_maps = [FaultMap(faulty=np.zeros((R, C), bool))] + [
        random_fault_map(c, R, C, 0.04 + 0.02 * c) for c in range(1, chips)
    ]
    extra = random_fault_map(999, R, C, 0.05)
    new_map = base_maps[victim].merge(extra)
    true_delta = new_map.faulty & ~base_maps[victim].faulty
    assert true_delta.any(), "injection must add at least one new fault"

    trace, _ = build_trace(cfg, smoke=smoke)
    streams = []
    for c in range(chips):
        rot = trace[c:] + trace[:c]
        streams.append([
            Request(r.rid, r.tokens, max_new_tokens=max(r.max_new_tokens, 16),
                    arrival=(i % 3))
            for i, r in enumerate(rot[: max(3, len(trace) // 2)])
        ])

    def build(recorder):
        return ShardedFleetServeEngine(
            cfg, [params] * chips, [from_fault_map(m) for m in base_maps],
            num_slots=num_slots, page_size=page_size,
            num_pages=1 + num_slots * 16,
            recorder=recorder, probe_every=probe_every, health_config=hc,
            alert_rules=detection_rules(),
        )

    # control arm: identical fleet, probes on, nothing injected — the
    # healthy-fleet zero-false-positive gate and the token baseline
    ctl_eng = build(None)
    ctl_outs, _ = ctl_eng.serve([list(s) for s in streams])

    rec = Recorder()
    eng = build(rec)
    inject_clock = probe_every + 2  # after the first probe tick validated
    injected = {}

    def on_step(clock):
        if clock >= inject_clock and not injected:
            injected["at"] = clock
            eng.set_silicon(victim, from_fault_map(new_map))

    t0 = time.time()
    outs, stats = eng.serve([list(s) for s in streams], on_step=on_step)
    wall = time.time() - t0

    detected_at = eng.health.detected_at(victim)
    latency = (detected_at - injected["at"]) if detected_at is not None else None
    # cadence x debounce: one probe tick to first divergence, suspect_after
    # consecutive bad probes to transition, +1 tick of scheduling slack
    latency_bound = probe_every * (hc.suspect_after + 1)
    delta = eng.health.last_delta(victim)
    others_pinned = all(
        np.array_equal(outs[c][rid].tokens, ctl_outs[c][rid].tokens)
        for c in range(chips) if c != victim for rid in ctl_outs[c]
    )
    alert_names = {e.name for e in rec.event_list() if e.kind == "instant"
                   and e.name == "alert"}
    checks = dict(
        inject_detected=eng.health.state(victim) != HEALTHY,
        inject_latency_bounded=latency is not None and latency <= latency_bound,
        inject_localized=(
            delta is not None and bool(delta.any())
            and not bool((delta & ~true_delta).any())
        ),
        inject_no_cross_chip_fp=(
            eng.health.detections == 1
            and all(eng.health.state(c) == HEALTHY
                    for c in range(chips) if c != victim)
        ),
        inject_alert_fired=(
            eng.alerts.fired_total >= 1
            and "detect.new_faults" in eng.alerts.summary()["fired"]
            and bool(alert_names)
        ),
        healthy_fleet_zero_false_positives=(
            ctl_eng.health.detections == 0
            and all(ctl_eng.health.state(c) == HEALTHY for c in range(chips))
            and ctl_eng.alerts.fired_total == 0
        ),
        inject_other_chips_pinned=bool(others_pinned),
    )
    report = dict(
        chips=chips,
        victim=victim,
        probe_every=probe_every,
        injected_at_clock=injected.get("at"),
        detected_at_clock=detected_at,
        detection_latency_dispatches=latency,
        detection_latency_bound=latency_bound,
        true_new_faults=int(true_delta.sum()),
        reconstructed_faults=None if delta is None else int(delta.sum()),
        probe_dispatches=stats.probe_dispatches,
        wall_s=wall,
        health=eng.health.summary(),
        alerts=eng.alerts.summary(),
        checks=checks,
    )
    return report, checks, rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI scale")
    ap.add_argument("--fleet", action="store_true", help="add the sharded fleet tier")
    ap.add_argument("--heavy-traffic", action="store_true",
                    help="add the Poisson/Zipf bucketed-vs-unbucketed "
                         "admission benchmark (bounded page pool)")
    ap.add_argument("--inject-fault", action="store_true",
                    help="add the mid-serve fault-injection detection "
                         "benchmark: one fleet chip's silicon changes under "
                         "the engine; the ABFT probe/health/alert stack must "
                         "detect, localize and alert with zero false "
                         "positives elsewhere")
    ap.add_argument("--health-out", type=str, default=None, metavar="FILE",
                    help="write the per-chip health + alert summary JSON "
                         "(from --inject-fault and/or --heavy-traffic)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--out", type=str, nargs="?", const=CANONICAL_OUT,
                    default=None, metavar="FILE",
                    help=f"write the JSON report (no value: {CANONICAL_OUT})")
    ap.add_argument("--trace-out", type=str, default=None, metavar="FILE",
                    help="write the recorded spans (continuous/heavy serve + "
                         "fleet) as a Chrome trace for Perfetto")
    ap.add_argument(
        "--no-analysis", action="store_true",
        help="skip the static-analyzer section (donated-bytes fraction, "
        "recompile census) of the report",
    )
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_arch, reduce_config
    from repro.models import model as M

    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    trace, plen = build_trace(cfg, smoke=args.smoke)
    num_pages = 1 + sum(  # enough pages for everything at once; paging still wins
        -(-(plen + r.max_new_tokens) // args.page_size) for r in trace
    )

    static_out, static = run_static(
        cfg, params, trace, plen, num_slots=args.slots, page_size=args.page_size
    )
    cont_out, cont, cont_rec = run_continuous(
        cfg, params, trace,
        num_slots=args.slots, page_size=args.page_size, num_pages=num_pages,
    )
    trace_recorders = [cont_rec]  # heavy replaces this serve-proc recording

    tokens_match = set(static_out) == set(cont_out) and all(
        np.array_equal(static_out[r], cont_out[r]) for r in static_out
    )
    checks = dict(
        tokens_match=bool(tokens_match),
        fewer_dispatches=cont["decode_dispatches"] < static["decode_dispatches"],
        less_peak_kv=cont["peak_resident_kv_bytes"] < static["peak_resident_kv_bytes"],
        less_kv_byte_steps=cont["kv_byte_steps"] < static["kv_byte_steps"],
    )
    report = dict(
        arch=cfg.name,
        requests=len(trace),
        prompt_len=plen,
        budgets=[r.max_new_tokens for r in trace],
        num_slots=args.slots,
        page_size=args.page_size,
        static=static,
        continuous=cont,
        checks=checks,
    )
    if not args.no_analysis:
        # static-analyzer metrics ahead of the ROADMAP-1 prefill-bucketing
        # work: the donated-bytes fraction of every loop-carried serve/train
        # operand and the recompile census the measured `compiles` should
        # track (see src/repro/analysis/README.md)
        from repro.analysis import analyze_stack

        ana = analyze_stack("smollm-135m", passes=("donation", "recompile"))
        don = ana.passes["donation"]
        report["analysis"] = dict(
            donated_fraction=don["donated_fraction"],
            undonated_carried_bytes={
                name: e["undonated_carried_bytes"]
                for name, e in don["entries"].items()
            },
            trace_signatures={
                name: e["signatures"]
                for name, e in ana.passes["recompile"].items()
            },
            findings=len(ana.findings),
        )
        checks["all_carried_bytes_donated"] = don["donated_fraction"] == 1.0
    if args.fleet:
        report["fleet"], fleet_rec = run_fleet(
            cfg, params, trace, chips=args.chips,
            num_slots=args.slots, page_size=args.page_size, num_pages=num_pages,
        )
        checks["fleet_pinned"] = report["fleet"]["pinned_vs_per_chip_engines"]
        trace_recorders.append(fleet_rec)  # distinct proc: own Perfetto lane
    if args.heavy_traffic:
        heavy, heavy_checks, heavy_rec = run_heavy_traffic(
            cfg, params, smoke=args.smoke,
            num_slots=args.slots, page_size=args.page_size,
        )
        report["heavy_traffic"] = heavy
        checks.update(heavy_checks)
        # the heavy bucketed run is the richer serve-proc recording — it
        # replaces the base continuous one (both record proc="serve")
        trace_recorders[0] = heavy_rec
    if args.inject_fault:
        inject, inject_checks, inject_rec = run_inject_fault(
            cfg, params, smoke=args.smoke, chips=args.chips,
            num_slots=args.slots, page_size=args.page_size,
        )
        report["inject_fault"] = inject
        checks.update(inject_checks)
        trace_recorders.append(inject_rec)  # carries the alert swimlanes
    if args.health_out:
        health = {}
        if "inject_fault" in report:
            health["inject_fault"] = dict(
                health=report["inject_fault"]["health"],
                alerts=report["inject_fault"]["alerts"],
                detection_latency_dispatches=report["inject_fault"][
                    "detection_latency_dispatches"],
            )
        if "heavy_traffic" in report:
            health["heavy_traffic"] = report["heavy_traffic"]["detection"]
        with open(args.health_out, "w") as f:
            json.dump(health, f, indent=2)
        report["health_out"] = args.health_out
    if args.trace_out:
        from repro.obs import validate_chrome_trace, write_chrome_trace

        written = write_chrome_trace(args.trace_out, trace_recorders)
        checks["trace_out_valid"] = not validate_chrome_trace(written)
        report["trace_out"] = dict(
            path=args.trace_out, events=len(written["traceEvents"]),
            recorders=len(trace_recorders),
        )

    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    if not all(checks.values()):
        failed = [k for k, v in checks.items() if not v]
        print(f"FAIL: {failed}", file=sys.stderr)
        return 1
    print(
        f"OK: continuous batching cut dispatches "
        f"{static['decode_dispatches']} -> {cont['decode_dispatches']} and peak "
        f"KV bytes {static['peak_resident_kv_bytes']} -> "
        f"{cont['peak_resident_kv_bytes']} with identical tokens",
        file=sys.stderr,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
