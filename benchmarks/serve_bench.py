"""Serving harness: static batching vs continuous batching on a skewed trace.

The static engine (``ServeEngine``) drains FCFS batches of ``num_slots``
requests to the LONGEST request's horizon — a request that finishes at
token 5 burns a dispatch per token until its batchmates finish, and every
sequence holds a dense KV buffer for the whole batch. The continuous engine
(``ContinuousBatchingEngine``) retires each request at its own budget and
frees its pages immediately, so a waiting request refills the slot
mid-flight.

Both engines serve the SAME skewed-generation-length trace with the same
greedy math, and the harness verifies on the way that per-request tokens
are identical — the savings are only real if the outputs are unchanged.
The run FAILS (exit 1) unless continuous batching strictly reduces BOTH
total decode dispatches and peak resident KV bytes.

``--fleet`` adds the sharded tier: N chips' independent ragged streams
through ``ShardedFleetServeEngine`` (shard_map over the pop mesh — force
host devices via XLA_FLAGS, as the CI serve job does), re-verifying that
per-chip outputs match per-chip continuous engines and that fused fleet
dispatches stay at busiest-chip scale rather than fleet-sum scale.

Output is JSON (tokens/sec, time-to-first-token in dispatches, slot
utilization, resident KV bytes) so CI can parse it; ``--smoke`` shrinks the
trace to CI scale.

Usage:
    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--fleet]
        [--out FILE]
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def build_trace(cfg, *, smoke: bool):
    """Skewed-length request trace: rectangular prompts (so the static
    engine can batch them at all), budgets spanning ~10x."""
    import jax
    import numpy as np

    from repro.serve import Request

    # long/short interleaved (the arrival pattern FCFS batching suffers on:
    # every static batch inherits its longest member's horizon)
    budgets = [4, 24, 4, 12, 6, 16, 6, 8] if smoke else [
        4, 64, 4, 24, 6, 32, 8, 48, 6, 12, 8, 16, 12, 4,
    ]
    plen = 8
    key = jax.random.PRNGKey(42)
    reqs = []
    for i, b in enumerate(budgets):
        toks = np.asarray(
            jax.random.randint(jax.random.fold_in(key, i), (plen,), 0, cfg.vocab_size)
        )
        reqs.append(Request(i, toks, max_new_tokens=b))
    return reqs, plen


def run_static(cfg, params, trace, plen, *, num_slots, page_size):
    """FCFS static batching: batches of ``num_slots``, each run to its
    longest member's horizon, per-request tokens truncated to own budget."""
    import jax.numpy as jnp
    import numpy as np

    from repro.serve import ServeEngine, dense_kv_bytes

    eng = ServeEngine(cfg, params, max_len=None, page_size=page_size)
    compiled_fns = (eng._prefill_len, eng._sample_decode)
    outputs = {}
    dispatches = 0
    peak_bytes = 0
    byte_steps = 0
    emitted = 0
    wasted = 0  # slot-steps burned past a request's own budget
    ttft = {}
    t0 = time.time()
    for lo in range(0, len(trace), num_slots):
        batch = trace[lo : lo + num_slots]
        horizon = max(r.max_new_tokens for r in batch)
        prompts = jnp.stack([jnp.asarray(r.tokens) for r in batch])
        out = eng.generate(prompts, max_new_tokens=horizon)
        cache_len = eng.cache_len_for(plen, horizon)
        batch_bytes = dense_kv_bytes(cfg, len(batch), cache_len)
        peak_bytes = max(peak_bytes, batch_bytes)
        byte_steps += horizon * batch_bytes
        for j, r in enumerate(batch):
            outputs[r.rid] = np.asarray(out.tokens[j, plen : plen + r.max_new_tokens])
            ttft[r.rid] = dispatches + 1
            emitted += r.max_new_tokens
            wasted += horizon - r.max_new_tokens
        dispatches += horizon
    wall = time.time() - t0
    return outputs, dict(
        decode_dispatches=dispatches,
        emitted_tokens=emitted,
        wasted_slot_steps=wasted,
        peak_resident_kv_bytes=peak_bytes,
        kv_byte_steps=byte_steps,
        mean_ttft_dispatches=float(np.mean(list(ttft.values()))),
        # real compile count over the run: the jit caches of the engine's
        # prefill + fused decode (the recompile census predicts these)
        compiles=sum(f._cache_size() for f in compiled_fns),
        wall_s=wall,
        tokens_per_s=emitted / wall if wall else float("inf"),
    )


def run_continuous(cfg, params, trace, *, num_slots, page_size, num_pages):
    import numpy as np

    from repro.serve import ContinuousBatchingEngine

    eng = ContinuousBatchingEngine(
        cfg, params, num_slots=num_slots, page_size=page_size, num_pages=num_pages
    )
    t0 = time.time()
    outs, stats = eng.serve(trace)
    wall = time.time() - t0
    d = stats.as_dict()
    d.update(
        mean_ttft_dispatches=float(np.mean([o.ttft for o in outs.values()])),
        compiles=eng._prefill_admit._cache_size() + eng._sample_decode._cache_size(),
        wall_s=wall,
        tokens_per_s=stats.emitted_tokens / wall if wall else float("inf"),
    )
    return {r: o.tokens for r, o in outs.items()}, d


def run_fleet(cfg, params, trace, *, chips, num_slots, page_size, num_pages):
    """Sharded ragged fleet serving vs per-chip continuous engines."""
    import numpy as np

    from repro.core import from_fault_map, healthy, random_fault_map
    from repro.fleet import ShardedFleetServeEngine
    from repro.serve import ContinuousBatchingEngine, Request

    ctxs = [healthy()] + [
        from_fault_map(random_fault_map(c, cfg.array_rows, cfg.array_cols, 0.1 + 0.05 * c))
        for c in range(1, chips)
    ]
    # ragged: chip c serves a rotated slice of the trace (different budgets)
    streams = []
    for c in range(chips):
        rot = trace[c:] + trace[:c]
        streams.append([
            Request(r.rid, r.tokens, r.max_new_tokens, arrival=(i % 3))
            for i, r in enumerate(rot[: max(3, len(trace) // 2)])
        ])
    eng = ShardedFleetServeEngine(
        cfg, [params] * chips, ctxs,
        num_slots=num_slots, page_size=page_size, num_pages=num_pages,
    )
    t0 = time.time()
    outs, stats = eng.serve(streams)
    wall = time.time() - t0
    pinned = True
    per_chip_dispatches = 0
    for c in range(chips):
        ref_eng = ContinuousBatchingEngine(
            cfg, params, ctxs[c],
            num_slots=num_slots, page_size=page_size, num_pages=num_pages,
        )
        ref, ref_stats = ref_eng.serve(streams[c])
        per_chip_dispatches += ref_stats.decode_dispatches
        for rid in ref:
            if not np.array_equal(outs[c][rid].tokens, ref[rid].tokens):
                pinned = False
    d = stats.as_dict()
    d.update(
        chips=chips,
        mesh_extent=int(eng.mesh.shape[eng.axis_name]),
        pinned_vs_per_chip_engines=pinned,
        per_chip_engine_dispatches_total=per_chip_dispatches,
        fused_dispatch_amortization=(
            per_chip_dispatches / stats.decode_dispatches
            if stats.decode_dispatches else float("inf")
        ),
        wall_s=wall,
    )
    return d


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI scale")
    ap.add_argument("--fleet", action="store_true", help="add the sharded fleet tier")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument(
        "--no-analysis", action="store_true",
        help="skip the static-analyzer section (donated-bytes fraction, "
        "recompile census) of the report",
    )
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_arch, reduce_config
    from repro.models import model as M

    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    trace, plen = build_trace(cfg, smoke=args.smoke)
    num_pages = 1 + sum(  # enough pages for everything at once; paging still wins
        -(-(plen + r.max_new_tokens) // args.page_size) for r in trace
    )

    static_out, static = run_static(
        cfg, params, trace, plen, num_slots=args.slots, page_size=args.page_size
    )
    cont_out, cont = run_continuous(
        cfg, params, trace,
        num_slots=args.slots, page_size=args.page_size, num_pages=num_pages,
    )

    tokens_match = set(static_out) == set(cont_out) and all(
        np.array_equal(static_out[r], cont_out[r]) for r in static_out
    )
    checks = dict(
        tokens_match=bool(tokens_match),
        fewer_dispatches=cont["decode_dispatches"] < static["decode_dispatches"],
        less_peak_kv=cont["peak_resident_kv_bytes"] < static["peak_resident_kv_bytes"],
        less_kv_byte_steps=cont["kv_byte_steps"] < static["kv_byte_steps"],
    )
    report = dict(
        arch=cfg.name,
        requests=len(trace),
        prompt_len=plen,
        budgets=[r.max_new_tokens for r in trace],
        num_slots=args.slots,
        page_size=args.page_size,
        static=static,
        continuous=cont,
        checks=checks,
    )
    if not args.no_analysis:
        # static-analyzer metrics ahead of the ROADMAP-1 prefill-bucketing
        # work: the donated-bytes fraction of every loop-carried serve/train
        # operand and the recompile census the measured `compiles` should
        # track (see src/repro/analysis/README.md)
        from repro.analysis import analyze_stack

        ana = analyze_stack("smollm-135m", passes=("donation", "recompile"))
        don = ana.passes["donation"]
        report["analysis"] = dict(
            donated_fraction=don["donated_fraction"],
            undonated_carried_bytes={
                name: e["undonated_carried_bytes"]
                for name, e in don["entries"].items()
            },
            trace_signatures={
                name: e["signatures"]
                for name, e in ana.passes["recompile"].items()
            },
            findings=len(ana.findings),
        )
        checks["all_carried_bytes_donated"] = don["donated_fraction"] == 1.0
    if args.fleet:
        report["fleet"] = run_fleet(
            cfg, params, trace, chips=args.chips,
            num_slots=args.slots, page_size=args.page_size, num_pages=num_pages,
        )
        checks["fleet_pinned"] = report["fleet"]["pinned_vs_per_chip_engines"]

    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    if not all(checks.values()):
        failed = [k for k, v in checks.items() if not v]
        print(f"FAIL: {failed}", file=sys.stderr)
        return 1
    print(
        f"OK: continuous batching cut dispatches "
        f"{static['decode_dispatches']} -> {cont['decode_dispatches']} and peak "
        f"KV bytes {static['peak_resident_kv_bytes']} -> "
        f"{cont['peak_resident_kv_bytes']} with identical tokens",
        file=sys.stderr,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
