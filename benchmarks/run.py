"""Benchmark harness entry point — one function per paper table plus the
roofline summary. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    import benchmarks.kernel_bench as kb
    import benchmarks.paper_tables as pt

    print("name,us_per_call,derived")
    for fn in pt.ALL + kb.ALL:
        try:
            for name, us, derived in fn():
                print(f'{name},{us:.1f},"{derived}"', flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f'{fn.__name__},-1,"ERROR: {e}"', flush=True)

    # roofline summary (requires dry-run artifacts; skipped gracefully)
    try:
        from benchmarks import roofline

        rows = roofline.analyze("experiments/dryrun", "pod1")
        if rows:
            print()
            print(roofline.table(rows))
    except Exception as e:
        print(f'roofline,-1,"SKIPPED: {e}"')


if __name__ == "__main__":
    main()
