"""Benchmark harness entry point — one function per paper table plus the
roofline summary. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations



def main() -> None:
    import benchmarks.kernel_bench as kb
    import benchmarks.paper_tables as pt

    kb.print_rows(pt.ALL + kb.ALL)

    # roofline summary (requires dry-run artifacts; skipped gracefully)
    try:
        from benchmarks import roofline

        rows = roofline.analyze("experiments/dryrun", "pod1")
        if rows:
            print()
            print(roofline.table(rows))
    except Exception as e:
        print(f'roofline,-1,"SKIPPED: {e}"')


if __name__ == "__main__":
    main()
