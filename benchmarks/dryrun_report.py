"""Render EXPERIMENTS.md SDry-run tables from the dry-run artifacts."""
from __future__ import annotations

import glob
import json
import os


def _fmt_gb(x) -> str:
    return f"{x/1e9:.2f}" if isinstance(x, (int, float)) else "-"


def report(d: str = "experiments/dryrun", tag: str = "pod1") -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(d, f"*__{tag}.json"))):
        info = json.load(open(path))
        if info.get("status") != "ok":
            rows.append(f"| {info['arch']} | {info['shape']} | FAILED {info.get('error','')} |")
            continue
        hc = info.get("hlo_cost", {})
        state_gb = (
            info.get("param_bytes_per_device", 0)
            + info.get("opt_bytes_per_device", 0)
            + info.get("cache_bytes_per_device", 0)
        )
        coll = hc.get("coll_count", {})
        sched = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in sorted(coll.items()))
        rows.append(
            f"| {info['arch']} | {info['shape']} | {_fmt_gb(state_gb)} | "
            f"{hc.get('flops', 0):.2e} | {hc.get('bytes', 0):.2e} | "
            f"{hc.get('collective_bytes', 0):.2e} | {sched} | "
            f"{info.get('compile_seconds', 0):.0f}s |"
        )
    hdr = (
        f"state GB/dev = params+optimizer+KV-cache under the resolved shardings; "
        f"flops/bytes/coll per device per step (loop-aware HLO walk).\n\n"
        "| arch | shape | state GB/dev | FLOPs/dev | HBM bytes/dev | coll bytes/dev | collective schedule (count) | compile |\n"
        "|---|---|---|---|---|---|---|---|\n"
    )
    return hdr + "\n".join(rows)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="pod1")
    args = ap.parse_args()
    print(report(args.dir, args.tag))
