"""Step-1 wall-clock harness: serial reference vs population FAT engine,
plus the fleet-scale ``--sharded`` mode.

Default mode runs the same resilience sweep (rates x repeats, identical
fault-map grid and identical base params) through the serial and population
engines and reports wall-clock, verifying on the way that the two engines
produce the SAME resilience table — the speedup is only real if the math is
unchanged.

``--sharded`` exercises the repro.fleet subsystem instead: the sweep runs
through ``ShardedPopulationEngine`` on growing "pop" meshes (1, 2, 4, ...
devices — forced host CPU devices unless XLA_FLAGS is already set), so the
JSON reports per-device scaling, re-verifies shard_map↔vmap table equality,
and prints the FleetScheduler's ``wasted_steps`` reduction (LPT vs arrival
order) on a deliberately skewed retraining plan — the run fails unless LPT
strictly reduces waste.

``--mesh POPxMODEL`` (e.g. ``--mesh 4x2``) runs the 2-D fleet-mesh mode:
the sweep runs through the sharded engine on a ``("pop", "model")`` mesh,
re-verifies 2-D↔vmap table equality, and reports PER-MEMBER RESIDENT PARAM
BYTES from the engine's fit output — the run fails unless each member's
resident bytes are <= (its total param bytes / model-axis extent) within
tolerance, i.e. unless member weights are genuinely sharded within pop
slices instead of replicated. ``--population-size auto`` sizes the chunk
width with ``fleet.suggest_population_size`` (per-device memory / member
param+opt bytes).

Companion to benchmarks/kernel_bench.py: where that file guards the Pallas
kernel layer row by row, this one guards the population/fleet training path.
The output is JSON so CI can parse it; ``--smoke`` shrinks the sweep to CI
scale and only checks equivalence, the full run is the perf claim (>= 3x on
CPU at repeats >= 4).

``--tuned`` (composable with any mode) additionally audits the kernel
tuning cache (``repro.tune``): every cached entry is replayed in interpret
mode with tuned vs heuristic blocks on identical inputs and the outputs
must agree to float tolerance — tuning may only change wall-clock, never
the math the resilience tables are built from. The report gains a
``tuning`` section (per-entry diffs plus the capacity planner's per-kernel
VMEM reserve) and the run fails on any numeric mismatch.

Usage:
    PYTHONPATH=src python benchmarks/efat_bench.py [--smoke] [--sharded]
        [--mesh POPxMODEL] [--population-size N|auto] [--devices N]
        [--tuned] [--out FILE]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _sweep_config(smoke: bool):
    from repro.core import fault_rate_list

    if smoke:
        sweep = dict(repeats=2, max_steps=80, seed=3)
        rates = fault_rate_list([0.05], max_fr=0.12, max_interval=0.04, step=0.8)
        pretrain = 200
    else:
        # the paper's interesting regime: tight constraint, rates up to 0.3,
        # so high-rate repeats genuinely need tens-to-hundreds of FAT steps
        sweep = dict(repeats=4, max_steps=400, seed=3)
        rates = fault_rate_list([0.04], max_fr=0.3, max_interval=0.05, step=0.5)
        pretrain = 300
    return sweep, rates, pretrain


def _tables_equal(a, b) -> bool:
    import numpy as np

    return bool(
        np.array_equal(a.rates, b.rates)
        and np.array_equal(a.min_steps, b.min_steps)
        and np.array_equal(a.mean_steps, b.mean_steps)
        and np.array_equal(a.max_steps_stat, b.max_steps_stat)
    )


def run_bench(smoke: bool) -> dict:
    from repro.configs import get_arch
    from repro.core.resilience import measure_resilience
    from repro.train.fat_trainer import ClassifierFATTrainer

    sweep, rates, pretrain = _sweep_config(smoke)
    cfg = get_arch("paper-mlp")
    pop_tr = ClassifierFATTrainer(cfg, pretrain_steps=pretrain, eval_batches=2, population_size=32)
    ser_tr = ClassifierFATTrainer(cfg, pretrain_steps=0, eval_batches=2, engine="serial")
    ser_tr.base_params = pop_tr.base_params  # identical starting point
    constraint = pop_tr.baseline_accuracy - (0.05 if smoke else 0.02)

    def sweep_once(trainer, engine):
        t0 = time.time()
        table = measure_resilience(
            trainer, rates, constraint, array_shape=(32, 32), engine=engine, **sweep
        )
        return time.time() - t0, table

    # population first so its compile time is honestly inside its wall-clock
    t_pop, table_pop = sweep_once(pop_tr, None)
    t_ser, table_ser = sweep_once(ser_tr, "serial")

    tables_equal = _tables_equal(table_pop, table_ser)
    speedup = t_ser / t_pop if t_pop > 0 else float("inf")
    return dict(
        mode="smoke" if smoke else "full",
        rates=[round(float(r), 5) for r in rates],
        repeats=sweep["repeats"],
        max_steps=sweep["max_steps"],
        constraint=round(float(constraint), 5),
        rows=[
            dict(name="efat/step1_serial", seconds=round(t_ser, 3), engine="serial"),
            dict(name="efat/step1_population", seconds=round(t_pop, 3), engine="population"),
        ],
        speedup=round(speedup, 2),
        tables_equal=tables_equal,
        max_steps_stat=[float(v) for v in table_pop.max_steps_stat],
    )


def _skewed_plan(max_steps: int, jobs: int = 16) -> list[int]:
    """Interleaved long/short budgets — the regime where arrival-order
    chunking wastes the most vectorized lanes (ROADMAP's 'very skewed
    plans')."""
    long, short = max_steps, max(1, max_steps // 40)
    return [long - 3 * i if i % 2 == 0 else short + i for i in range(jobs)]


def run_sharded_bench(smoke: bool) -> dict:
    import jax

    from repro.configs import get_arch
    from repro.core.resilience import measure_resilience
    from repro.fleet import FleetScheduler
    from repro.launch.mesh import make_pop_mesh
    from repro.train.fat_trainer import ClassifierFATTrainer

    sweep, rates, pretrain = _sweep_config(smoke)
    n_dev = len(jax.devices())
    cfg = get_arch("paper-mlp")
    pop_size = 8 if smoke else 32
    vmap_tr = ClassifierFATTrainer(
        cfg, pretrain_steps=pretrain, eval_batches=2, population_size=pop_size
    )
    constraint = vmap_tr.baseline_accuracy - (0.05 if smoke else 0.02)

    def sweep_once(trainer):
        t0 = time.time()
        table = measure_resilience(
            trainer, rates, constraint, array_shape=(32, 32), **sweep
        )
        return time.time() - t0, table

    t_vmap, table_vmap = sweep_once(vmap_tr)
    rows = [dict(name="efat/step1_population", seconds=round(t_vmap, 3), devices=1)]

    # per-device scaling: 1, 2, 4, ... up to every visible device
    mesh_sizes = [d for d in (1, 2, 4, 8, 16) if d <= n_dev]
    if n_dev not in mesh_sizes:
        mesh_sizes.append(n_dev)
    tables_equal = True
    for d in mesh_sizes:
        tr = ClassifierFATTrainer(
            cfg, pretrain_steps=0, eval_batches=2, engine="sharded",
            population_size=pop_size, engine_kwargs=dict(mesh=make_pop_mesh(d)),
        )
        tr.base_params = vmap_tr.base_params
        t_d, table_d = sweep_once(tr)
        tables_equal = tables_equal and _tables_equal(table_vmap, table_d)
        rows.append(
            dict(name=f"efat/step1_sharded[pop={d}]", seconds=round(t_d, 3), devices=d)
        )

    # scheduler: wasted vectorized lane-steps, LPT vs arrival, skewed plan
    budgets = _skewed_plan(sweep["max_steps"])
    sched_report = FleetScheduler(pop_size, policy="lpt").report(budgets)
    lpt_strictly_reduces = (
        sched_report["wasted_steps"] < sched_report["arrival_wasted_steps"]
    )
    return dict(
        mode="sharded-smoke" if smoke else "sharded-full",
        devices_visible=n_dev,
        rates=[round(float(r), 5) for r in rates],
        repeats=sweep["repeats"],
        max_steps=sweep["max_steps"],
        constraint=round(float(constraint), 5),
        rows=rows,
        tables_equal=tables_equal,
        max_steps_stat=[float(v) for v in table_vmap.max_steps_stat],
        scheduler=dict(
            plan_budgets=budgets,
            lpt_strictly_reduces=lpt_strictly_reduces,
            **sched_report,
        ),
    )


def _parse_mesh(spec: str) -> tuple[int, int]:
    try:
        pop_s, model_s = spec.lower().split("x")
        pop, model = int(pop_s), int(model_s)
    except ValueError:
        raise SystemExit(f"--mesh wants POPxMODEL (e.g. 4x2), got {spec!r}")
    if pop < 1 or model < 1:
        raise SystemExit(f"--mesh extents must be >= 1, got {spec!r}")
    return pop, model


def run_mesh_bench(smoke: bool, mesh_spec: str, population_size: str) -> dict:
    """2-D fleet-mesh mode: pop x model sharded engine vs the vmap engine,
    plus per-member resident param bytes off the fit output."""
    import jax

    from repro.configs import get_arch
    from repro.core.resilience import measure_resilience
    from repro.fleet import suggest_population_size
    from repro.launch.mesh import make_fleet_mesh
    from repro.train.fat_trainer import ClassifierFATTrainer

    sweep, rates, pretrain = _sweep_config(smoke)
    pop_ext, model_ext = _parse_mesh(mesh_spec)
    cfg = get_arch("paper-mlp")
    mesh = make_fleet_mesh(pop_ext, model_ext)
    if population_size == "auto":
        pop_size = suggest_population_size(cfg, mesh)
    else:
        pop_size = int(population_size) if population_size else (8 if smoke else 32)

    vmap_tr = ClassifierFATTrainer(
        cfg, pretrain_steps=pretrain, eval_batches=2, population_size=pop_size
    )
    mesh_tr = ClassifierFATTrainer(
        cfg, pretrain_steps=0, eval_batches=2, engine="sharded",
        population_size=pop_size, engine_kwargs=dict(mesh=mesh),
    )
    mesh_tr.base_params = vmap_tr.base_params
    constraint = vmap_tr.baseline_accuracy - (0.05 if smoke else 0.02)

    def sweep_once(trainer):
        t0 = time.time()
        table = measure_resilience(
            trainer, rates, constraint, array_shape=(32, 32), **sweep
        )
        return time.time() - t0, table

    t_vmap, table_vmap = sweep_once(vmap_tr)
    t_mesh, table_mesh = sweep_once(mesh_tr)
    tables_equal = _tables_equal(table_vmap, table_mesh)

    # resident-memory proof: train a plan and read the engine's accounting
    # of the raw (still member-stacked, still device-resident) fit output
    budgets = _skewed_plan(sweep["max_steps"], jobs=min(mesh_tr.engine.population_size, 16))
    mesh_tr.train_batch(
        [_bench_fault_map(i) for i in range(len(budgets))], budgets
    )
    stats = mesh_tr.engine.last_fit_stats or {}
    resident = stats.get("per_member_resident_bytes", float("inf"))
    total = stats.get("per_member_total_bytes", 0.0)
    # replicated would be == total; sharded is total/model. 5% + 1 KiB of
    # slack absorbs small replicated leaves (biases that don't divide etc.)
    bound = total / model_ext * 1.05 + 1024
    params_sharded = resident <= bound

    return dict(
        mode="mesh-smoke" if smoke else "mesh-full",
        mesh=dict(pop=pop_ext, model=model_ext),
        devices_visible=len(jax.devices()),
        population_size=pop_size,
        population_size_policy=population_size or "fixed",
        rates=[round(float(r), 5) for r in rates],
        repeats=sweep["repeats"],
        max_steps=sweep["max_steps"],
        constraint=round(float(constraint), 5),
        rows=[
            dict(name="efat/step1_population", seconds=round(t_vmap, 3), devices=1),
            dict(
                name=f"efat/step1_mesh[{pop_ext}x{model_ext}]",
                seconds=round(t_mesh, 3), devices=pop_ext * model_ext,
            ),
        ],
        tables_equal=tables_equal,
        max_steps_stat=[float(v) for v in table_vmap.max_steps_stat],
        memory=dict(
            per_member_resident_bytes=resident,
            per_member_total_bytes=total,
            sharded_bound_bytes=round(bound, 1),
            params_sharded_within_pop_slices=params_sharded,
            **{k: stats[k] for k in (
                "chunk_width", "members_per_lane", "pop_extent", "model_extent",
            ) if k in stats},
        ),
    )


def _bench_fault_map(i: int):
    from repro.core import random_fault_map

    return random_fault_map(i, 32, 32, 0.06 + 0.015 * (i % 8))


def run_tuned_check() -> dict:
    """--tuned: prove the tuning cache never changes numerics.

    For every entry in the process-global tuning cache, run the kernel in
    interpret mode with the TUNED blocks and with the HEURISTIC blocks on
    identical inputs (the tuner's own deterministic runners) and compare.
    Block geometry only re-brackets reductions, so the outputs must agree to
    float tolerance — any larger drift means the cache is changing math, and
    the bench exits non-zero. Also reports the capacity planner's per-kernel
    VMEM reserve so ``--population-size auto`` consumers can see what the
    tuned table costs them.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.fleet.capacity import kernel_vmem_reserve
    from repro.tune import KERNELS, get_tuning_cache, parse_key
    from repro.tune.tuner import HEURISTIC_BLOCKS, normalize_blocks

    cache = get_tuning_cache()
    checks = []
    all_match = True
    for key, entry in sorted(cache.entries.items()):
        kernel, shape, dtype_name, _backend = parse_key(key)
        heur = normalize_blocks(kernel, shape, HEURISTIC_BLOCKS[kernel])
        tuned = normalize_blocks(kernel, shape, entry["blocks"])
        runner = KERNELS[kernel].make_runner(shape, jnp.dtype(dtype_name), True)
        a = np.asarray(runner(heur))
        b = np.asarray(runner(tuned))
        atol = 5e-5 if dtype_name == "float32" else 5e-2
        match = bool(np.allclose(a, b, rtol=1e-4, atol=atol))
        all_match = all_match and match
        checks.append(
            dict(
                key=key,
                heuristic_blocks=heur,
                tuned_blocks=tuned,
                max_abs_diff=float(np.max(np.abs(a - b))) if a.size else 0.0,
                numerics_match=match,
            )
        )
    return dict(
        tuning_cache_entries=len(cache.entries),
        tuning_cache_source=cache.source,
        kernel_vmem_reserve_bytes=kernel_vmem_reserve(cache),
        checks=checks,
        numerics_match=all_match,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-scale sweep; equivalence only")
    ap.add_argument(
        "--sharded", action="store_true",
        help="fleet mode: shard_map per-device scaling + scheduler waste report",
    )
    ap.add_argument(
        "--mesh", default=None, metavar="POPxMODEL",
        help="2-D fleet-mesh mode (e.g. 4x2): pop x model sharded engine, "
        "table equality + per-member resident param bytes",
    )
    ap.add_argument(
        "--population-size", default=None,
        help="population chunk width for --mesh: an integer, or 'auto' to "
        "size against per-device memory (fleet.suggest_population_size)",
    )
    ap.add_argument(
        "--devices", type=int, default=8,
        help="forced host CPU device count for --sharded/--mesh "
        "(ignored if XLA_FLAGS is set)",
    )
    ap.add_argument(
        "--tuned", action="store_true",
        help="also verify the kernel tuning cache: tuned vs heuristic blocks "
        "must agree numerically per cached entry (tuning never changes math)",
    )
    ap.add_argument("--out", default=None, help="also write the JSON report to this file")
    args = ap.parse_args(argv)

    if args.sharded and args.mesh:
        ap.error("--sharded and --mesh are separate modes; pass one at a time")
    if (args.sharded or args.mesh) and "XLA_FLAGS" not in os.environ:
        # must happen before the first jax import — all repro imports are lazy
        need = args.devices
        if args.mesh:
            pop_ext, model_ext = _parse_mesh(args.mesh)
            need = max(need, pop_ext * model_ext)
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={need}"

    if args.mesh:
        report = run_mesh_bench(
            smoke=args.smoke, mesh_spec=args.mesh, population_size=args.population_size
        )
    elif args.sharded:
        report = run_sharded_bench(smoke=args.smoke)
    else:
        report = run_bench(smoke=args.smoke)
    if args.tuned:
        report["tuning"] = run_tuned_check()
    doc = json.dumps(report, indent=2)
    print(doc)
    if args.out:
        with open(args.out, "w") as f:
            f.write(doc)

    if not report["tables_equal"]:
        print("FAIL: engines disagree on the resilience table", file=sys.stderr)
        return 1
    if args.tuned and not report["tuning"]["numerics_match"]:
        bad = [c["key"] for c in report["tuning"]["checks"] if not c["numerics_match"]]
        print(
            "FAIL: tuned blocks changed kernel numerics for: " + ", ".join(bad),
            file=sys.stderr,
        )
        return 1
    if args.mesh and not report["memory"]["params_sharded_within_pop_slices"]:
        print(
            "FAIL: per-member resident param bytes "
            f"{report['memory']['per_member_resident_bytes']} exceed the sharded "
            f"bound {report['memory']['sharded_bound_bytes']} — member weights are "
            "replicated, not model-sharded",
            file=sys.stderr,
        )
        return 1
    if args.sharded and not report["scheduler"]["lpt_strictly_reduces"]:
        print("FAIL: LPT scheduling did not strictly reduce wasted_steps", file=sys.stderr)
        return 1
    if not args.sharded and not args.mesh and not args.smoke and report["speedup"] < 3.0:
        print(f"FAIL: population speedup {report['speedup']}x below the 3x target", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
