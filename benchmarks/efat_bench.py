"""Step-1 wall-clock harness: serial reference vs population FAT engine.

Runs the same resilience sweep (rates x repeats, identical fault-map grid
and identical base params) through both engines and reports wall-clock,
verifying on the way that the two engines produce the SAME resilience
table — the speedup is only real if the math is unchanged.

Companion to benchmarks/kernel_bench.py: where that file guards the Pallas
kernel layer row by row, this one guards the population training path. The
output is JSON (one document with per-engine rows + the speedup) so CI can
parse it; ``--smoke`` shrinks the sweep to CI scale and only checks
equivalence, the full run is the perf claim (>= 3x on CPU at repeats >= 4).

Usage:
    PYTHONPATH=src python benchmarks/efat_bench.py [--smoke] [--out FILE]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.configs import get_arch
from repro.core import fault_rate_list
from repro.core.resilience import measure_resilience
from repro.train.fat_trainer import ClassifierFATTrainer


def run_bench(smoke: bool) -> dict:
    if smoke:
        sweep = dict(repeats=2, max_steps=80, seed=3)
        rates = fault_rate_list([0.05], max_fr=0.12, max_interval=0.04, step=0.8)
        pretrain = 200
    else:
        # the paper's interesting regime: tight constraint, rates up to 0.3,
        # so high-rate repeats genuinely need tens-to-hundreds of FAT steps
        sweep = dict(repeats=4, max_steps=400, seed=3)
        rates = fault_rate_list([0.04], max_fr=0.3, max_interval=0.05, step=0.5)
        pretrain = 300

    cfg = get_arch("paper-mlp")
    pop_tr = ClassifierFATTrainer(cfg, pretrain_steps=pretrain, eval_batches=2, population_size=32)
    ser_tr = ClassifierFATTrainer(cfg, pretrain_steps=0, eval_batches=2, engine="serial")
    ser_tr.base_params = pop_tr.base_params  # identical starting point
    constraint = pop_tr.baseline_accuracy - (0.05 if smoke else 0.02)

    def sweep_once(trainer, engine):
        t0 = time.time()
        table = measure_resilience(
            trainer, rates, constraint, array_shape=(32, 32), engine=engine, **sweep
        )
        return time.time() - t0, table

    # population first so its compile time is honestly inside its wall-clock
    t_pop, table_pop = sweep_once(pop_tr, None)
    t_ser, table_ser = sweep_once(ser_tr, "serial")

    tables_equal = bool(
        np.array_equal(table_pop.rates, table_ser.rates)
        and np.array_equal(table_pop.min_steps, table_ser.min_steps)
        and np.array_equal(table_pop.mean_steps, table_ser.mean_steps)
        and np.array_equal(table_pop.max_steps_stat, table_ser.max_steps_stat)
    )
    speedup = t_ser / t_pop if t_pop > 0 else float("inf")
    return dict(
        mode="smoke" if smoke else "full",
        rates=[round(float(r), 5) for r in rates],
        repeats=sweep["repeats"],
        max_steps=sweep["max_steps"],
        constraint=round(float(constraint), 5),
        rows=[
            dict(name="efat/step1_serial", seconds=round(t_ser, 3), engine="serial"),
            dict(name="efat/step1_population", seconds=round(t_pop, 3), engine="population"),
        ],
        speedup=round(speedup, 2),
        tables_equal=tables_equal,
        max_steps_stat=[float(v) for v in table_pop.max_steps_stat],
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-scale sweep; equivalence only")
    ap.add_argument("--out", default=None, help="also write the JSON report to this file")
    args = ap.parse_args(argv)

    report = run_bench(smoke=args.smoke)
    doc = json.dumps(report, indent=2)
    print(doc)
    if args.out:
        with open(args.out, "w") as f:
            f.write(doc)

    if not report["tables_equal"]:
        print("FAIL: population and serial engines disagree on the resilience table", file=sys.stderr)
        return 1
    if not args.smoke and report["speedup"] < 3.0:
        print(f"FAIL: population speedup {report['speedup']}x below the 3x target", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
