"""Benchmarks mirroring the paper's tables/figures (deliverable d).

Fig. 1a  epoch cost per model            -> bench_fig1_epoch_cost
Fig. 1b  fleet cost scaling vs #chips    -> bench_fig1_fleet_scaling
Fig. 2/8 resilience curves (steps@rate)  -> bench_fig8_resilience
Fig. 12  min/mean/max across patterns    -> bench_fig12_spread
Fig. 13  eFAT vs fixed vs random-merge   -> bench_fig13_comparison
Fig. 3   constraint sensitivity          -> bench_fig3_constraints

All run on the paper-faithful CPU-scale classifier (see DESIGN.md S2);
the same eFAT machinery drives the LM archs via LMFATTrainer.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_arch, reduce_config
from repro.core import (
    EFAT,
    EFATConfig,
    correlated_family,
    fault_rate_list,
    gaussian_chip_rates,
    random_fault_map,
)
from repro.core.resilience import measure_resilience
from repro.train.fat_trainer import ClassifierFATTrainer, LMFATTrainer

Row = tuple[str, float, str]  # (name, us_per_call, derived)

_CACHE: dict = {}


def _trainer() -> ClassifierFATTrainer:
    if "clf" not in _CACHE:
        _CACHE["clf"] = ClassifierFATTrainer(get_arch("paper-mlp"), pretrain_steps=600)
    return _CACHE["clf"]


def bench_fig1_epoch_cost() -> list[Row]:
    """Wall time of one epoch (here: 20 steps) of FAT per model family."""
    rows = []
    tr = _trainer()
    fm = random_fault_map(0, 32, 32, 0.1)
    t0 = time.time()
    tr.train(fm, 20)
    dt_mlp = (time.time() - t0) / 20
    rows.append(("fig1a/paper_mlp_step", dt_mlp * 1e6, "classifier FAT step"))

    lm = LMFATTrainer(reduce_config(get_arch("smollm-135m")), pretrain_steps=5)
    t0 = time.time()
    lm.train(random_fault_map(0, 16, 16, 0.1), 10)
    dt_lm = (time.time() - t0) / 10
    rows.append(("fig1a/smollm_reduced_step", dt_lm * 1e6, "LM FAT step (reduced)"))
    return rows


def bench_fig1_fleet_scaling() -> list[Row]:
    """Fleet retraining cost grows linearly with #chips (fixed policy)."""
    tr = _trainer()
    fm = random_fault_map(1, 32, 32, 0.1)
    t0 = time.time()
    tr.train(fm, 20)
    per_chip_s = time.time() - t0
    rows = []
    for n in (10, 100, 1000):
        rows.append(
            (
                f"fig1b/fleet_{n}chips",
                per_chip_s * n * 1e6,
                f"projected: {per_chip_s * n:.1f}s for {n} chips @20 steps each",
            )
        )
    return rows


def bench_fig8_resilience() -> list[Row]:
    """Steps-to-constraint vs fault rate (the resilience curve, Algo 1 rates)."""
    tr = _trainer()
    constraint = tr.baseline_accuracy - 0.03
    rates = fault_rate_list([0.02], max_fr=0.3, max_interval=0.06, step=0.9)
    t0 = time.time()
    table = measure_resilience(
        tr, rates, constraint, array_shape=(32, 32), repeats=3, max_steps=400, seed=0
    )
    dt = time.time() - t0
    _CACHE["table"] = table
    _CACHE["constraint"] = constraint
    derived = "; ".join(
        f"r={r:.3f}:steps[{mn:.0f},{mu:.0f},{mx:.0f}]"
        for r, mn, mu, mx in zip(
            table.rates, table.min_steps, table.mean_steps, table.max_steps_stat
        )
    )
    return [("fig8/resilience_curve", dt * 1e6, derived)]


def bench_fig12_spread() -> list[Row]:
    """min/mean/max spread across fault patterns justifies the max-stat."""
    t = _CACHE.get("table")
    if t is None:
        bench_fig8_resilience()
        t = _CACHE["table"]
    spread = float(np.mean(t.max_steps_stat - t.min_steps))
    return [
        (
            "fig12/pattern_spread",
            0.0,
            f"mean(max-min) across rates = {spread:.1f} steps -> use max bound",
        )
    ]


def bench_fig3_constraints() -> list[Row]:
    """Relaxed accuracy constraints need dramatically less retraining."""
    tr = _trainer()
    rows = []
    fm = random_fault_map(7, 32, 32, 0.18)
    for delta in (0.01, 0.03, 0.08):
        c = tr.baseline_accuracy - delta
        t0 = time.time()
        steps = tr.steps_to_constraint(fm, c, 400)
        rows.append(
            (
                f"fig3/constraint_minus_{delta}",
                (time.time() - t0) * 1e6,
                f"steps={steps} @ acc>={c:.3f}",
            )
        )
    return rows


def bench_fig13_comparison() -> list[Row]:
    """The headline table: eFAT vs individual vs fixed vs random-merge on a
    correlated fleet (20 chips here; examples/fleet_retraining.py runs 100)."""
    tr = _trainer()
    if "table" not in _CACHE:
        bench_fig8_resilience()
    cfg = EFATConfig(
        constraint=_CACHE["constraint"], repeats=3, max_steps=400,
        m_comparisons=6, k_iterations=2, seed=0,
    )
    ef = EFAT(tr, cfg)
    ef.table = _CACHE["table"]
    fleet = correlated_family(11, 20, 32, 32, base_rate=0.08, idio_rate=0.02)
    rows = []
    t0 = time.time()
    r_efat = ef.run(fleet)
    rows.append(
        (
            "fig13/efat", (time.time() - t0) * 1e6,
            f"jobs={r_efat.plan.num_jobs} steps={r_efat.total_retraining_steps:.0f} "
            f"satisfied={r_efat.satisfied_fraction:.2f}",
        )
    )
    for method, kw in (
        ("individual", {}),
        ("fixed", dict(steps_per_chip=60)),
        ("random-merge", {}),
    ):
        t0 = time.time()
        r = ef.run_baseline(fleet, method, **kw)
        rows.append(
            (
                f"fig13/{method}", (time.time() - t0) * 1e6,
                f"jobs={r.plan.num_jobs} steps={r.total_retraining_steps:.0f} "
                f"satisfied={r.satisfied_fraction:.2f}",
            )
        )
    return rows


ALL = [
    bench_fig1_epoch_cost,
    bench_fig1_fleet_scaling,
    bench_fig8_resilience,
    bench_fig12_spread,
    bench_fig3_constraints,
    bench_fig13_comparison,
]
