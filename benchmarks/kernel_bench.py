"""Micro-benchmarks of the fault-masking substrate on the host backend.

These time the XLA (non-Pallas) paths — the Pallas kernels target TPU and
are validated for correctness in interpret mode; their perf claims are
made structurally in EXPERIMENTS.md SPerf from the lowered HLO.
Here we measure the paper-relevant CPU-visible deltas:
  * masked vs unmasked matmul (the FAP overhead the fused kernel removes)
  * blockwise vs dense attention at long sequence (memory-safe prefill)
  * per-kernel before/after regression rows for all four Pallas kernels
    (reference path vs the kernel path through the shared runtime layer;
    on CPU the kernel path runs in interpret mode, so the timing is a
    correctness/regression signal, not a perf claim)

``--tune`` runs the kernel autotuner (``repro.tune``) over the committed
shape suite instead and emits ``benchmarks/BENCH_kernels.json`` — the
committed perf-trajectory snapshot (per-cell best config, speedup over the
heuristic, achieved-vs-roofline fraction). ``--tune --check`` gates CI:

  * every committed cell must re-tune to a tuned/heuristic wall-clock ratio
    no more than 10% (plus a small absolute epsilon) worse than the
    committed ratio — ratios, not raw seconds, so the gate is portable
    across runner hardware;
  * every entry in the committed tuning cache
    (``src/repro/tune/default_cache.json``) must still pass the
    kernel-geometry lint — a kernel change that invalidates a cached
    config fails here, not at launch time.

``--tune --write-cache`` additionally rewrites the committed default cache
with the fresh winners (run it with ``--out`` when regenerating both
artifacts after a kernel or suite change).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import from_fault_map, healthy, random_fault_map
from repro.core.masking import fault_linear
from repro.kernels.common import dtype_tol, is_tpu_backend
from repro.models.layers import attention_impl

Row = tuple[str, float, str]


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters


def bench_masked_matmul_overhead() -> list[Row]:
    m, k, n = 512, 1024, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    fm = random_fault_map(0, 256, 256, 0.1)
    ctx_h, ctx_f = healthy(), from_fault_map(fm)
    f_h = jax.jit(lambda x, w: fault_linear(x, w, ctx_h))
    f_m = jax.jit(lambda x, w: fault_linear(x, w, ctx_f))
    t_h = _time(f_h, x, w)
    t_m = _time(f_m, x, w)
    return [
        ("kernel/matmul_healthy", t_h * 1e6, f"{2*m*k*n/t_h/1e9:.1f} GFLOP/s"),
        (
            "kernel/matmul_fap_masked", t_m * 1e6,
            f"overhead {100*(t_m-t_h)/t_h:.0f}% (removed by fused Pallas kernel on TPU)",
        ),
    ]


def bench_attention_impls() -> list[Row]:
    b, hq, hkv, s, d = 1, 8, 2, 2048, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    f_d = jax.jit(lambda q, k, v: attention_impl(q, k, v, causal=True, window=None, impl="dense"))
    f_b = jax.jit(lambda q, k, v: attention_impl(q, k, v, causal=True, window=None, impl="blockwise"))
    f_w = jax.jit(lambda q, k, v: attention_impl(q, k, v, causal=True, window=256, impl="blockwise"))
    t_d = _time(f_d, q, k, v, iters=3)
    t_b = _time(f_b, q, k, v, iters=3)
    t_w = _time(f_w, q, k, v, iters=3)
    return [
        ("kernel/attn_dense_2k", t_d * 1e6, "materializes S^2 scores"),
        ("kernel/attn_blockwise_2k", t_b * 1e6, f"flat memory, {t_b/t_d:.2f}x dense time"),
        ("kernel/attn_swa_blockwise_2k", t_w * 1e6, f"O(S*w): {t_w/t_b:.2f}x of full blockwise"),
    ]


# ---------------------------------------------------------------------------
# Per-kernel before/after regression harness (all four Pallas kernels)
# ---------------------------------------------------------------------------


def _regression_row(name: str, ref_fn, kernel_fn, ref_out, kernel_out) -> list[Row]:
    """Time the reference ('before') and kernel ('after') paths and check
    the kernel against the oracle with the shared tolerance table."""
    rtol, atol = dtype_tol(jnp.float32, atol_scale=50)
    err = float(
        np.max(
            np.abs(
                np.asarray(kernel_out, np.float32) - np.asarray(ref_out, np.float32)
            )
        )
    )
    ok = bool(
        np.allclose(
            np.asarray(kernel_out, np.float32),
            np.asarray(ref_out, np.float32),
            rtol=rtol,
            atol=atol,
        )
    )
    t_ref = _time(ref_fn, iters=3)
    t_ker = _time(kernel_fn, iters=3)
    mode = "compiled" if is_tpu_backend() else "interpret"
    return [
        (f"kernel/{name}_ref", t_ref * 1e6, "reference (before)"),
        (
            f"kernel/{name}_pallas",
            t_ker * 1e6,
            f"{mode}; max|err|={err:.2e} {'OK' if ok else 'REGRESSION'}",
        ),
    ]


def bench_kernel_regressions() -> list[Row]:
    """Before/after rows for masked_matmul, flash_attention,
    decode_attention and mamba_scan. Shapes are deliberately tiny: off-TPU
    the kernel body runs in the Pallas interpreter, which is orders of
    magnitude slower than XLA — this harness guards numerics and the shared
    runtime plumbing, and doubles as the perf harness on a real TPU."""
    rows: list[Row] = []
    key = jax.random.PRNGKey(0)

    # masked_matmul
    from repro.kernels.masked_matmul.ops import masked_matmul
    from repro.kernels.masked_matmul.ref import masked_matmul_ref

    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (32, 64))
    w = jax.random.normal(k2, (64, 48))
    ok = (jax.random.uniform(k3, (16, 16)) > 0.1).astype(jnp.float32)
    ref_fn = jax.jit(lambda: masked_matmul_ref(x, w, ok))
    ker_fn = jax.jit(
        lambda: masked_matmul(x, w, ok, bm=32, bn=32, bk=32, interpret=not is_tpu_backend())
    )
    rows += _regression_row("masked_matmul", ref_fn, ker_fn, ref_fn(), ker_fn())

    # flash_attention
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32))
    kk = jax.random.normal(ks[1], (1, 2, 64, 32))
    v = jax.random.normal(ks[2], (1, 2, 64, 32))
    ref_fn = jax.jit(lambda: attention_ref(q, kk, v, causal=True, window=None))
    ker_fn = jax.jit(
        lambda: flash_attention(
            q, kk, v, causal=True, bq=32, bkv=32, interpret=not is_tpu_backend()
        )
    )
    rows += _regression_row("flash_attention", ref_fn, ker_fn, ref_fn(), ker_fn())

    # decode_attention (int8 KV)
    from repro.kernels.decode_attention.ops import decode_attention, quantize_kv
    from repro.kernels.decode_attention.ref import decode_attention_ref

    ks = jax.random.split(key, 3)
    q1 = jax.random.normal(ks[0], (1, 2, 1, 32))
    kc = jax.random.normal(ks[1], (1, 2, 128, 32))
    vc = jax.random.normal(ks[2], (1, 2, 128, 32))
    ki, ksc = quantize_kv(kc)
    vi, vsc = quantize_kv(vc)
    ref_fn = jax.jit(
        lambda: decode_attention_ref(q1, ki, ksc, vi, vsc, kv_valid_len=100)
    )
    ker_fn = jax.jit(
        lambda: decode_attention(
            q1, ki, ksc, vi, vsc, 100, bkv=64, interpret=not is_tpu_backend()
        )
    )
    rows += _regression_row("decode_attention", ref_fn, ker_fn, ref_fn(), ker_fn())

    # mamba selective scan
    from repro.kernels.mamba_scan.ops import selective_scan
    from repro.kernels.mamba_scan.ref import selective_scan_ref

    ks = jax.random.split(key, 6)
    u = jax.random.normal(ks[0], (1, 32, 16))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 32, 16)))
    a = -jnp.exp(jax.random.normal(ks[2], (16, 4)))
    bb = jax.random.normal(ks[3], (1, 32, 4))
    c = jax.random.normal(ks[4], (1, 32, 4))
    dd = jax.random.normal(ks[5], (16,))
    ref_fn = jax.jit(lambda: selective_scan_ref(u, dt, a, bb, c, dd)[0])
    ker_fn = jax.jit(
        lambda: selective_scan(
            u, dt, a, bb, c, dd, bd=16, bl=16, interpret=not is_tpu_backend()
        )[0]
    )
    rows += _regression_row("mamba_scan", ref_fn, ker_fn, ref_fn(), ker_fn())

    return rows


ALL = [bench_masked_matmul_overhead, bench_attention_impls, bench_kernel_regressions]


def print_rows(fns) -> None:
    """Shared ``name,us_per_call,derived`` CSV printer (also used by
    benchmarks/run.py so the two outputs cannot drift)."""
    import traceback

    print("name,us_per_call,derived")
    for fn in fns:
        try:
            for name, us, derived in fn():
                print(f'{name},{us:.1f},"{derived}"', flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f'{fn.__name__},-1,"ERROR: {e}"', flush=True)


# ---------------------------------------------------------------------------
# --tune: the committed kernel-autotuning suite + perf-trajectory snapshot
# ---------------------------------------------------------------------------

SNAPSHOT_VERSION = 1
BENCH_PATH = "benchmarks/BENCH_kernels.json"

# The committed shape suite: small enough that interpret mode finishes in CI
# minutes, non-trivial enough that the heuristic is NOT always the winner
# (heuristic blocks smaller than the axis leave grid steps on the table).
TUNE_SUITE: list[tuple[str, dict]] = [
    ("masked_matmul", dict(m=64, k=64, n=64, r=16, c=16)),
    ("masked_matmul", dict(m=128, k=128, n=128, r=32, c=32)),
    ("flash_attention", dict(b=1, hq=2, hkv=1, sq=256, skv=256, d=32, causal=1)),
    ("decode_attention", dict(b=1, hq=2, hkv=2, skv=512, d=32)),
    ("mamba_scan", dict(b=1, l=256, d=64, n=8)),
]

# --check tolerance on the tuned/heuristic wall-clock ratio: machine noise
# moves both numerators and denominators, so a relative band + small
# absolute epsilon holds across runner generations.
RATIO_SLACK_REL = 1.10
RATIO_SLACK_ABS = 0.05


def run_tune(iters: int = 3, max_evals: int = 16):
    """Tune the committed suite; returns (snapshot_dict, results, cache)."""
    from repro.kernels.common import backend_tag, is_tpu_backend
    from repro.obs.recorder import Recorder
    from repro.tune import set_tuning_cache, tune_many, TuningCache

    # tune against heuristics only — a stale global cache must not seed
    # (or contaminate) the measurement of what the heuristic costs
    prev = set_tuning_cache(TuningCache())
    rec = Recorder()
    try:
        results, cache = tune_many(
            TUNE_SUITE, iters=iters, max_evals=max_evals, recorder=rec
        )
    finally:
        set_tuning_cache(prev)

    cells = {}
    for res in results:
        cells[res.key] = dict(
            kernel=res.kernel,
            shape=res.shape,
            dtype=res.dtype,
            heuristic=dict(
                blocks=res.heuristic_blocks, us=round(res.heuristic_s * 1e6, 1)
            ),
            tuned=dict(blocks=res.best_blocks, us=round(res.best_s * 1e6, 1)),
            ratio=round(res.best_s / res.heuristic_s, 4),
            speedup=round(res.speedup, 4),
            roofline_fraction=res.roofline_fraction,
            vmem_bytes=res.vmem_bytes,
            evaluated=res.evaluated,
            rejected=res.rejected,
        )
    snapshot = dict(
        version=SNAPSHOT_VERSION,
        backend=backend_tag(not is_tpu_backend()),
        iters=iters,
        max_evals=max_evals,
        tune_spans_recorded=len(rec.event_list()),
        cells=cells,
    )
    return snapshot, results, cache


def check_tune(snapshot: dict, committed_path: str) -> list[str]:
    """CI gate: fresh snapshot vs the committed one + relint of the
    committed tuning cache. Returns a list of failure messages."""
    from repro.tune.cache import DEFAULT_CACHE_PATH, TuningCache, parse_key
    from repro.tune.tuner import lint_candidate

    failures: list[str] = []
    try:
        committed = json.load(open(committed_path))
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot read committed snapshot {committed_path}: {e}"]
    if committed.get("version") != SNAPSHOT_VERSION:
        return [f"committed snapshot version {committed.get('version')} != {SNAPSHOT_VERSION}"]

    fresh_cells = snapshot["cells"]
    for key, cell in committed.get("cells", {}).items():
        fresh = fresh_cells.get(key)
        if fresh is None:
            failures.append(
                f"committed cell {key} missing from the fresh tune — suite "
                "changed? regenerate with --tune --out " + committed_path
            )
            continue
        bound = cell["ratio"] * RATIO_SLACK_REL + RATIO_SLACK_ABS
        if fresh["ratio"] > bound:
            failures.append(
                f"{key}: tuned/heuristic ratio regressed to {fresh['ratio']:.3f} "
                f"(committed {cell['ratio']:.3f}, bound {bound:.3f}) — the tuner "
                "no longer finds the committed win"
            )

    # every committed cache entry must still be a lintable launch
    cache = TuningCache.load(DEFAULT_CACHE_PATH)
    for key, entry in cache.entries.items():
        kernel, shape, dtype, _backend = parse_key(key)
        findings, _ = lint_candidate(kernel, shape, jnp.dtype(dtype), entry["blocks"])
        if findings:
            codes = ",".join(f.code for f in findings)
            failures.append(
                f"cached config {key} -> {entry['blocks']} now fails the "
                f"kernel-geometry lint ({codes}) — a kernel change invalidated "
                "it; re-run --tune --write-cache"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tune", action="store_true", help="run the autotuner suite")
    ap.add_argument("--check", action="store_true",
                    help="with --tune: gate against the committed snapshot")
    ap.add_argument("--write-cache", action="store_true",
                    help="with --tune: rewrite src/repro/tune/default_cache.json")
    ap.add_argument("--out", default=None,
                    help=f"with --tune: write the snapshot (canonical: {BENCH_PATH})")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--max-evals", type=int, default=16)
    args = ap.parse_args(argv)

    if not args.tune:
        print_rows(ALL)
        return 0

    snapshot, results, cache = run_tune(iters=args.iters, max_evals=args.max_evals)
    for res in results:
        print(
            f"{res.kernel:18s} {res.heuristic_blocks} {res.heuristic_s*1e6:9.1f}us"
            f" -> {res.best_blocks} {res.best_s*1e6:9.1f}us  x{res.speedup:.2f}"
            f"  roofline {res.roofline_fraction:.2e}  ({res.evaluated} timed,"
            f" {res.rejected} lint-rejected)",
            file=sys.stderr, flush=True,
        )
    # gate BEFORE writing: --check always compares against the *committed*
    # snapshot, even when --out points at the same file
    failures: list[str] = []
    if args.check:
        failures = check_tune(snapshot, BENCH_PATH)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(snapshot, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}", file=sys.stderr)
    if args.write_cache:
        from repro.tune.cache import DEFAULT_CACHE_PATH

        cache.save(DEFAULT_CACHE_PATH)
        print(f"wrote {DEFAULT_CACHE_PATH} ({len(cache)} entries)", file=sys.stderr)

    if args.check:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        if failures:
            return 1
        print(
            f"tune check OK: {len(snapshot['cells'])} cells within "
            f"{RATIO_SLACK_REL:.0%}+{RATIO_SLACK_ABS} of the committed ratios; "
            "cached configs lint-clean",
            file=sys.stderr,
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
