"""Micro-benchmarks of the fault-masking substrate on the host backend.

These time the XLA (non-Pallas) paths — the Pallas kernels target TPU and
are validated for correctness in interpret mode; their perf claims are
made structurally in EXPERIMENTS.md SPerf from the lowered HLO.
Here we measure the paper-relevant CPU-visible deltas:
  * masked vs unmasked matmul (the FAP overhead the fused kernel removes)
  * blockwise vs dense attention at long sequence (memory-safe prefill)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import from_fault_map, healthy, random_fault_map
from repro.core.masking import fault_linear
from repro.models.layers import attention_impl

Row = tuple[str, float, str]


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters


def bench_masked_matmul_overhead() -> list[Row]:
    m, k, n = 512, 1024, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    fm = random_fault_map(0, 256, 256, 0.1)
    ctx_h, ctx_f = healthy(), from_fault_map(fm)
    f_h = jax.jit(lambda x, w: fault_linear(x, w, ctx_h))
    f_m = jax.jit(lambda x, w: fault_linear(x, w, ctx_f))
    t_h = _time(f_h, x, w)
    t_m = _time(f_m, x, w)
    return [
        ("kernel/matmul_healthy", t_h * 1e6, f"{2*m*k*n/t_h/1e9:.1f} GFLOP/s"),
        (
            "kernel/matmul_fap_masked", t_m * 1e6,
            f"overhead {100*(t_m-t_h)/t_h:.0f}% (removed by fused Pallas kernel on TPU)",
        ),
    ]


def bench_attention_impls() -> list[Row]:
    b, hq, hkv, s, d = 1, 8, 2, 2048, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    f_d = jax.jit(lambda q, k, v: attention_impl(q, k, v, causal=True, window=None, impl="dense"))
    f_b = jax.jit(lambda q, k, v: attention_impl(q, k, v, causal=True, window=None, impl="blockwise"))
    f_w = jax.jit(lambda q, k, v: attention_impl(q, k, v, causal=True, window=256, impl="blockwise"))
    t_d = _time(f_d, q, k, v, iters=3)
    t_b = _time(f_b, q, k, v, iters=3)
    t_w = _time(f_w, q, k, v, iters=3)
    return [
        ("kernel/attn_dense_2k", t_d * 1e6, "materializes S^2 scores"),
        ("kernel/attn_blockwise_2k", t_b * 1e6, f"flat memory, {t_b/t_d:.2f}x dense time"),
        ("kernel/attn_swa_blockwise_2k", t_w * 1e6, f"O(S*w): {t_w/t_b:.2f}x of full blockwise"),
    ]


ALL = [bench_masked_matmul_overhead, bench_attention_impls]
