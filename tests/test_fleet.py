"""Fleet subsystem tests (repro.fleet): budget-aware scheduling, device-
sharded population execution, and multi-chip serving.

Equivalence contracts pinned here:
* LPT-packed chunks yield bitwise-identical params / steps-to-constraint to
  arrival-order submission (scheduling is pure reordering).
* serial, vmap, and shard_map engines produce identical resilience tables
  and steps-to-constraint (the shard_map check runs in-process on whatever
  devices exist, and in a subprocess on a forced 8-host-device CPU mesh).
* FleetServeEngine greedy generation reproduces per-chip ServeEngine
  token-for-token.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduce_config
from repro.core import EFAT, EFATConfig, from_fault_map, healthy, random_fault_map
from repro.core.resilience import measure_resilience
from repro.fleet import (
    FleetScheduler,
    FleetServeEngine,
    ShardedPopulationEngine,
    suggest_population_size,
)
from repro.launch.mesh import make_fleet_mesh, make_pop_mesh
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.train.fat_trainer import ClassifierFATTrainer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CFG = get_arch("paper-mlp")


def _leaves_equal(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


@pytest.fixture(scope="module")
def trainers():
    """(lpt, arrival, sharded) ClassifierFATTrainers sharing base params."""
    lpt = ClassifierFATTrainer(CFG, pretrain_steps=300, eval_batches=2, population_size=8)
    arr = ClassifierFATTrainer(
        CFG, pretrain_steps=0, eval_batches=2, population_size=8, schedule="arrival"
    )
    shd = ClassifierFATTrainer(
        CFG, pretrain_steps=0, eval_batches=2, population_size=8, engine="sharded"
    )
    arr.base_params = lpt.base_params
    shd.base_params = lpt.base_params
    return lpt, arr, shd


@pytest.fixture(scope="module")
def fleet():
    rng = np.random.default_rng(7)
    rates = [0.18, 0.03, 0.22, 0.08, 0.12]
    return [random_fault_map(rng, 32, 32, r) for r in rates]


# ---------------------------------------------------------------------------
# FleetScheduler
# ---------------------------------------------------------------------------


def test_scheduler_lpt_packs_by_descending_cost():
    sched = FleetScheduler(population_size=2, policy="lpt").schedule([10, 500, 20, 480])
    assert sched.order == (1, 3, 2, 0)  # descending cost, stable index tiebreak
    assert [c.indices for c in sched.chunks] == [(1, 3), (2, 0)]
    # chunk spans: 500 (with 480 riding 20 wasted), 20 (with 10 riding 10)
    assert sched.chunks[0].span == 500 and sched.chunks[1].span == 20
    assert sched.wasted_steps == (500 - 480) + (20 - 10)


def test_scheduler_lpt_strictly_reduces_waste_on_skewed_plan():
    budgets = [500, 10, 20, 480, 15, 490, 5, 470]  # long/short interleaved
    scheduler = FleetScheduler(population_size=2, policy="lpt")
    rep = scheduler.report(budgets)
    assert rep["wasted_steps"] < rep["arrival_wasted_steps"]
    assert rep["wasted_steps_reduction"] == rep["arrival_wasted_steps"] - rep["wasted_steps"]
    # uniform budgets: nothing to win, nothing to lose
    flat = FleetScheduler(population_size=2).report([100] * 6)
    assert flat["wasted_steps"] == flat["arrival_wasted_steps"] == 0


def test_scheduler_counts_padding_lanes_of_partial_chunks():
    # 3 jobs, width 2: final chunk has one real member + one padding lane
    sched = FleetScheduler(population_size=2, policy="arrival").schedule([50, 50, 40])
    assert [c.indices for c in sched.chunks] == [(0, 1), (2,)]
    assert sched.chunks[1].width == 2
    assert sched.chunks[1].wasted_steps == 40  # the empty lane rides 40 steps
    # a single sub-width submission compiles at its own width, not the max
    small = FleetScheduler(population_size=8).schedule([10, 30])
    assert small.chunks[0].width == 2 and small.chunks[0].wasted_steps == 20
    # sharded engines tile their pop mesh: width rounds up to the mesh size
    # and the extra padding lanes count as waste (they run for real)
    shard = FleetScheduler(population_size=8, width_multiple=8).schedule([100] * 5)
    assert shard.chunks[0].width == 8
    assert shard.chunks[0].wasted_steps == 300  # 3 padding lanes x 100 steps


def test_sharded_trainer_scheduler_counts_mesh_padding(trainers):
    _, _, shd = trainers
    assert shd.scheduler.width_multiple == shd.engine.num_shards


def test_schedule_permute_unpermute_roundtrip():
    sched = FleetScheduler(population_size=3).schedule([5.0, 9.0, 1.0, 7.0])
    seq = ["a", "b", "c", "d"]
    assert sched.unpermute(sched.permute(seq)) == seq
    with pytest.raises(ValueError):
        sched.permute(seq[:2])
    with pytest.raises(ValueError):
        FleetScheduler(population_size=2, policy="bogus")


# ---------------------------------------------------------------------------
# Scheduler invariance on the real training path
# ---------------------------------------------------------------------------


def test_lpt_and_arrival_schedules_bitwise_identical(trainers, fleet):
    """Packing policy changes chunk composition only; every member's
    trajectory — and therefore the shipped params — is bit-for-bit the same."""
    lpt, arr, _ = trainers
    budgets = [30, 5, 25, 10, 7]  # skewed on purpose
    p_lpt = lpt.train_batch(fleet, budgets)
    p_arr = arr.train_batch(fleet, budgets)
    for a, b in zip(p_lpt, p_arr):
        assert _leaves_equal(a, b)
    constraint = lpt.baseline_accuracy - 0.05
    assert lpt.steps_to_constraint_batch(fleet, constraint, 100) == (
        arr.steps_to_constraint_batch(fleet, constraint, 100)
    )


def test_execute_plan_reports_scheduling(trainers, fleet):
    lpt, _, _ = trainers
    ef = EFAT(
        lpt,
        EFATConfig(
            constraint=lpt.baseline_accuracy - 0.06, max_fr=0.25, max_interval=0.06,
            step_ratio=0.8, repeats=2, max_steps=120, m_comparisons=4, k_iterations=1,
        ),
    )
    result = ef.run(fleet)
    assert result.scheduling is not None
    assert result.scheduling["policy"] == "lpt"
    assert result.scheduling["wasted_steps_reduction"] >= 0
    assert "wasted_steps" in result.summary()


# ---------------------------------------------------------------------------
# ShardedPopulationEngine (in-process: mesh over whatever devices exist)
# ---------------------------------------------------------------------------


def test_make_pop_mesh():
    mesh = make_pop_mesh()
    assert mesh.axis_names == ("pop",)
    assert mesh.shape["pop"] == len(jax.devices())
    with pytest.raises(ValueError):
        make_pop_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError):
        make_pop_mesh(0)


def test_make_pop_mesh_validates_instead_of_raw_reshape():
    """Bad extents get clear ValueErrors naming devices/extents — never a
    raw numpy reshape failure."""
    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        make_pop_mesh(n + 3)
    with pytest.raises(ValueError, match="integer"):
        make_pop_mesh("four")
    with pytest.raises(ValueError, match=">= 1"):
        make_pop_mesh(-2)


def test_make_fleet_mesh_validation_and_clamping():
    n = len(jax.devices())
    mesh = make_fleet_mesh()  # defaults: every device, model=1
    assert mesh.axis_names == ("pop", "model")
    assert mesh.shape["pop"] == n and mesh.shape["model"] == 1
    # pop=None clamps to the largest clean tiling instead of failing
    if n >= 3:
        clamped = make_fleet_mesh(None, 3)
        assert clamped.shape["pop"] == n // 3
    # explicit extents that don't fit name the numbers in the error
    with pytest.raises(ValueError, match=f"{n + 1} devices"):
        make_fleet_mesh(n + 1, 1)
    with pytest.raises(ValueError, match="model extent"):
        make_fleet_mesh(1, n + 1)
    with pytest.raises(ValueError, match=">= 1"):
        make_fleet_mesh(1, 0)
    with pytest.raises(ValueError, match="integer"):
        make_fleet_mesh("4x2")
    with pytest.raises(ValueError, match="axis names"):
        make_fleet_mesh(1, 1, axis_names=("pop",))


def _engine_kwargs_from(engine):
    return dict(
        loss_fn=engine.loss_fn, opt_cfg=engine.opt_cfg,
        eval_batches=[{}], param_axes=engine.param_axes,
    )


def test_sharded_engine_2d_mesh_requires_layout(trainers):
    """A model axis of extent > 1 needs the tensor-parallel layout inputs
    (cfg/mesh_rules + param_axes) and a valid compute mode."""
    _, _, shd = trainers
    dev = jax.devices()[0]
    mesh2 = jax.sharding.Mesh(np.array([dev] * 4).reshape(2, 2), ("pop", "model"))
    kw = _engine_kwargs_from(shd.engine)
    with pytest.raises(ValueError, match="rules"):
        ShardedPopulationEngine(mesh=mesh2, **kw)
    with pytest.raises(ValueError, match="param_axes"):
        ShardedPopulationEngine(mesh=mesh2, cfg=CFG, **{**kw, "param_axes": None})
    with pytest.raises(ValueError, match="compute"):
        ShardedPopulationEngine(cfg=CFG, compute="bogus", **kw)


def test_suggest_population_size_scales_with_model_axis():
    dev = jax.devices()[0]
    mesh_1d = jax.sharding.Mesh(np.array([dev] * 4), ("pop",))
    mesh_2d = jax.sharding.Mesh(np.array([dev] * 8).reshape(4, 2), ("pop", "model"))
    budget = CFG.param_count() * 12 * 3  # three members' state per device
    flat = suggest_population_size(CFG, mesh_1d, hbm_bytes=budget, headroom=1.0)
    tp = suggest_population_size(CFG, mesh_2d, hbm_bytes=budget, headroom=1.0)
    assert flat == 3 * 4  # 3 members per lane x 4 lanes
    assert tp == 6 * 4  # model axis halves per-member resident bytes
    assert suggest_population_size(CFG, None, hbm_bytes=budget, headroom=1.0) == 3
    with pytest.raises(ValueError, match="model axis"):
        suggest_population_size(
            get_arch("llama3-405b"), mesh_2d, hbm_bytes=budget
        )
    with pytest.raises(ValueError, match="headroom"):
        suggest_population_size(CFG, mesh_1d, hbm_bytes=budget, headroom=0.0)


def test_sharded_engine_chunks_tile_the_mesh(trainers):
    _, _, shd = trainers
    eng = shd.engine
    assert isinstance(eng, ShardedPopulationEngine)
    assert eng.population_size % eng.num_shards == 0
    for n in (1, eng.num_shards, eng.population_size + 1):
        for _lo, keep, size in eng._chunks(n):
            assert size % eng.num_shards == 0
            assert keep <= size
    with pytest.raises(ValueError):
        ShardedPopulationEngine(
            mesh=make_pop_mesh(axis="rows"), axis_name="pop",
            loss_fn=eng.loss_fn, opt_cfg=eng.opt_cfg, eval_batches=[{}],
        )


def test_sharded_matches_vmap_tables_and_steps(trainers, fleet):
    """shard_map <-> vmap: identical steps-to-constraint and resilience
    tables; params within one float32 ulp-scale tolerance (vmap width
    changes GEMM batching, not member math)."""
    lpt, _, shd = trainers
    constraint = lpt.baseline_accuracy - 0.05
    assert shd.steps_to_constraint_batch(fleet, constraint, 100) == (
        lpt.steps_to_constraint_batch(fleet, constraint, 100)
    )
    rates = [0.06, 0.14, 0.2]
    kw = dict(array_shape=(32, 32), repeats=2, max_steps=100, seed=5)
    t_pop = measure_resilience(lpt, rates, constraint, **kw)
    t_shd = measure_resilience(shd, rates, constraint, **kw)
    assert np.array_equal(t_pop.rates, t_shd.rates)
    assert np.array_equal(t_pop.min_steps, t_shd.min_steps)
    assert np.array_equal(t_pop.mean_steps, t_shd.mean_steps)
    assert np.array_equal(t_pop.max_steps_stat, t_shd.max_steps_stat)
    budgets = [12, 30, 5, 21, 9]
    p_pop = lpt.train_batch(fleet, budgets)
    p_shd = shd.train_batch(fleet, budgets)
    for a, b in zip(p_pop, p_shd):
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6)
    ev_pop = lpt.evaluate_batch(p_pop, fleet)
    ev_shd = shd.evaluate_batch(p_shd, fleet)
    assert ev_pop == pytest.approx(ev_shd, abs=2e-3)


def test_fleet_mesh_engine_matches_vmap_in_process(trainers, fleet):
    """2-D ("pop", "model") engine over whatever devices exist: identical
    steps-to-constraint / resilience tables to the vmap engine, params to
    ulp tolerance — the same contract the 1-D pop mesh is pinned to. With
    >= 2 devices the model axis is a real extent (the CI fleet job forces
    8); on one device the 1x1 mesh still runs the full 2-D code path."""
    lpt, _, _ = trainers
    n = len(jax.devices())
    model = 2 if n >= 2 and n % 2 == 0 else 1
    mesh = make_fleet_mesh(n // model, model)
    tr = ClassifierFATTrainer(
        CFG, pretrain_steps=0, eval_batches=2, population_size=8,
        engine="sharded", engine_kwargs=dict(mesh=mesh),
    )
    tr.base_params = lpt.base_params
    assert tr.engine.num_shards == n // model  # pop extent, NOT device count
    assert tr.engine.model_size == model
    assert tr.scheduler.width_multiple == n // model
    constraint = lpt.baseline_accuracy - 0.05
    assert tr.steps_to_constraint_batch(fleet, constraint, 100) == (
        lpt.steps_to_constraint_batch(fleet, constraint, 100)
    )
    rates = [0.06, 0.14, 0.2]
    kw = dict(array_shape=(32, 32), repeats=2, max_steps=100, seed=5)
    t_pop = measure_resilience(lpt, rates, constraint, **kw)
    t_2d = measure_resilience(tr, rates, constraint, **kw)
    assert np.array_equal(t_pop.min_steps, t_2d.min_steps)
    assert np.array_equal(t_pop.mean_steps, t_2d.mean_steps)
    assert np.array_equal(t_pop.max_steps_stat, t_2d.max_steps_stat)
    budgets = [12, 30, 5, 21, 9]
    p_pop = lpt.train_batch(fleet, budgets)
    p_2d = tr.train_batch(fleet, budgets)
    for a, b in zip(p_pop, p_2d):
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6)
    # fit accounting: member params resident sharded model_size-ways
    stats = tr.engine.last_fit_stats
    assert stats is not None and stats["model_extent"] == model
    assert stats["per_member_resident_bytes"] <= (
        stats["per_member_total_bytes"] / model * 1.05 + 1024
    )


# ---------------------------------------------------------------------------
# subprocess: forced 8-host-device CPU mesh (genuine multi-device shard_map)
# ---------------------------------------------------------------------------

_SUB = r"""
import os
os.environ['JAX_PLATFORMS'] = 'cpu'
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import jax, numpy as np
from repro.configs import get_arch
from repro.core import random_fault_map
from repro.core.resilience import measure_resilience
from repro.train.fat_trainer import ClassifierFATTrainer

assert len(jax.devices()) == 8
cfg = get_arch('paper-mlp')
pop = ClassifierFATTrainer(cfg, pretrain_steps=250, eval_batches=2, population_size=8)
ser = ClassifierFATTrainer(cfg, pretrain_steps=0, eval_batches=2, engine='serial')
shd = ClassifierFATTrainer(cfg, pretrain_steps=0, eval_batches=2, engine='sharded',
                           population_size=8)
from repro.launch.mesh import make_fleet_mesh
shd2 = ClassifierFATTrainer(cfg, pretrain_steps=0, eval_batches=2, engine='sharded',
                            population_size=8,
                            engine_kwargs=dict(mesh=make_fleet_mesh(4, 2)))
ser.base_params = pop.base_params
shd.base_params = pop.base_params
shd2.base_params = pop.base_params
assert shd.engine.num_shards == 8
assert shd2.engine.num_shards == 4 and shd2.engine.model_size == 2
constraint = pop.baseline_accuracy - 0.05
rates = [0.05, 0.12, 0.2]
kw = dict(array_shape=(32, 32), repeats=2, max_steps=100, seed=11)
t_ser = measure_resilience(ser, rates, constraint, engine='serial', **kw)
t_pop = measure_resilience(pop, rates, constraint, **kw)
t_shd = measure_resilience(shd, rates, constraint, **kw)
t_shd2 = measure_resilience(shd2, rates, constraint, **kw)
fleet = [random_fault_map(i, 32, 32, 0.1 + 0.02 * i) for i in range(5)]
s_ser = ser.steps_to_constraint_batch(fleet, constraint, 100)
s_pop = pop.steps_to_constraint_batch(fleet, constraint, 100)
s_shd = shd.steps_to_constraint_batch(fleet, constraint, 100)
s_shd2 = shd2.steps_to_constraint_batch(fleet, constraint, 100)
budg = [12, 30, 5, 21, 9]
p_pop = pop.train_batch(fleet, budg)
shd2.train_batch(fleet, budg)
mem = shd2.engine.last_fit_stats
# compute='sharded': true tensor-parallel math — float-tolerance equal,
# resident bytes still sharded
tps = ClassifierFATTrainer(cfg, pretrain_steps=0, eval_batches=2, engine='sharded',
                           population_size=8,
                           engine_kwargs=dict(mesh=make_fleet_mesh(4, 2),
                                              compute='sharded'))
tps.base_params = pop.base_params
p_tp = tps.train_batch(fleet, budg)
tp_close = all(
    np.allclose(np.asarray(x), np.asarray(y), rtol=1e-4, atol=1e-5)
    for a, b in zip(p_pop, p_tp)
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))
tp_mem = tps.engine.last_fit_stats
def teq(a, b):
    return bool(np.array_equal(a.max_steps_stat, b.max_steps_stat)
                and np.array_equal(a.min_steps, b.min_steps)
                and np.array_equal(a.mean_steps, b.mean_steps))
print('RESULT', json.dumps(dict(
    devices=len(jax.devices()),
    tables_serial_vmap=teq(t_ser, t_pop),
    tables_vmap_shard=teq(t_pop, t_shd),
    tables_serial_mesh2d=teq(t_ser, t_shd2),
    steps_equal=bool(s_ser == s_pop == s_shd == s_shd2),
    steps=[None if s is None else int(s) for s in s_shd],
    per_member_resident_bytes=mem['per_member_resident_bytes'],
    per_member_total_bytes=mem['per_member_total_bytes'],
    tp_compute_close=bool(tp_close),
    tp_per_member_resident_bytes=tp_mem['per_member_resident_bytes'],
)))
"""


@pytest.mark.slow
def test_serial_vmap_shardmap_identical_on_8_device_mesh():
    """serial <-> vmap <-> 1-D shard_map (pop=8) <-> 2-D shard_map (4x2)
    produce identical resilience tables and steps-to-constraint, and the
    4x2 mesh keeps per-member resident param bytes at total/model-extent
    (member weights sharded within pop slices, not replicated)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)  # the child sets its own device count
    out = subprocess.run(
        [sys.executable, "-c", _SUB], capture_output=True, text=True, env=env,
        timeout=720,
    )
    lines = [l for l in out.stdout.splitlines() if l.startswith("RESULT")]
    assert lines, f"no result: {out.stdout[-800:]} {out.stderr[-2000:]}"
    res = json.loads(lines[0][len("RESULT "):])
    assert res["devices"] == 8
    assert res["tables_serial_vmap"], res
    assert res["tables_vmap_shard"], res
    assert res["tables_serial_mesh2d"], res
    assert res["steps_equal"], res
    assert res["per_member_resident_bytes"] <= (
        res["per_member_total_bytes"] / 2 * 1.05 + 1024
    ), res
    assert res["tp_compute_close"], res
    assert res["tp_per_member_resident_bytes"] <= (
        res["per_member_total_bytes"] / 2 * 1.05 + 1024
    ), res


# ---------------------------------------------------------------------------
# FleetServeEngine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_fleet():
    cfg = reduce_config(get_arch("smollm-135m"))
    key = jax.random.PRNGKey(0)
    chips = []
    for i, rate in enumerate((0.0, 0.25, 0.4)):
        params, _ = M.init_params(cfg, jax.random.PRNGKey(i))
        ctx = (
            healthy()
            if rate == 0.0
            else from_fault_map(random_fault_map(i, cfg.array_rows, cfg.array_cols, rate))
        )
        chips.append((params, ctx))
    prompts = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    return cfg, chips, prompts


def test_fleet_serve_greedy_matches_per_chip_engines(serve_fleet):
    cfg, chips, prompts = serve_fleet
    fleet_eng = FleetServeEngine(
        cfg, [p for p, _ in chips], [c for _, c in chips], max_len=48
    )
    out = fleet_eng.generate(prompts, max_new_tokens=6)
    assert out.tokens.shape == (len(chips), 2, 8 + 6)
    assert out.logprobs.shape == (len(chips), 2, 6)
    for i, (params, ctx) in enumerate(chips):
        ref = ServeEngine(cfg, params, ctx, max_len=48).generate(prompts, max_new_tokens=6)
        toks_i, lps_i = out.chip(i)
        assert np.array_equal(np.asarray(toks_i), np.asarray(ref.tokens)), f"chip {i}"
        np.testing.assert_allclose(
            np.asarray(lps_i), np.asarray(ref.logprobs), rtol=1e-5, atol=1e-5
        )


def test_fleet_serve_faulty_chips_diverge(serve_fleet):
    """Chips share prompts but not weights/masks — generations must differ
    across chips, proving each lane runs its own (params, mask)."""
    cfg, chips, prompts = serve_fleet
    params0, _ = chips[0]
    ctxs = [c for _, c in chips]
    eng = FleetServeEngine(cfg, [params0] * 3, ctxs, max_len=48)
    out = eng.generate(prompts, max_new_tokens=6)
    gen = np.asarray(out.tokens[:, :, 8:])
    assert not np.array_equal(gen[0], gen[1])  # healthy vs faulty mask


def test_fleet_serve_temperature_uses_per_chip_keys(serve_fleet):
    cfg, chips, prompts = serve_fleet
    params0, _ = chips[0]
    eng = FleetServeEngine(cfg, [params0] * 2, None, max_len=48)
    out = eng.generate(
        prompts, max_new_tokens=6, temperature=1.0, key=jax.random.PRNGKey(3)
    )
    # same params + healthy ctx, different per-chip sample streams
    assert not np.array_equal(np.asarray(out.tokens[0]), np.asarray(out.tokens[1]))


def test_fleet_serve_validates_inputs(serve_fleet):
    cfg, chips, _ = serve_fleet
    with pytest.raises(ValueError):
        FleetServeEngine(cfg, [], [])
    with pytest.raises(ValueError):
        FleetServeEngine(cfg, [chips[0][0]], [healthy(), healthy()])
