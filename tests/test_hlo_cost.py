"""Regression tests for the loop-aware HLO cost model that feeds the
roofline analysis (EXPERIMENTS.md §Roofline)."""
import numpy as np

from repro.launch.hlo_cost import analyze_hlo

# A hand-written post-SPMD-style HLO module:
#   body: one dot (M=8,K=16,N=32 f32) + an all-gather (out 4096 B, groups of 4)
#   entry: while(body) with known_trip_count 5 + one all-reduce (f32[100])
_HLO = """
HloModule test

%body.1 (p: (s32[], f32[8,16], f32[4,16])) -> (s32[], f32[8,16], f32[4,16]) {
  %p = (s32[], f32[8,16], f32[4,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %a = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %kshard = f32[4,16]{1,0} get-tuple-element(%p), index=2
  %w = f32[16,32]{1,0} constant({...})
  %dot.1 = f32[8,32]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[16,16]{1,0} all-gather(%kshard), replica_groups=[4,4]<=[16], dimensions={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16], f32[4,16]) tuple(%ip, %a, %kshard)
}

%cond.1 (p: (s32[], f32[8,16], f32[4,16])) -> pred[] {
  %p = (s32[], f32[8,16], f32[4,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main.1 (x: f32[8,16], ks: f32[4,16], g: f32[100]) -> f32[100] {
  %x = f32[8,16]{1,0} parameter(0)
  %ks = f32[4,16]{1,0} parameter(1)
  %g = f32[100]{0} parameter(2)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16], f32[4,16]) tuple(%zero, %x, %ks)
  %while.1 = (s32[], f32[8,16], f32[4,16]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %ar = f32[100]{0} all-reduce(%g), replica_groups=[16,16]<=[256], to_apply=%add.1
}
"""


def test_dot_flops_with_trip_count():
    cost = analyze_hlo(_HLO, n_devices_default=256)
    # dot: 2*8*32*16 = 8192 flops, x5 trips
    assert cost.flops == 2 * 8 * 32 * 16 * 5


def test_collective_wire_bytes():
    cost = analyze_hlo(_HLO, n_devices_default=256)
    d = cost.as_dict()
    # all-gather: out 16*16*4 = 1024 B, groups of 4 -> 1024 * 3/4, x5 trips
    assert np.isclose(d["coll_by_kind"]["all-gather"], 1024 * 0.75 * 5)
    # all-reduce: out 400 B, groups of 16 -> 2 * 400 * 15/16, x1
    assert np.isclose(d["coll_by_kind"]["all-reduce"], 2 * 400 * 15 / 16)
    assert d["coll_count"]["all-gather"] == 5
    assert d["coll_count"]["all-reduce"] == 1


def test_bytes_include_dot_operands_and_result():
    cost = analyze_hlo(_HLO, n_devices_default=256)
    # per trip the dot touches a(512) + w(2048) + out(1024) bytes; the
    # all-gather adds local read+write of the gathered buffer (2*1024)
    per_trip = (8 * 16 + 16 * 32 + 8 * 32) * 4 + 2 * 1024
    assert cost.bytes >= per_trip * 5


def test_real_cell_attribution_smollm():
    """End-to-end sanity on a stored artifact: attention dot FLOPs in the
    smollm train HLO match the analytic count (the validation quoted in
    EXPERIMENTS.md §Roofline)."""
    import gzip
    import os

    path = "experiments/dryrun/smollm_135m__train_4k__pod1.hlo.gz"
    if not os.path.exists(path):
        import pytest

        pytest.skip("dry-run artifact not present")
    hlo = gzip.open(path, "rt").read()
    cost = analyze_hlo(hlo, n_devices_default=256)
    dots = dict(cost.as_dict()["top_dots"])
    qk = dots.get("bhgqd,bhkd->bhgqk", 0.0)
    # analytic: L30 * B16 * H9 * S^2 * hd64 * 2 (no causal skip in the scan
    # form) * 4 executions (fwd + remat + 2 bwd dots share the label)
    analytic = 30 * 16 * 9 * 4096**2 * 64 * 2 * 4
    assert abs(qk - analytic) / analytic < 0.05
