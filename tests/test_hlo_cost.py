"""Regression tests for the loop-aware HLO cost model that feeds the
roofline analysis (EXPERIMENTS.md §Roofline), plus the per-instruction /
alias-table API the donation lint (repro.analysis) consumes."""
import numpy as np

from repro.launch.hlo_cost import (
    analyze_hlo,
    entry_parameters,
    input_output_aliases,
    iter_instructions,
)

# A hand-written post-SPMD-style HLO module:
#   body: one dot (M=8,K=16,N=32 f32) + an all-gather (out 4096 B, groups of 4)
#   entry: while(body) with known_trip_count 5 + one all-reduce (f32[100])
_HLO = """
HloModule test

%body.1 (p: (s32[], f32[8,16], f32[4,16])) -> (s32[], f32[8,16], f32[4,16]) {
  %p = (s32[], f32[8,16], f32[4,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %a = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %kshard = f32[4,16]{1,0} get-tuple-element(%p), index=2
  %w = f32[16,32]{1,0} constant({...})
  %dot.1 = f32[8,32]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[16,16]{1,0} all-gather(%kshard), replica_groups=[4,4]<=[16], dimensions={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16], f32[4,16]) tuple(%ip, %a, %kshard)
}

%cond.1 (p: (s32[], f32[8,16], f32[4,16])) -> pred[] {
  %p = (s32[], f32[8,16], f32[4,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main.1 (x: f32[8,16], ks: f32[4,16], g: f32[100]) -> f32[100] {
  %x = f32[8,16]{1,0} parameter(0)
  %ks = f32[4,16]{1,0} parameter(1)
  %g = f32[100]{0} parameter(2)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16], f32[4,16]) tuple(%zero, %x, %ks)
  %while.1 = (s32[], f32[8,16], f32[4,16]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %ar = f32[100]{0} all-reduce(%g), replica_groups=[16,16]<=[256], to_apply=%add.1
}
"""


def test_dot_flops_with_trip_count():
    cost = analyze_hlo(_HLO, n_devices_default=256)
    # dot: 2*8*32*16 = 8192 flops, x5 trips
    assert cost.flops == 2 * 8 * 32 * 16 * 5


def test_collective_wire_bytes():
    cost = analyze_hlo(_HLO, n_devices_default=256)
    d = cost.as_dict()
    # all-gather: out 16*16*4 = 1024 B, groups of 4 -> 1024 * 3/4, x5 trips
    assert np.isclose(d["coll_by_kind"]["all-gather"], 1024 * 0.75 * 5)
    # all-reduce: out 400 B, groups of 16 -> 2 * 400 * 15/16, x1
    assert np.isclose(d["coll_by_kind"]["all-reduce"], 2 * 400 * 15 / 16)
    assert d["coll_count"]["all-gather"] == 5
    assert d["coll_count"]["all-reduce"] == 1


def test_bytes_include_dot_operands_and_result():
    cost = analyze_hlo(_HLO, n_devices_default=256)
    # per trip the dot touches a(512) + w(2048) + out(1024) bytes; the
    # all-gather adds local read+write of the gathered buffer (2*1024)
    per_trip = (8 * 16 + 16 * 32 + 8 * 32) * 4 + 2 * 1024
    assert cost.bytes >= per_trip * 5


def test_iter_instructions_yields_parsed_entry():
    instrs = list(iter_instructions(_HLO, entry_only=True))
    by_name = {i.name: i for i in instrs}
    assert by_name["x"].opcode == "parameter"
    assert by_name["x"].result_bytes == 8 * 16 * 4
    assert by_name["while.1"].opcode == "while"
    assert by_name["ar"].is_root and by_name["ar"].opcode == "all-reduce"
    assert by_name["ar"].operands == ("g",)
    # computation-scoped iteration sees the body's dot but not the entry
    body = list(iter_instructions(_HLO, computation="body.1"))
    assert any(i.opcode == "dot" for i in body)
    assert not any(i.name == "while.1" for i in body)


def test_entry_parameters_by_number():
    params = entry_parameters(_HLO)
    assert sorted(params) == [0, 1, 2]
    assert params[2].result_bytes == 100 * 4


def test_input_output_alias_header_parse():
    hlo = (
        "HloModule jit_f, input_output_alias={ {0}: (1, {}, may-alias), "
        "{1}: (3, {}, must-alias) }, entry_computation_layout={(f32[8])->f32[8]}\n"
        "ENTRY %main (p0: f32[8]) -> f32[8] {\n"
        "  ROOT %p0 = f32[8]{0} parameter(0)\n"
        "}\n"
    )
    aliases = input_output_aliases(hlo)
    assert [(a.output_index, a.param_number, a.kind) for a in aliases] == [
        ((0,), 1, "may-alias"),
        ((1,), 3, "must-alias"),
    ]
    assert input_output_aliases(_HLO) == []  # no table -> nothing donated


def test_alias_table_from_real_compiled_module():
    """End to end on a real jit: donation shows up in the optimized HLO and
    the donated parameter's byte size matches entry_parameters."""
    import jax
    import jax.numpy as jnp

    fn = jax.jit(lambda x, y: (x + y, y * 2.0), donate_argnums=(0,))
    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    hlo = fn.lower(s, s).compile().as_text()
    aliases = input_output_aliases(hlo)
    assert {a.param_number for a in aliases} == {0}
    params = entry_parameters(hlo)
    assert params[0].result_bytes == 64 * 64 * 4

    undonated = jax.jit(lambda x, y: (x + y, y * 2.0))
    assert input_output_aliases(undonated.lower(s, s).compile().as_text()) == []


def test_real_cell_attribution_smollm():
    """End-to-end sanity on a stored artifact: attention dot FLOPs in the
    smollm train HLO match the analytic count (the validation quoted in
    EXPERIMENTS.md §Roofline)."""
    import gzip
    import os

    path = "experiments/dryrun/smollm_135m__train_4k__pod1.hlo.gz"
    if not os.path.exists(path):
        import pytest

        pytest.skip("dry-run artifact not present")
    hlo = gzip.open(path, "rt").read()
    cost = analyze_hlo(hlo, n_devices_default=256)
    dots = dict(cost.as_dict()["top_dots"])
    qk = dots.get("bhgqd,bhkd->bhgqk", 0.0)
    # analytic: L30 * B16 * H9 * S^2 * hd64 * 2 (no causal skip in the scan
    # form) * 4 executions (fwd + remat + 2 bwd dots share the label)
    analytic = 30 * 16 * 9 * 4096**2 * 64 * 2 * 4
    assert abs(qk - analytic) / analytic < 0.05
