"""Property tests (hypothesis) + unit tests for the eFAT core:
fault-map algebra (Eq. 2/3), Algo 1, resilience interpolation, Algo 2.

``hypothesis`` is optional: in offline environments where it cannot be
installed, only the property-based tests are skipped — the module still
collects and the plain unit tests run."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised in offline environments

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis is not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies``: strategy constructors are
        only evaluated inside ``@given(...)`` decorator arguments, so inert
        placeholders are enough for collection."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core import (
    FaultMap,
    ResilienceTable,
    ResilienceTable2D,
    clustered_fault_map,
    correlated_family,
    expected_merged_rate,
    expected_weight_loss,
    fam_permutation,
    fault_rate_list,
    fixed_policy_plan,
    from_fault_map,
    group_and_fuse,
    individual_plan,
    masked_weight,
    overlap_rate,
    periodic_mask,
    random_fault_map,
    random_pair_merge_plan,
)

# ---------------------------------------------------------------------------
# Fault-map algebra
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    rate_a=st.floats(0.0, 0.5),
    rate_b=st.floats(0.0, 0.5),
    seed=st.integers(0, 10_000),
)
def test_merge_rate_bounds(rate_a, rate_b, seed):
    a = random_fault_map(seed, 32, 32, rate_a)
    b = random_fault_map(seed + 1, 32, 32, rate_b)
    merged = a | b
    assert merged.fault_rate <= min(1.0, a.fault_rate + b.fault_rate) + 1e-9
    assert merged.fault_rate >= max(a.fault_rate, b.fault_rate) - 1e-9
    # Eq. 3 exactly, using the measured overlap
    expected = expected_merged_rate(a.fault_rate, b.fault_rate, overlap_rate(a, b))
    assert merged.fault_rate == pytest.approx(expected, abs=1e-9)
    # union semantics
    assert np.array_equal(merged.faulty, a.faulty | b.faulty)


@settings(max_examples=10, deadline=None)
@given(rate=st.floats(0.01, 0.3), seed=st.integers(0, 1000))
def test_exact_fault_rate(rate, seed):
    fm = random_fault_map(seed, 64, 64, rate)
    assert fm.num_faults == round(rate * 64 * 64)


def test_correlated_family_overlap_exceeds_independence():
    fam = correlated_family(0, 4, 64, 64, base_rate=0.06, idio_rate=0.01)
    a, b = fam[0], fam[1]
    assert overlap_rate(a, b) > 2 * a.fault_rate * b.fault_rate


def test_clustered_map_rate():
    fm = clustered_fault_map(0, 64, 64, 0.08)
    assert fm.fault_rate == pytest.approx(0.08, abs=0.002)


def test_fault_map_save_load_roundtrip(tmp_path):
    """np.savez_compressed appends '.npz'; load must find what save wrote
    whether the caller spelled the suffix or not."""
    fm = random_fault_map(7, 16, 16, 0.2, chip_id="chipA")
    for name in ("bare", "with_suffix.npz"):
        path = str(tmp_path / name)
        fm.save(path)
        loaded = FaultMap.load(path)  # original spelling
        assert np.array_equal(loaded.faulty, fm.faulty)
        assert loaded.chip_id == "chipA"
    # the artifact on disk is the normalized .npz path
    assert (tmp_path / "bare.npz").exists()
    assert (tmp_path / "with_suffix.npz").exists()


# ---------------------------------------------------------------------------
# Systolic mapping
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    din=st.integers(1, 70),
    dout=st.integers(1, 70),
    r=st.sampled_from([8, 16]),
    seed=st.integers(0, 100),
)
def test_periodic_mask_semantics(din, dout, r, seed):
    fm = random_fault_map(seed, r, r, 0.2)
    mask = np.asarray(periodic_mask((din, dout), jnp.asarray(fm.ok_mask)))
    for _ in range(20):
        a = np.random.randint(din)
        b = np.random.randint(dout)
        assert mask[a, b] == fm.ok_mask[a % r, b % r]


def test_expected_weight_loss_matches_mask():
    fm = random_fault_map(3, 16, 16, 0.15)
    shape = (40, 56)
    mask = np.asarray(periodic_mask(shape, jnp.asarray(fm.ok_mask)))
    assert expected_weight_loss(shape, fm) == pytest.approx(1.0 - mask.mean(), abs=1e-6)


def test_masked_weight_grad_is_masked():
    import jax

    fm = random_fault_map(0, 8, 8, 0.3)
    w = jnp.ones((16, 16))
    ok = jnp.asarray(fm.ok_mask)

    def f(w):
        return jnp.sum(masked_weight(w, ok) ** 2)

    g = jax.grad(f)(w)
    mask = np.asarray(periodic_mask((16, 16), ok))
    assert np.all((np.asarray(g) != 0) == (mask > 0))


def test_fam_beats_fap_on_salient_mass():
    """Greedy FAM assignment zeroes less saliency mass than identity (FAP)."""
    rng = np.random.default_rng(0)
    w = rng.normal(size=(64, 48)) * rng.uniform(0.1, 10.0, size=(1, 48))
    fm = random_fault_map(1, 16, 16, 0.2)
    perm = fam_permutation(w, fm)
    assert sorted(perm) == list(range(48))  # a real permutation
    col_faults = fm.faulty.mean(axis=0)
    sal = np.abs(w).sum(axis=0)
    fap_loss = sum(sal[j] * col_faults[j % 16] for j in range(48))
    fam_loss = sum(sal[j] * col_faults[perm[j] % 16] for j in range(48))
    assert fam_loss <= fap_loss + 1e-9


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    rates=st.lists(st.floats(0.001, 0.4), min_size=1, max_size=20),
    max_fr=st.floats(0.05, 0.6),
    max_int=st.floats(0.01, 0.1),
    step=st.floats(0.1, 1.0),
)
def test_fault_rate_list_properties(rates, max_fr, max_int, step):
    lfr = fault_rate_list(rates, max_fr=max_fr, max_interval=max_int, step=step)
    assert lfr[0] == pytest.approx(min(rates))
    upper = max(max(rates), max_fr)
    assert lfr[-1] > upper  # covers the range (merged maps interpolate)
    diffs = np.diff(lfr)
    assert np.all(diffs > 0)
    assert np.all(diffs <= max_int + 1e-9)


# ---------------------------------------------------------------------------
# Resilience tables
# ---------------------------------------------------------------------------


def test_table_interpolation_exact_at_knots_and_monotone():
    rates = [0.05, 0.1, 0.2, 0.3]
    fn = lambda r: 10 * np.exp(15 * r)
    t = ResilienceTable.from_function(rates, fn, cap=100000, constraint=0.9)
    for r in rates:
        assert t.required_steps(r) == pytest.approx(fn(r), rel=1e-9)
    qs = np.linspace(0.05, 0.3, 37)
    vals = [t.required_steps(q) for q in qs]
    assert np.all(np.diff(vals) >= -1e-9)
    # clamp below, linear-extrapolate (capped) above
    assert t.required_steps(0.0) == pytest.approx(fn(0.05))
    assert t.required_steps(0.9) <= 100000


def test_table_json_roundtrip():
    t = ResilienceTable.from_function([0.1, 0.2], lambda r: 5 + r, cap=10, constraint=0.5)
    t2 = ResilienceTable.from_json(t.to_json())
    assert np.allclose(t2.rates, t.rates)
    assert t2.cap == t.cap


def test_bilinear_2d():
    ra, rb = [0.0, 0.1, 0.2], [0.0, 0.2]
    z = np.array([[0, 2], [10, 12], [20, 22]], dtype=float)
    t = ResilienceTable2D(ra, rb, z, cap=100, constraint=0.9)
    for i, a in enumerate(ra):
        for j, b in enumerate(rb):
            assert t.required_steps(a, b) == pytest.approx(z[i, j])
    assert t.required_steps(0.05, 0.1) == pytest.approx(6.0)  # center of a cell


# ---------------------------------------------------------------------------
# Algorithm 2 + baselines
# ---------------------------------------------------------------------------


def _table():
    rates = fault_rate_list([0.02], max_fr=0.5, max_interval=0.03, step=0.5)
    return ResilienceTable.from_function(
        rates, lambda r: 5 * np.exp(18 * r), cap=10**6, constraint=0.9
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(2, 24))
def test_group_and_fuse_partitions_chips(seed, n):
    rng = np.random.default_rng(seed)
    maps = [
        random_fault_map(rng, 32, 32, float(r))
        for r in np.clip(rng.normal(0.08, 0.03, n), 0.01, 0.3)
    ]
    plan = group_and_fuse(maps, _table(), m_comparisons=4, k_iterations=2, seed=seed)
    covered = sorted(i for link in plan.links for i in link)
    assert covered == list(range(n))  # exact partition, nothing lost
    # fused map of each group is the union of its members
    for fm, link in zip(plan.fault_maps, plan.links):
        union = np.zeros_like(maps[0].faulty)
        for i in link:
            union |= maps[i].faulty
        assert np.array_equal(fm.faulty, union)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_efat_never_costs_more_than_individual(seed):
    """Each Algo-2 merge requires saving >= 0 (zero-saving merges still cut
    a retraining job), so the plan's table cost never increases over
    per-chip selection."""
    maps = correlated_family(seed, 16, 32, 32, base_rate=0.05, idio_rate=0.015)
    t = _table()
    efat = group_and_fuse(maps, t, m_comparisons=6, k_iterations=2, seed=seed)
    indiv = individual_plan(maps, t)
    assert efat.total_steps <= indiv.total_steps + 1e-6


def test_independent_maps_rarely_merge():
    maps = [random_fault_map(100 + i, 32, 32, 0.1) for i in range(16)]
    plan = group_and_fuse(maps, _table(), m_comparisons=6, k_iterations=2, seed=0)
    assert plan.num_jobs >= 14  # Eq. 3: no correlation -> no benefit


def test_correlated_maps_do_merge():
    maps = correlated_family(3, 16, 32, 32, base_rate=0.06, idio_rate=0.01)
    plan = group_and_fuse(maps, _table(), m_comparisons=8, k_iterations=3, seed=0)
    assert plan.num_jobs < 16


def test_baseline_plans_cover_all_chips():
    maps = [random_fault_map(i, 32, 32, 0.1) for i in range(9)]
    for plan in (
        fixed_policy_plan(maps, 25),
        random_pair_merge_plan(maps, steps_per_job=25, seed=0),
        individual_plan(maps, _table()),
    ):
        covered = sorted(i for link in plan.links for i in link)
        assert covered == list(range(9))


def test_fault_context_masks_only_in_fap_mode():
    import jax

    fm = random_fault_map(0, 8, 8, 0.5)
    w = jnp.ones((8, 8))
    x = jnp.ones((1, 8))
    from repro.core import fault_linear, healthy

    y_healthy = fault_linear(x, w, healthy())
    y_fap = fault_linear(x, w, from_fault_map(fm))
    assert float(y_healthy[0, 0]) == 8.0
    assert float(jnp.max(y_fap)) < 8.0


# ---------------------------------------------------------------------------
# FaultMap edges: merge validation, overlap extremes, pristine round-trip
# ---------------------------------------------------------------------------


def test_fault_map_merge_rejects_shape_mismatch():
    a = random_fault_map(0, 8, 8, 0.1)
    b = random_fault_map(1, 16, 16, 0.1)
    with pytest.raises(ValueError, match="shape mismatch"):
        a.merge(b)
    with pytest.raises(ValueError, match="shape mismatch"):
        _ = a | b


def test_overlap_rate_extremes():
    faulty = np.zeros((8, 8), bool)
    faulty[0] = True
    a = FaultMap(faulty)
    other = np.zeros((8, 8), bool)
    other[1] = True
    b = FaultMap(other)
    assert overlap_rate(a, b) == 0.0  # disjoint: Pr_{A AND B} = 0
    assert overlap_rate(a, a) == a.fault_rate  # identical: full overlap
    merged = a.merge(b)
    assert merged.fault_rate == pytest.approx(
        a.fault_rate + b.fault_rate - overlap_rate(a, b)
    )  # Eq. 3 holds exactly on measured maps


def test_all_healthy_fault_map_round_trip(tmp_path):
    fm = FaultMap(np.zeros((8, 8), bool), chip_id="pristine")
    assert fm.num_faults == 0 and fm.fault_rate == 0.0
    assert np.all(fm.ok_mask == 1.0)
    p = tmp_path / "fm"
    fm.save(p)
    back = FaultMap.load(p)
    assert back.chip_id == "pristine"
    assert np.array_equal(back.faulty, fm.faulty) and back.num_faults == 0
