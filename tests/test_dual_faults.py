"""Dual fault types (PE bypass + weight-memory stuck-at-1) and the 2-D
resilience surface — the paper's §III-B multi-dimensional extension."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import random_fault_map
from repro.core.dual import dual_fault_weight, measure_resilience_2d, project_params
from repro.core.mapping import periodic_mask
from repro.train.fat_trainer import ClassifierFATTrainer


def test_dual_fault_weight_semantics():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(32, 32)).astype(np.float32))
    fm_pe = random_fault_map(0, 16, 16, 0.2)
    fm_sa1 = random_fault_map(1, 16, 16, 0.2)
    out = np.asarray(dual_fault_weight(w, fm_pe, fm_sa1, magnitude=0.5))
    pe_mask = np.asarray(periodic_mask((32, 32), jnp.asarray(fm_pe.ok_mask)))
    sa1 = np.asarray(periodic_mask((32, 32), jnp.asarray(fm_sa1.faulty, jnp.float32)))
    # PE bypass dominates: anything on a faulty PE is zero
    assert np.all(out[pe_mask == 0] == 0)
    # stuck-at-1 cells on healthy PEs read back +-magnitude
    sel = (sa1 > 0) & (pe_mask > 0)
    assert np.all(np.abs(out[sel]) == pytest.approx(0.5))
    # untouched cells pass through
    clean = (sa1 == 0) & (pe_mask > 0)
    assert np.allclose(out[clean], np.asarray(w)[clean])


def test_projection_idempotent():
    params = {"w0": jnp.ones((16, 16)), "b0": jnp.zeros(16)}
    fm_sa1 = random_fault_map(2, 8, 8, 0.3)
    p1 = project_params(params, None, fm_sa1)
    p2 = project_params(p1, None, fm_sa1)
    assert np.allclose(np.asarray(p1["w0"]), np.asarray(p2["w0"]))
    assert np.array_equal(np.asarray(p1["b0"]), np.asarray(params["b0"]))


def test_resilience_2d_surface_monotone_in_pe_rate():
    cfg = get_arch("paper-mlp")
    tr = ClassifierFATTrainer(cfg, pretrain_steps=400, eval_batches=2)
    constraint = tr.baseline_accuracy - 0.06
    table = measure_resilience_2d(
        tr, rates_pe=[0.05, 0.3], rates_sa1=[0.0, 0.1], constraint=constraint,
        max_steps=250, repeats=1, seed=0, magnitude=0.5,
    )
    # higher PE rate never needs fewer steps (at fixed sa1 rate)
    assert table.steps[1, 0] >= table.steps[0, 0]
    assert table.steps[1, 1] >= table.steps[0, 1]
    # bilinear query inside the grid is finite and bounded by the cap
    q = table.required_steps(0.15, 0.05)
    assert 0 <= q <= 250
