import os
import sys

# tests run on the real single-CPU backend; the 512-device flag is ONLY for
# the dry-run CLI. Sharding tests that need fake devices use subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
