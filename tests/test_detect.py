"""Online fault-detection tests: ABFT probes, health scoring, alerts.

Contracts pinned here:
* the numpy syndrome math is exact against the weight-stationary mapping:
  a fault at PE (rho, c) perturbs only output columns b % C == c through
  weight rows a % R == rho, and folding the syndrome mod C recovers (rho, c);
* ``masked_matmul_checksummed`` returns the same payload as
  ``masked_matmul`` bitwise and a checksum row equal to the column sums
  (up to float reassociation), through the interpreted Pallas kernel too;
* ``ChipProber`` is structurally zero-false-positive (healthy probes are
  bitwise identical to their golden snapshot) and reconstructs an injected
  delta that matches ``core/faults.py`` ground truth exactly;
* the health state machine debounces healthy -> suspect -> degraded on
  probe evidence only (soft drift transitions require an explicit
  ``drift_z`` opt-in) and recovers on a clean streak;
* the alert engine fires/resolves with for_ticks debounce, aggregates
  glob matches, reads histogram percentile fields lazily, and treats
  missing metrics as inactive;
* enabling probes on the serving engines changes ZERO sampled tokens and
  never false-positives on healthy silicon, while a mid-serve
  ``set_silicon`` injection is detected within a bounded number of decode
  dispatches with a localized delta — per chip, without perturbing the
  rest of the fleet;
* dropped-ring accounting surfaces in Recorder.summary / read_jsonl /
  validate_chrome_trace, and PoolMonitor.flush closes counter series.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduce_config
from repro.core import FaultMap, from_fault_map, healthy, random_fault_map
from repro.models import model as M
from repro.obs import (
    DEGRADED,
    HEALTHY,
    SUSPECT,
    AlertEngine,
    AlertRule,
    ChipHealth,
    ChipProber,
    HealthConfig,
    HealthTracker,
    Recorder,
    chrome_trace,
    detection_rules,
    read_jsonl,
    validate_chrome_trace,
    write_jsonl,
)
from repro.obs.abft import (
    ProbeResult,
    fold_syndrome,
    make_structured_probe,
    periodic_mask_np,
    reconstruct_delta,
    select_probe_weight,
)
from repro.obs.health import DriftDetector, Ewma
from repro.serve import ContinuousBatchingEngine, PageAllocator, Request

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def served_model():
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    return cfg, params


def _prompt(cfg, seed, n):
    return np.asarray(jax.random.randint(
        jax.random.fold_in(KEY, seed), (n,), 0, cfg.vocab_size
    ))


def _zero_map(r, c):
    return FaultMap(np.zeros((r, c), bool))


# ---------------------------------------------------------------------------
# syndrome math (pure numpy)
# ---------------------------------------------------------------------------


def test_periodic_mask_np_matches_core_mapping():
    from repro.core.mapping import periodic_mask

    fm = random_fault_map(0, 4, 4, 0.25)
    ok = ~fm.faulty
    jm = np.asarray(periodic_mask((10, 11), jax.numpy.asarray(ok, jax.numpy.float32)))
    nm = periodic_mask_np((10, 11), ok)
    assert np.array_equal(jm, nm)


def test_fault_structure_is_periodic_and_fold_localizes():
    """A single faulty PE (rho, c) perturbs exactly the columns b % C == c,
    and only through weight rows a % R == rho — folding the syndrome mod C
    lands it back on column c."""
    rng = np.random.default_rng(1)
    R, C, K, N = 4, 4, 12, 10
    W = rng.standard_normal((K, N))
    x = rng.standard_normal((3, K))
    ok = np.ones((R, C), bool)
    y0 = x @ (W * periodic_mask_np(W.shape, ok))
    ok[2, 1] = False
    y1 = x @ (W * periodic_mask_np(W.shape, ok))
    diff_cols = np.nonzero(np.abs(y1 - y0).max(axis=0) > 0)[0]
    assert set(diff_cols % C) == {1}
    folded = fold_syndrome((y1 - y0)[0], C)
    assert folded.shape == (C,)
    assert folded[1] > 0 and np.all(folded[np.arange(C) != 1] == 0)
    # inputs avoiding the faulty PE's weight rows (a % R == 2) see no fault
    x_masked = x.copy()
    x_masked[:, np.arange(K) % R == 2] = 0.0
    healthy_y = x_masked @ (W * periodic_mask_np(W.shape, np.ones((R, C), bool)))
    assert np.allclose(x_masked @ (W * periodic_mask_np(W.shape, ok)), healthy_y)


def test_fold_syndrome_pads_ragged_tails():
    s = np.zeros(10)
    s[9] = 3.0  # N=10, C=4: column 9 folds onto PE col 1
    folded = fold_syndrome(s, 4)
    assert folded.tolist() == [0.0, 3.0, 0.0, 0.0]


def test_structured_probe_row_support():
    x = make_structured_probe(k_dim=13, rows=4)
    assert x.shape == (4, 13)
    for rho in range(4):
        support = np.nonzero(x[rho])[0]
        assert np.all(support % 4 == rho)
        assert np.all(x[rho][support] >= 0.5)  # no cancellation by design
    # every weight row is covered by exactly one probe row
    assert int((x != 0).sum()) == 13


def test_reconstruct_delta_matches_fault_map_ground_truth():
    rng = np.random.default_rng(2)
    R, C, K, N = 8, 8, 32, 24
    W = rng.standard_normal((K, N)).astype(np.float32)
    probe = make_structured_probe(K, R)
    believed = random_fault_map(3, R, C, 0.05)
    truth = believed.merge(random_fault_map(4, R, C, 0.08))
    gold = probe @ (W * periodic_mask_np(W.shape, ~believed.faulty))
    live = probe @ (W * periodic_mask_np(W.shape, ~truth.faulty))
    delta = reconstruct_delta(gold, live, C, tol=1e-5)
    assert np.array_equal(delta, truth.faulty & ~believed.faulty)


# ---------------------------------------------------------------------------
# probe weight selection
# ---------------------------------------------------------------------------


def test_select_probe_weight_slices_layer_stacked_params(served_model):
    _, params = served_model
    name, w = select_probe_weight(params)
    assert w.ndim == 2  # layer-stacked leaves contribute one (K, N) slice
    assert min(w.shape) > 1
    assert any(k in name for k in ("wq", "wk", "wv", "wo", "wg", "wu", "wd",
                                   "wi", "lm_head", "in_proj", "out_proj"))


def test_select_probe_weight_rejects_unmaskable_params():
    with pytest.raises(ValueError, match="maskable"):
        select_probe_weight({"bias": np.zeros(4), "scale": np.ones(3)})


# ---------------------------------------------------------------------------
# checksummed kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("interpret", [None, True])
def test_masked_matmul_checksummed_parity_and_identity(interpret):
    from repro.kernels.masked_matmul.ops import masked_matmul, masked_matmul_checksummed

    rng = np.random.default_rng(5)
    x = jax.numpy.asarray(rng.standard_normal((6, 16)).astype(np.float32))
    w = jax.numpy.asarray(rng.standard_normal((16, 12)).astype(np.float32))
    ok = jax.numpy.asarray((~random_fault_map(6, 4, 4, 0.2).faulty), jax.numpy.float32)
    y, chk = masked_matmul_checksummed(x, w, ok, interpret=interpret)
    y_ref = masked_matmul(x, w, ok, interpret=interpret)
    # the payload went through the same masked path: bitwise equal
    assert np.array_equal(np.asarray(y), np.asarray(y_ref))
    # ABFT identity: the checksum row is the column sum (float reassociation)
    np.testing.assert_allclose(
        np.asarray(chk), np.asarray(y).sum(axis=0), rtol=1e-4, atol=1e-4
    )


def test_checksummed_syndrome_localizes_under_silicon_change():
    """The believed-map golden vs the true-silicon live checksum row
    diverges exactly on the faulty PE columns mod C."""
    from repro.kernels.masked_matmul.ops import masked_matmul_checksummed

    rng = np.random.default_rng(7)
    C = 4
    x = jax.numpy.asarray(rng.standard_normal((5, 16)).astype(np.float32))
    w = jax.numpy.asarray(rng.standard_normal((16, 12)).astype(np.float32))
    believed = np.ones((4, C), bool)
    true = believed.copy()
    true[1, 3] = False
    _, chk_gold = masked_matmul_checksummed(
        x, w, jax.numpy.asarray(believed, jax.numpy.float32))
    _, chk_live = masked_matmul_checksummed(
        x, w, jax.numpy.asarray(true, jax.numpy.float32))
    folded = fold_syndrome(np.asarray(chk_live, np.float64)
                           - np.asarray(chk_gold, np.float64), C)
    assert folded[3] > 1e-5
    assert np.all(folded[np.arange(C) != 3] <= 1e-5)


# ---------------------------------------------------------------------------
# ChipProber against a numpy silicon model
# ---------------------------------------------------------------------------


def _silicon(W, ok_ref):
    """Numpy stand-in for the jitted checksummed dispatch; reads the LIVE
    ok array through the closure like the engines re-read self.ctx."""
    def dispatch(x):
        m = periodic_mask_np(W.shape, ok_ref)
        y = (np.asarray(x, np.float64) @ (W * m)).astype(np.float32)
        chk = (np.asarray(x, np.float64).sum(axis=0) @ (W * m)).astype(np.float32)
        return y, chk
    return dispatch


def test_prober_healthy_probes_are_bitwise_zero_false_positive():
    rng = np.random.default_rng(8)
    W = rng.standard_normal((24, 20)).astype(np.float32)
    ok = np.ones((4, 4), bool)
    prober = ChipProber(_silicon(W, ok), array_shape=(4, 4), k_dim=24)
    for clock in range(50):
        res = prober.probe(clock=clock)
        assert not res.detected and res.canary_mismatches == 0
        assert res.dispatches == 1  # structured probe only spent on divergence
        assert res.delta is None and res.clock == clock


def test_prober_localizes_injected_faults_and_rebases():
    rng = np.random.default_rng(9)
    R, C = 8, 8
    W = rng.standard_normal((32, 24)).astype(np.float32)
    believed = random_fault_map(10, R, C, 0.06)
    ok = ~believed.faulty  # mutated in place below: the live silicon
    prober = ChipProber(_silicon(W, ok), array_shape=(R, C), k_dim=32, chip=3)
    assert not prober.probe(clock=0).detected
    truth = believed.merge(random_fault_map(11, R, C, 0.1))
    new = truth.faulty & ~believed.faulty
    assert new.any()
    ok &= ~truth.faulty  # silicon degrades under the prober
    res = prober.probe(clock=1)
    assert res.detected and res.dispatches == 2 and res.chip == 3
    assert np.array_equal(res.delta, new)  # exact ground-truth localization
    assert res.delta_faults == int(new.sum())
    d = res.as_dict()
    assert d["detected"] and d["delta_faults"] == int(new.sum()) and d["chip"] == 3
    prober.rebase()  # recovery adopted the new map: clean again
    assert not prober.probe(clock=2).detected


def test_prober_validates_array_shape():
    with pytest.raises(ValueError, match="shape"):
        ChipProber(lambda x: (x, x[0]), array_shape=(0, 4), k_dim=8)


# ---------------------------------------------------------------------------
# health primitives + state machine
# ---------------------------------------------------------------------------


def test_ewma_seeds_on_first_sample():
    e = Ewma(alpha=0.5)
    assert e.update(10.0) == 10.0  # seeded, not pulled toward the 0.0 init
    assert e.update(0.0) == 5.0


def test_drift_detector_zero_in_warmup_then_flags_level_shift():
    d = DriftDetector(alpha=0.05, warmup=8)
    zs = [d.update(-1.0) for _ in range(20)]
    assert all(z == 0.0 for z in zs[:8]) and all(abs(z) < 1.0 for z in zs)
    assert abs(d.update(-9.0)) > 3.0  # a real level shift stands out


def _probe_result(detected, mism=0, cols=8, delta=None, chip=0):
    return ProbeResult(
        canary_mismatches=mism,
        syndrome_cols=np.full(cols, 1.0 if detected else 0.0),
        detected=detected,
        dispatches=2 if detected else 1,
        delta=delta,
        chip=chip,
    )


def test_chip_health_debounce_degrade_and_recover():
    cfg = HealthConfig(suspect_after=2, degraded_after=4, recover_after=3)
    h = ChipHealth(0, cfg)
    bad, clean = _probe_result(True, mism=5), _probe_result(False)
    assert h.observe_probe(bad, clock=0) is None  # one bad probe: no move
    assert h.state == HEALTHY
    moved = h.observe_probe(bad, clock=1)
    assert moved == (1, HEALTHY, SUSPECT, "probe")
    assert h.detections == 1 and h.detected_at == 1
    h.observe_probe(bad, clock=2)
    moved = h.observe_probe(bad, clock=3)  # 4th consecutive: degraded
    assert moved == (3, SUSPECT, DEGRADED, "probe") and h.state == DEGRADED
    for clock in (4, 5):
        assert h.observe_probe(clean, clock=clock) is None
    moved = h.observe_probe(clean, clock=6)  # 3rd consecutive clean
    assert moved == (6, DEGRADED, HEALTHY, "recovered")
    assert h.detections == 1  # recovery is not a second detection
    assert h.score.value < 1.0  # the bad stretch dented the score
    s = h.summary()
    assert [t["to"] for t in s["transitions"]] == [SUSPECT, DEGRADED, HEALTHY]


def test_chip_health_drift_transitions_only_when_opted_in():
    # default config: soft evidence moves the score, never the state
    h = ChipHealth(0, HealthConfig())
    for clock in range(30):
        lp = -1.0 if clock < 15 else -50.0
        assert h.observe_decode(clock=clock, mean_logprob=lp) is None
    assert h.state == HEALTHY
    # drift_z set: sustained drift raises suspect on its own
    h2 = ChipHealth(0, HealthConfig(drift_z=3.0, drift_after=3))
    moved = None
    for clock in range(30):
        lp = -1.0 if clock < 15 else -50.0
        moved = moved or h2.observe_decode(clock=clock, mean_logprob=lp)
    assert moved is not None and moved[2] == SUSPECT and moved[3] == "logit-drift"


def test_chip_health_backpressure_dents_score():
    h = ChipHealth(0, HealthConfig())
    h.observe_decode(clock=0, alloc_failures=0)
    base = h.score.value
    for clock in range(1, 8):
        h.observe_decode(clock=clock, alloc_failures=clock)  # failing every tick
    assert h.score.value < base and h.state == HEALTHY


def test_health_tracker_records_gauges_transitions_and_detections():
    rec = Recorder()
    t = HealthTracker(2, rec, config=HealthConfig(suspect_after=1), proc="fleet")
    delta = np.zeros((4, 4), bool)
    delta[1, 2] = True
    t.observe_probe(1, _probe_result(True, mism=2, delta=delta, chip=1), clock=5)
    assert t.state(1) == SUSPECT and t.state(0) == HEALTHY
    assert t.detections == 1 and t.detected_at(1) == 5
    assert np.array_equal(t.last_delta(1), delta)
    evs = rec.event_list()
    assert any(e.name == "health.transition" for e in evs)
    det = [e for e in evs if e.name == "fault.detected"]
    assert len(det) == 1 and det[0].args["chip"] == 1 and det[0].args["delta_faults"] == 1
    assert det[0].track == "chip1/health"  # per-chip swimlane
    assert rec.metrics.counter("health.detections").value == 1
    assert rec.metrics.gauge("health.chip1.state").value == 1
    before = len(rec.event_list())
    t.finalize()  # closing gauge samples for EVERY chip
    assert len(rec.event_list()) == before + 4
    s = t.summary()
    assert s["detections"] == 1 and s["states"] == {0: HEALTHY, 1: SUSPECT}
    assert s["chips"][1]["delta_coords"] == [[1, 2]]
    with pytest.raises(ValueError):
        HealthTracker(0)


# ---------------------------------------------------------------------------
# alert engine
# ---------------------------------------------------------------------------


def test_alert_rule_validation():
    with pytest.raises(ValueError, match="op"):
        AlertRule("r", "m", "!=", 1.0)
    with pytest.raises(ValueError, match="agg"):
        AlertRule("r", "m", ">", 1.0, agg="avg")
    with pytest.raises(ValueError, match="for_ticks"):
        AlertRule("r", "m", ">", 1.0, for_ticks=0)
    with pytest.raises(ValueError, match="field"):
        AlertRule("r", "m", ">", 1.0, field="p42")
    with pytest.raises(ValueError, match="severity"):
        AlertRule("r", "m", ">", 1.0, severity="meh")
    with pytest.raises(ValueError, match="duplicate"):
        AlertEngine(Recorder(), [AlertRule("r", "m", ">", 1.0),
                                 AlertRule("r", "m2", ">", 1.0)])


def test_alert_fire_resolve_debounce_and_instants():
    rec = Recorder()
    eng = AlertEngine(rec, [AlertRule("hot", "temp", ">", 10.0, for_ticks=2)])
    rec.gauge_set("temp", 50.0)
    assert eng.evaluate(clock=0) == []  # debounce holds the first breach
    assert eng.evaluate(clock=1) == ["hot"]
    assert eng.firing() == ["hot"]
    assert eng.evaluate(clock=2) == []  # still breaching, not NEWLY fired
    rec.gauge_set("temp", 1.0)
    eng.evaluate(clock=3)
    assert eng.firing() == [] and eng.fired_total == 1
    s = eng.summary()
    assert s["fired"] == ["hot"]  # history survives the resolve
    states = [e.args["state"] for e in rec.event_list() if e.name == "alert"]
    assert states == ["firing", "resolved"]
    assert rec.metrics.counter("alerts.fired").value == 1
    assert rec.metrics.counter("alerts.resolved").value == 1
    assert rec.metrics.gauge("alerts.firing").value == 0


def test_alert_glob_agg_histogram_fields_and_missing_metrics():
    rec = Recorder()
    eng = AlertEngine(rec, [
        AlertRule("fleet.suspect", "health.chip*.state", ">=", 1.0, agg="max"),
        AlertRule("slow", "lat", ">", 0.5, field="p99"),
        AlertRule("ghost", "no.such.metric", ">", 0.0),
    ])
    assert eng.evaluate(clock=0) == []  # no data is not a breach
    rec.sample("health.chip0.state", 0)
    rec.sample("health.chip1.state", 2)
    for v in [0.01] * 95 + [2.0] * 5:
        rec.observe("lat", v, buckets=(0.1, 1.0, 4.0))
    fired = eng.evaluate(clock=1)
    assert set(fired) == {"fleet.suspect", "slow"}  # max over glob; real p99
    assert "ghost" not in eng.summary()["fired"]


def test_detection_rules_are_probe_evidence_only():
    names = {r.metric for r in detection_rules()}
    assert names == {"health.chip*.state", "health.chip*.score",
                     "health.detections"}
    assert all(r.name.startswith(("health.", "detect."))
               for r in detection_rules())


def test_metrics_registry_items_returns_live_objects():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    c = reg.counter("n")
    c.inc(2)
    items = dict(reg.items())
    assert items["n"] is c  # live, not a serialized snapshot
    c.inc(3)
    assert items["n"].value == 5


# ---------------------------------------------------------------------------
# engine integration: zero token impact, bounded detection, fleet isolation
# ---------------------------------------------------------------------------


def _reqs(cfg, budget=16):
    return [
        Request(0, _prompt(cfg, 0, 6), max_new_tokens=budget),
        Request(1, _prompt(cfg, 1, 7), max_new_tokens=budget - 4),
        Request(2, _prompt(cfg, 2, 5), max_new_tokens=budget // 2, arrival=2),
    ]


def _engine(cfg, params, ctx, **kw):
    return ContinuousBatchingEngine(
        cfg, params, ctx, num_slots=2, page_size=4, num_pages=64,
        prefill_buckets=(8, 16), **kw,
    )


def test_probes_change_zero_tokens_and_never_false_positive(served_model):
    """Extends the PR-8 pin to the detection stack: probes + health + alerts
    enabled on healthy silicon change no sampled token, detect nothing, and
    fire no alert."""
    cfg, params = served_model
    ctx = from_fault_map(_zero_map(cfg.array_rows, cfg.array_cols))
    reqs = _reqs(cfg)
    off, _ = _engine(cfg, params, ctx).serve(reqs)
    rec = Recorder()
    eng = _engine(cfg, params, ctx, recorder=rec, probe_every=2,
                  alert_rules=detection_rules())
    on, stats = eng.serve(reqs)
    for rid in off:
        assert np.array_equal(off[rid].tokens, on[rid].tokens), rid
        np.testing.assert_array_equal(off[rid].logprobs, on[rid].logprobs)
    assert stats.probe_dispatches > 0
    assert eng.health.detections == 0 and eng.health.state(0) == HEALTHY
    assert eng.alerts.fired_total == 0
    spans = [e for e in rec.event_list() if e.name == "probe"]
    assert spans and all(e.track == "health" for e in spans)
    assert not any(e.args["detected"] for e in spans)
    assert validate_chrome_trace(chrome_trace(rec)) == []


def test_probe_programs_stay_out_of_the_serve_census(served_model):
    cfg, params = served_model
    ctx = from_fault_map(_zero_map(cfg.array_rows, cfg.array_cols))
    plain = _engine(cfg, params, ctx)
    probed = _engine(cfg, params, ctx, probe_every=2)
    plain.serve(_reqs(cfg, budget=6))
    probed.serve(_reqs(cfg, budget=6))
    assert probed.used_programs == plain.used_programs
    assert probed.compile_counts() == plain.compile_counts()


def test_continuous_injection_detected_bounded_and_localized(served_model):
    cfg, params = served_model
    R, C = cfg.array_rows, cfg.array_cols
    base = _zero_map(R, C)
    new_map = random_fault_map(42, R, C, 0.05)
    true_delta = new_map.faulty & ~base.faulty
    assert true_delta.any()
    hc = HealthConfig()
    probe_every, inject_at = 3, 4
    rec = Recorder()
    eng = _engine(cfg, params, from_fault_map(base), recorder=rec,
                  probe_every=probe_every, health_config=hc,
                  alert_rules=detection_rules())
    state = dict(injected=False)

    def on_step(clock):
        if clock >= inject_at and not state["injected"]:
            state["injected"] = True
            eng.set_silicon(from_fault_map(new_map))

    outs, _ = eng.serve(_reqs(cfg, budget=28), on_step=on_step)
    assert state["injected"] and len(outs) == 3
    assert eng.health.detections >= 1 and eng.health.state(0) != HEALTHY
    # detection latency: debounce needs suspect_after breaching probes, each
    # probe_every dispatches apart (+1 tick of probe/injection skew)
    assert eng.health.detected_at(0) is not None
    assert eng.health.detected_at(0) <= inject_at + probe_every * (hc.suspect_after + 1)
    delta = eng.health.last_delta(0)
    assert delta is not None and delta.any()
    assert not (delta & ~true_delta).any()  # localized: subset of true faults
    assert "detect.new_faults" in eng.alerts.summary()["fired"]
    assert any(e.name == "fault.detected" for e in rec.event_list())


def test_fleet_injection_isolated_to_victim_chip(served_model):
    from repro.fleet import ShardedFleetServeEngine

    cfg, params = served_model
    R, C = cfg.array_rows, cfg.array_cols
    base = [_zero_map(R, C), random_fault_map(1, R, C, 0.04)]
    victim = 1
    new_map = base[victim].merge(random_fault_map(99, R, C, 0.06))
    true_delta = new_map.faulty & ~base[victim].faulty
    assert true_delta.any()
    streams = [[
        Request(0, _prompt(cfg, 50 + 10 * c, 6), max_new_tokens=24),
        Request(1, _prompt(cfg, 51 + 10 * c, 5), max_new_tokens=12, arrival=1),
    ] for c in range(2)]

    def build(rules, rec=None):
        return ShardedFleetServeEngine(
            cfg, [params, params], [from_fault_map(m) for m in base],
            num_slots=2, page_size=4, num_pages=64, prefill_buckets=(8, 16),
            probe_every=3, alert_rules=rules, recorder=rec,
        )

    ctl = build(None)
    ctl_outs, _ = ctl.serve(streams)  # probes on, no injection: control arm
    assert ctl.health.detections == 0

    eng = build(detection_rules(), rec=Recorder())
    state = dict(injected=False)

    def on_step(clock):
        if clock >= 4 and not state["injected"]:
            state["injected"] = True
            eng.set_silicon(victim, from_fault_map(new_map))

    outs, _ = eng.serve(streams, on_step=on_step)
    assert eng.health.state(victim) != HEALTHY
    delta = eng.health.last_delta(victim)
    assert delta is not None and not (delta & ~true_delta).any()
    # isolation: the healthy chip neither false-positives nor changes tokens
    assert eng.health.state(0) == HEALTHY and eng.health.detections == 1
    assert eng.health.last_delta(0) is None
    for rid in ctl_outs[0]:
        assert np.array_equal(outs[0][rid].tokens, ctl_outs[0][rid].tokens)
    assert "detect.new_faults" in eng.alerts.summary()["fired"]


def test_set_silicon_validates(served_model):
    from repro.fleet import ShardedFleetServeEngine

    cfg, params = served_model
    R, C = cfg.array_rows, cfg.array_cols
    active = from_fault_map(_zero_map(R, C))
    lazy = _engine(cfg, params, healthy())
    with pytest.raises(ValueError, match="ACTIVE"):
        lazy.set_silicon(active)
    eng = _engine(cfg, params, active)
    with pytest.raises(ValueError, match="ACTIVE"):
        eng.set_silicon(healthy())
    with pytest.raises(ValueError, match="shape"):
        eng.set_silicon(from_fault_map(_zero_map(R * 2, C)))
    fleet = ShardedFleetServeEngine(
        cfg, [params, params], None, num_slots=2, page_size=4, num_pages=32,
    )
    with pytest.raises(ValueError, match="FaultMap context"):
        fleet.set_silicon(0, active)
    fleet2 = ShardedFleetServeEngine(
        cfg, [params, params], [active, active],
        num_slots=2, page_size=4, num_pages=32,
    )
    with pytest.raises(ValueError, match="chip"):
        fleet2.set_silicon(5, active)
    with pytest.raises(ValueError, match="shape"):
        fleet2.set_silicon(0, from_fault_map(_zero_map(R * 2, C)))
    with pytest.raises(ValueError):
        _engine(cfg, params, active, probe_every=0)


# ---------------------------------------------------------------------------
# satellite pins: pool flush, dropped-ring surfacing, CLI exit codes
# ---------------------------------------------------------------------------


def test_pool_monitor_flush_closes_the_series():
    from repro.obs.hooks import PoolMonitor

    rec = Recorder()
    mon = PoolMonitor(rec, PageAllocator(num_pages=8, page_size=4))
    mon.sample()
    mon.sample()  # identical state: deduped
    assert len([e for e in rec.event_list() if e.name == "kv.free_pages"]) == 1
    mon.flush()  # unconditional closing sample at serve end
    assert len([e for e in rec.event_list() if e.name == "kv.free_pages"]) == 2


def test_dropped_ring_surfaces_in_summary_jsonl_and_validator(tmp_path):
    rec = Recorder(capacity=4)
    for i in range(9):
        rec.instant(f"e{i}")
    s = rec.summary()
    assert s["events_dropped"] == 5
    assert s["ring"] == dict(capacity=4, len=4, dropped=5)
    assert any("overwrote" in w for w in s["warnings"])
    tr = chrome_trace(rec)
    assert tr["otherData"]["events_dropped"] == 5
    with pytest.warns(UserWarning, match="overwrote 5"):
        assert validate_chrome_trace(tr) == []
    p = tmp_path / "dropped.jsonl"
    write_jsonl(str(p), rec)
    back = read_jsonl(str(p))
    assert back["dropped"] == 5
    with pytest.warns(UserWarning, match="overwrote 5"):
        chrome_trace(back["events"], events_dropped=back["dropped"])
        assert validate_chrome_trace(
            chrome_trace(back["events"], events_dropped=back["dropped"])) == []


def test_obs_summary_check_exits_nonzero_on_fired_alerts(tmp_path, capsys):
    from repro.launch.obs import main as obs_main

    rec = Recorder()
    eng = AlertEngine(rec, [AlertRule("hot", "temp", ">", 1.0)])
    rec.gauge_set("temp", 5.0)
    eng.evaluate(clock=0)
    p = tmp_path / "alerted.jsonl"
    write_jsonl(str(p), rec)
    assert obs_main(["--summary", str(p)]) == 0  # summary alone reports
    out = json.loads(capsys.readouterr().out)
    assert out["alerts"]["fired"] == ["hot"]
    assert obs_main(["--summary", str(p), "--check"]) == 1  # gate trips
    clean = tmp_path / "clean.jsonl"
    write_jsonl(str(clean), Recorder())
    capsys.readouterr()
    assert obs_main(["--summary", str(clean), "--check"]) == 0
