"""Tests for the kernel autotuner stack (repro.tune): cache key
canonicalization, corrupt/stale cache degradation, the tuned_block seam's
resolution order and bitwise empty-cache identity, lint gating (rejected
candidates never reach pallas_call), the hillclimb search, an end-to-end
interpret-mode tune, and the capacity planner's kernel-VMEM reserve.

``hypothesis`` is optional (same contract as tests/test_core.py): without
it only the key round-trip property test skips."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised in offline environments

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis is not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.tune import cache as tc
from repro.tune.cache import (
    CACHE_VERSION,
    TuningCache,
    cache_key,
    parse_key,
    set_tuning_cache,
)
from repro.tune.search import hillclimb, lattice_neighbors, pow2_lattice
from repro.tune.tuner import (
    HEURISTIC_BLOCKS,
    KERNELS,
    lint_candidate,
    normalize_blocks,
    tune_kernel,
    tune_many,
)


@pytest.fixture
def isolated_cache():
    """Run a test against an empty process-wide cache; restore after."""
    prev = set_tuning_cache(TuningCache())
    try:
        yield tc.get_tuning_cache()
    finally:
        set_tuning_cache(prev)


# ---------------------------------------------------------------------------
# cache keys
# ---------------------------------------------------------------------------


def test_cache_key_canonicalizes_shape_order():
    a = cache_key("masked_matmul", dict(m=64, k=32, n=16), "float32", "interpret")
    b = cache_key("masked_matmul", dict(n=16, m=64, k=32), "float32", "interpret")
    assert a == b == "masked_matmul|k=32,m=64,n=16|float32|interpret"


def test_cache_key_round_trip_unit():
    key = cache_key("flash_attention", dict(b=2, sq=128, causal=1), "bfloat16", "tpu")
    kernel, shape, dtype, backend = parse_key(key)
    assert kernel == "flash_attention"
    assert shape == dict(b=2, sq=128, causal=1)
    assert (dtype, backend) == ("bfloat16", "tpu")
    assert cache_key(kernel, shape, dtype, backend) == key


def test_cache_key_rejects_bad_kernel_names():
    with pytest.raises(ValueError):
        cache_key("", dict(m=1), "float32", "cpu")
    with pytest.raises(ValueError):
        cache_key("a|b", dict(m=1), "float32", "cpu")


@settings(max_examples=100, deadline=None)
@given(
    kernel=st.sampled_from(sorted(KERNELS)),
    shape=st.dictionaries(
        st.sampled_from(["b", "m", "k", "n", "sq", "skv", "d", "l", "causal"]),
        st.integers(min_value=0, max_value=1 << 20),
        min_size=1,
        max_size=6,
    ),
    dtype=st.sampled_from(["float32", "bfloat16", "float16"]),
    backend=st.sampled_from(["interpret", "tpu", "cpu"]),
)
def test_cache_key_round_trip_property(kernel, shape, dtype, backend):
    key = cache_key(kernel, shape, dtype, backend)
    assert parse_key(key) == (kernel, shape, dtype, backend)


# ---------------------------------------------------------------------------
# cache persistence: corrupt / stale / malformed files degrade, never raise
# ---------------------------------------------------------------------------


def test_cache_save_load_round_trip(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = TuningCache()
    key = cache_key("mamba_scan", dict(b=1, l=64, d=16, n=4), "float32", "interpret")
    cache.put(key, dict(blocks=dict(bd=16, bl=32), vmem_bytes=1234))
    cache.save(path)
    loaded = TuningCache.load(path)
    assert loaded.entries == cache.entries
    assert loaded.source == path
    assert loaded.lookup_blocks(
        "mamba_scan", dict(b=1, l=64, d=16, n=4), "float32", "interpret"
    ) == dict(bd=16, bl=32)


def test_cache_load_missing_file_is_silently_empty(tmp_path, recwarn):
    cache = TuningCache.load(str(tmp_path / "nope.json"))
    assert len(cache) == 0
    assert len(recwarn) == 0


def test_cache_load_corrupt_json_warns_and_falls_back(tmp_path):
    path = tmp_path / "corrupt.json"
    path.write_text("{this is not json")
    with pytest.warns(UserWarning, match="unreadable"):
        cache = TuningCache.load(str(path))
    assert len(cache) == 0


def test_cache_load_stale_version_warns_and_falls_back(tmp_path):
    path = tmp_path / "stale.json"
    path.write_text(json.dumps({"version": CACHE_VERSION + 1, "entries": {
        "masked_matmul|m=8|float32|cpu": {"blocks": {"bm": 8}},
    }}))
    with pytest.warns(UserWarning, match="version"):
        cache = TuningCache.load(str(path))
    assert len(cache) == 0


def test_cache_load_drops_malformed_entries_keeps_good(tmp_path):
    good_key = cache_key("masked_matmul", dict(m=8, k=8, n=8, r=4, c=4), "float32", "cpu")
    path = tmp_path / "mixed.json"
    path.write_text(json.dumps({"version": CACHE_VERSION, "entries": {
        good_key: {"blocks": {"bm": 8}},
        "not-a-canonical-key": {"blocks": {"bm": 8}},
        "too|few|parts": {"blocks": {"bm": 8}},
    }}))
    with pytest.warns(UserWarning, match="malformed"):
        cache = TuningCache.load(str(path))
    assert list(cache.entries) == [good_key]


def test_cache_merge_other_wins():
    key = cache_key("decode_attention", dict(b=1, skv=64), "float32", "cpu")
    base = TuningCache(entries={key: dict(blocks=dict(bkv=32))})
    over = TuningCache(entries={key: dict(blocks=dict(bkv=64))})
    assert base.merge(over).entries[key]["blocks"] == dict(bkv=64)
    assert over.merge(base).entries[key]["blocks"] == dict(bkv=32)


def test_env_overlay_wins_over_default_table(tmp_path, monkeypatch):
    key = cache_key("masked_matmul", dict(m=8, k=8, n=8, r=4, c=4), "float32", "cpu")
    user = tmp_path / "user.json"
    user.write_text(json.dumps({"version": CACHE_VERSION, "entries": {
        key: {"blocks": {"bm": 8, "bn": 8, "bk": 8}},
    }}))
    monkeypatch.setenv(tc.ENV_CACHE_PATH, str(user))
    prev = set_tuning_cache(None)
    try:
        tc.reset_tuning_cache()
        cache = tc.get_tuning_cache()
        assert cache.entries[key]["blocks"] == {"bm": 8, "bn": 8, "bk": 8}
        assert str(user) in cache.source
    finally:
        set_tuning_cache(prev)


def test_lookup_blocks_rejects_malformed_blocks():
    key = cache_key("masked_matmul", dict(m=8), "float32", "cpu")
    for bad in (None, "big", dict(bm="not-an-int"), 7):
        cache = TuningCache(entries={key: dict(blocks=bad)})
        assert cache.lookup_blocks("masked_matmul", dict(m=8), "float32", "cpu") is None


# ---------------------------------------------------------------------------
# the tuned_block seam (kernels/common.py)
# ---------------------------------------------------------------------------


def test_tuned_block_empty_cache_returns_defaults(isolated_cache):
    from repro.kernels.common import tuned_block

    out = tuned_block(
        "masked_matmul", dict(m=64, k=64, n=64, r=16, c=16), jnp.float32,
        interpret=True, defaults=dict(bm=512, bn=512, bk=512),
    )
    assert out == dict(bm=512, bn=512, bk=512)


def test_tuned_block_resolution_order(isolated_cache):
    from repro.kernels.common import tuned_block

    shape = dict(m=64, k=64, n=64, r=16, c=16)
    key = cache_key("masked_matmul", shape, "float32", "interpret")
    isolated_cache.put(key, dict(blocks=dict(bm=32, bn=32, bk=32, bogus=99)))
    # cache hit overrides defaults — but only for known block params
    out = tuned_block(
        "masked_matmul", shape, jnp.float32,
        interpret=True, defaults=dict(bm=512, bn=512, bk=512),
    )
    assert out == dict(bm=32, bn=32, bk=32)
    # explicit caller overrides beat the cache, per parameter
    out = tuned_block(
        "masked_matmul", shape, jnp.float32,
        interpret=True, defaults=dict(bm=512, bn=512, bk=512),
        overrides=dict(bm=16, bn=None, bk=None),
    )
    assert out == dict(bm=16, bn=32, bk=32)
    # a different backend tag misses the cache entirely
    out = tuned_block(
        "masked_matmul", shape, jnp.float32,
        interpret=False, defaults=dict(bm=512, bn=512, bk=512),
    )
    assert out == dict(bm=512, bn=512, bk=512)


def test_empty_cache_ops_output_is_bitwise_heuristic(isolated_cache):
    """The acceptance pin: with an empty cache the wrappers must produce
    BITWISE-identical outputs to explicit heuristic block arguments."""
    from repro.kernels.masked_matmul.ops import masked_matmul

    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (64, 64))
    w = jax.random.normal(key, (64, 64))
    ok = (jax.random.uniform(key, (16, 16)) > 0.2).astype(jnp.float32)
    auto = masked_matmul(x, w, ok, interpret=True)
    explicit = masked_matmul(x, w, ok, bm=512, bn=512, bk=512, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))


def test_cached_blocks_change_launch_not_numerics(isolated_cache):
    """A cache hit must steer geometry (observable) while output stays
    within float tolerance of the heuristic launch."""
    from repro.kernels.flash_attention.ops import flash_attention

    shape = dict(b=1, hq=1, hkv=1, sq=128, skv=128, d=16, causal=1)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 1, 128, 16))
    k = jax.random.normal(ks[1], (1, 1, 128, 16))
    v = jax.random.normal(ks[2], (1, 1, 128, 16))
    base = flash_attention(q, k, v, interpret=True)
    key = cache_key("flash_attention", shape, "float32", "interpret")
    isolated_cache.put(key, dict(blocks=dict(bq=32, bkv=32)))
    tuned = flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(
        np.asarray(tuned), np.asarray(base), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# search primitives
# ---------------------------------------------------------------------------


def test_pow2_lattice_contents():
    assert pow2_lattice(64, lo=8) == [8, 16, 32, 64]
    # non-power-of-two dim rides along as its own (clamped) point
    assert pow2_lattice(96, lo=8) == [8, 16, 32, 64, 96]
    assert pow2_lattice(4, lo=8) == [4]


def test_lattice_neighbors_single_param_moves():
    lat = dict(bm=[8, 16, 32], bn=[8, 16, 32])
    moves = list(lattice_neighbors(dict(bm=16, bn=8), lat))
    assert dict(bm=32, bn=8) in moves  # up first
    assert dict(bm=8, bn=8) in moves
    assert dict(bm=16, bn=16) in moves
    assert all(sum(a != b for a, b in zip(m.values(), (16, 8))) == 1 for m in moves)


def test_hillclimb_greedy_first_improvement():
    lat = dict(x=[1, 2, 4, 8])
    score = lambda b: -b["x"]  # bigger x is better  # noqa: E731
    best, best_s, evals = hillclimb(
        dict(x=1), lambda b: lattice_neighbors(b, lat), score, max_evals=16
    )
    assert best == dict(x=8) and best_s == -8


def test_hillclimb_unscoreable_start_raises():
    with pytest.raises(ValueError):
        hillclimb(dict(x=1), lambda b: [], lambda b: None)


# ---------------------------------------------------------------------------
# lint gating: rejected candidates are never compiled / launched
# ---------------------------------------------------------------------------


def test_lint_rejected_candidates_never_reach_pallas_call(monkeypatch, isolated_cache):
    import repro.kernels.flash_attention.ops as fa_ops

    shape = dict(b=1, hq=1, hkv=1, sq=256, skv=256, d=8, causal=1)
    heur = normalize_blocks("flash_attention", shape, HEURISTIC_BLOCKS["flash_attention"])
    up = normalize_blocks("flash_attention", shape, dict(bq=256, bkv=256))
    _, heur_vmem = lint_candidate("flash_attention", shape, jnp.float32, heur)
    _, up_vmem = lint_candidate("flash_attention", shape, jnp.float32, up)
    assert up_vmem > heur_vmem
    limit = (heur_vmem + up_vmem) // 2  # heuristic passes, up-neighbors fail

    seen = []
    real = fa_ops.flash_attention

    def spy(q, k, v, *args, **kwargs):
        seen.append({p: kwargs.get(p) for p in ("bq", "bkv")})
        return real(q, k, v, *args, **kwargs)

    monkeypatch.setattr(fa_ops, "flash_attention", spy)
    res = tune_kernel(
        "flash_attention", shape, jnp.float32,
        iters=1, max_evals=6, interpret=True, vmem_limit_bytes=limit,
    )
    assert res.rejected > 0
    rejected = [tuple(sorted(r["blocks"].items())) for r in res.rejected_configs]
    launched = [tuple(sorted(s.items())) for s in seen]
    assert launched, "the tuner never ran the kernel at all"
    assert not set(rejected) & set(launched), (
        "a lint-rejected candidate was compiled/launched"
    )
    for blocks in seen:
        findings, _ = lint_candidate(
            "flash_attention", shape, jnp.float32, blocks, vmem_limit_bytes=limit
        )
        assert not findings


def test_heuristic_failing_lint_raises_before_any_launch(monkeypatch):
    called = []
    space = KERNELS["masked_matmul"]
    monkeypatch.setitem(
        KERNELS,
        "masked_matmul",
        dataclasses.replace(
            space,
            make_runner=lambda *a, **k: lambda blocks: called.append(blocks),
        ),
    )
    with pytest.raises(ValueError, match="fails the"):
        tune_kernel(
            "masked_matmul", dict(m=64, k=64, n=64, r=16, c=16),
            interpret=True, vmem_limit_bytes=1,  # everything over budget
        )
    assert not called


# ---------------------------------------------------------------------------
# end-to-end interpret-mode tune
# ---------------------------------------------------------------------------


def test_tune_masked_matmul_beats_or_ties_heuristic(isolated_cache):
    shape = dict(m=64, k=64, n=64, r=16, c=16)
    res = tune_kernel("masked_matmul", shape, iters=1, max_evals=6, interpret=True)
    assert res.best_s <= res.heuristic_s  # hillclimb is seeded at the heuristic
    assert res.speedup >= 1.0
    assert res.backend == "interpret"
    assert res.evaluated >= 1
    assert res.vmem_bytes > 0
    assert 0.0 <= res.roofline_fraction <= 1.0
    # the cache entry round-trips through the seam
    kernel, pshape, dtype, backend = parse_key(res.key)
    assert (kernel, pshape, dtype, backend) == (
        "masked_matmul", shape, "float32", "interpret"
    )
    isolated_cache.put(res.key, res.entry)
    assert isolated_cache.lookup_blocks(
        "masked_matmul", shape, "float32", "interpret"
    ) == res.best_blocks


def test_tune_many_fills_cache():
    cells = [("masked_matmul", dict(m=32, k=32, n=32, r=8, c=8))]
    results, cache = tune_many(cells, iters=1, max_evals=4, interpret=True)
    assert len(results) == 1 and len(cache) == 1
    assert cache.get(results[0].key)["blocks"] == results[0].best_blocks


# ---------------------------------------------------------------------------
# capacity planner's kernel-VMEM reserve
# ---------------------------------------------------------------------------


def test_kernel_vmem_reserve_sums_per_kernel_maxima():
    from repro.fleet.capacity import kernel_vmem_reserve

    cache = TuningCache()
    cache.put(cache_key("masked_matmul", dict(m=8), "float32", "cpu"),
              dict(blocks=dict(bm=8), vmem_bytes=100))
    cache.put(cache_key("masked_matmul", dict(m=16), "float32", "cpu"),
              dict(blocks=dict(bm=16), vmem_bytes=300))
    cache.put(cache_key("mamba_scan", dict(l=8), "float32", "cpu"),
              dict(blocks=dict(bl=8), vmem_bytes=50))
    assert kernel_vmem_reserve(cache) == 300 + 50
    assert kernel_vmem_reserve(TuningCache()) == 0


def test_suggest_population_size_reserve_is_opt_in_and_shrinks():
    from repro.configs import get_arch
    from repro.fleet.capacity import suggest_population_size

    cfg = get_arch("paper-mlp")
    member = int(cfg.param_count()) * 12
    cache = TuningCache()
    cache.put(cache_key("masked_matmul", dict(m=8), "float32", "cpu"),
              dict(blocks=dict(bm=8), vmem_bytes=4 * member))
    budget = 10 * member  # fits 10 members at headroom=1.0
    base = suggest_population_size(cfg, None, hbm_bytes=budget, headroom=1.0)
    reserved = suggest_population_size(
        cfg, None, hbm_bytes=budget, headroom=1.0,
        reserve_kernel_vmem=True, tuning_cache=cache,
    )
    assert base == 10
    assert reserved == 6  # (10 - 4) members after the kernel reserve
    # a reserve that eats the whole device is a hard error, not pop=0
    with pytest.raises(ValueError, match="reserve"):
        suggest_population_size(
            cfg, None, hbm_bytes=3 * member, headroom=1.0,
            reserve_kernel_vmem=True,
            tuning_cache=TuningCache(entries={
                cache_key("masked_matmul", dict(m=8), "float32", "cpu"):
                    dict(blocks=dict(bm=8), vmem_bytes=4 * member),
            }),
        )
