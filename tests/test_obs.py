"""Observability layer tests (repro.obs + its hook sites).

Contracts pinned here:
* the ring buffer is bounded: overwrite-oldest, oldest-first iteration,
  dropped accounting;
* histogram bucket edges use ``le`` semantics (a value equal to an edge
  lands in that bucket), NaN observations are skipped, and percentiles are
  exact until the raw-sample store truncates (then bucket-interpolated);
* NULL_RECORDER is falsy, un-enableable, and every record call on a
  disabled recorder is a no-op;
* Chrome/JSONL exporters round-trip losslessly and the schema validator
  actually rejects malformed traces;
* the PageAllocator guards double frees and foreign pages instead of
  corrupting the free list, and counts high-water/alloc-failures;
* instrumenting ContinuousBatchingEngine changes ZERO sampled tokens
  (bitwise, greedy) and emits a complete, well-nested request lifecycle
  even under mid-flight admissions into freed slots;
* the ``repro.launch.obs`` CLI self-check passes.
"""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduce_config
from repro.models import model as M
from repro.obs import (
    NULL_RECORDER,
    Recorder,
    RingBuffer,
    chrome_trace,
    jsonl_to_chrome,
    read_jsonl,
    validate_chrome_trace,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.metrics import TTFT_BUCKETS_S, Histogram, MetricsRegistry
from repro.serve import ContinuousBatchingEngine, PageAllocator, Request

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# ring buffer
# ---------------------------------------------------------------------------


def test_ring_buffer_wraparound():
    rb = RingBuffer(4)
    for i in range(3):
        rb.append(i)
    assert list(rb) == [0, 1, 2] and rb.dropped == 0
    for i in range(3, 10):
        rb.append(i)
    assert len(rb) == 4
    assert list(rb) == [6, 7, 8, 9]  # oldest-first after wrap
    assert rb.dropped == 6
    with pytest.raises(ValueError):
        RingBuffer(0)


def test_recorder_ring_is_bounded_and_drop_counted():
    rec = Recorder(capacity=8)
    for i in range(20):
        rec.instant(f"e{i}")
    assert len(rec.event_list()) == 8
    assert rec.events.dropped == 12
    assert [e.name for e in rec.event_list()] == [f"e{i}" for i in range(12, 20)]
    assert rec.summary()["events_dropped"] == 12


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_histogram_bucket_edges_le_semantics():
    h = Histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 9.0):
        h.observe(v)
    # v <= edge lands in that bucket: 1.0 joins [.., 1.0], 2.0 joins (1, 2],
    # 4.0 joins (2, 4], 9.0 overflows to +inf
    assert h.counts == [2, 2, 1, 1]
    assert h.count == 6 and h.min == 0.5 and h.max == 9.0
    h.observe(float("nan"))  # skipped, not counted anywhere
    assert h.count == 6
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("empty", buckets=())


def test_histogram_percentiles_exact_then_interpolated():
    h = Histogram("h", buckets=(10.0, 20.0, 40.0), max_samples=1000)
    vals = list(range(1, 101))
    for v in vals:
        h.observe(float(v))
    assert h.percentile(50) == pytest.approx(np.percentile(vals, 50))
    assert h.percentile(99) == pytest.approx(np.percentile(vals, 99))
    # truncate the raw store: percentile falls back to bucket interpolation,
    # staying inside the right bucket
    t = Histogram("t", buckets=(10.0, 20.0, 40.0), max_samples=10)
    for v in vals:
        t.observe(float(v))
    assert t.samples_truncated
    # interpolation stays close to truth: true p50 = 50.5, p99 = 99.01
    assert t.percentile(50) == pytest.approx(50.5, abs=2.0)
    assert t.percentile(99) == pytest.approx(99.0, abs=2.0)
    d = t.as_dict()
    assert d["samples_truncated"] and d["count"] == 100


def test_metrics_registry_type_conflicts_and_counter_monotonicity():
    reg = MetricsRegistry()
    reg.counter("n").inc(3)
    assert reg.counter("n").value == 3
    with pytest.raises(TypeError):
        reg.gauge("n")
    with pytest.raises(ValueError):
        reg.counter("n").inc(-1)
    with pytest.raises(ValueError):
        reg.histogram("h")  # new histogram needs buckets
    reg.histogram("h", buckets=(1.0,)).observe(0.5)
    assert reg.histogram("h").count == 1  # registered: buckets optional
    g = reg.gauge("g")
    g.set(5)
    g.set(2)
    assert g.value == 2 and g.high_water == 5
    g2 = reg.gauge("g2")
    g2.set(-3)  # first set pins high-water even when negative
    assert g2.high_water == -3


# ---------------------------------------------------------------------------
# recorder + null recorder
# ---------------------------------------------------------------------------


def test_null_recorder_is_falsy_noop_and_unenableable():
    assert not NULL_RECORDER
    NULL_RECORDER.instant("x")
    NULL_RECORDER.span("x", t0=0.0, t1=1.0)
    NULL_RECORDER.sample("x", 1.0)
    NULL_RECORDER.count("x")
    NULL_RECORDER.observe("x", 1.0, buckets=(1.0,))
    assert len(NULL_RECORDER.event_list()) == 0
    assert NULL_RECORDER.metrics.names() == []
    with pytest.raises(AttributeError):
        NULL_RECORDER.enabled = True


def test_disabled_recorder_records_nothing():
    rec = Recorder(enabled=False)
    assert not rec
    rec.instant("x")
    rec.count("x")
    with rec.timed("block"):
        pass
    assert len(rec.event_list()) == 0 and rec.metrics.names() == []


def test_recorder_timed_and_sample_mirror_gauge():
    rec = Recorder()
    with rec.timed("work", track="t"):
        pass
    (ev,) = rec.event_list()
    assert ev.kind == "span" and ev.name == "work" and ev.dur >= 0.0
    rec.sample("pool.free", 7, track="pages")
    rec.sample("pool.free", 3, track="pages")
    g = rec.metrics.gauge("pool.free")
    assert g.value == 3 and g.high_water == 7


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def _recorded():
    rec = Recorder()
    t0 = rec.now()
    rec.span("admit", proc="serve", track="slot0", t0=t0, t1=t0 + 0.01,
             args=dict(rid=0))
    rec.span("decode", proc="serve", track="slot0", t0=t0 + 0.01, t1=t0 + 0.03,
             args=dict(rid=0, tokens=3))
    rec.instant("retire", proc="serve", track="slot0", args=dict(rid=0))
    rec.sample("kv.free_pages", 5, proc="serve", track="pages")
    rec.span("fit_chunk", proc="train", track="engine", t0=t0, t1=t0 + 0.02)
    rec.count("serve.tokens_emitted", 3)
    rec.observe("serve.ttft_wall_s", 0.01, TTFT_BUCKETS_S)
    return rec


def test_chrome_trace_schema_and_lane_mapping():
    rec = _recorded()
    tr = chrome_trace(rec)
    assert validate_chrome_trace(tr) == []
    evs = tr["traceEvents"]
    procs = {e["args"]["name"]: e["pid"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert set(procs) == {"serve", "train"}  # one pid lane per proc
    tids = {(e["pid"], e["args"]["name"]) for e in evs
            if e["ph"] == "M" and e["name"] == "thread_name"}
    assert (procs["serve"], "slot0") in tids and (procs["serve"], "pages") in tids
    spans = [e for e in evs if e["ph"] == "X"]
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in spans)
    counters = [e for e in evs if e["ph"] == "C"]
    assert counters and all("value" in e["args"] for e in counters)


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace({"nope": 1})
    assert validate_chrome_trace(dict(traceEvents=[]))
    # span missing dur, counter missing value, unnamed pid
    bad = dict(traceEvents=[
        dict(ph="X", name="s", pid=1, tid=1, ts=0.0),
        dict(ph="C", name="c", pid=1, tid=1, ts=0.0, args={}),
    ])
    problems = validate_chrome_trace(bad)
    assert any("dur" in p for p in problems)
    assert any("value" in p for p in problems)
    assert any("process_name" in p for p in problems)
    assert validate_chrome_trace("/nonexistent/trace.json")


def test_jsonl_round_trip_and_convert(tmp_path):
    rec = _recorded()
    log = tmp_path / "run.jsonl"
    write_jsonl(str(log), rec)
    back = read_jsonl(str(log))
    assert back["meta"]["version"] == 1
    assert back["events"] == rec.event_list()  # lossless, order-preserving
    names = {m["name"]: m for m in back["metrics"]}
    assert names["serve.tokens_emitted"]["value"] == 3
    assert names["serve.ttft_wall_s"]["count"] == 1
    out = tmp_path / "run.trace.json"
    tr = jsonl_to_chrome(str(log), str(out))
    assert validate_chrome_trace(tr) == []
    assert validate_chrome_trace(str(out)) == []
    with open(out) as f:
        assert json.load(f)["traceEvents"]
    with pytest.raises(ValueError, match="meta"):
        empty = tmp_path / "empty.jsonl"
        empty.write_text("")
        read_jsonl(str(empty))


def test_write_chrome_trace_merges_recorders(tmp_path):
    serve_rec = _recorded()
    train_rec = Recorder()
    train_rec.instant("schedule", proc="train", track="scheduler")
    out = tmp_path / "merged.json"
    tr = write_chrome_trace(str(out), [serve_rec, train_rec])
    assert validate_chrome_trace(tr) == []
    pids = {e["args"]["name"] for e in tr["traceEvents"]
            if e["ph"] == "M" and e["name"] == "process_name"}
    assert pids == {"serve", "train"}


# ---------------------------------------------------------------------------
# page allocator guards + counters
# ---------------------------------------------------------------------------


def test_page_allocator_double_free_and_foreign_page_guards():
    a = PageAllocator(num_pages=8, page_size=4)
    chain = a.alloc(3)
    a.free(chain)
    with pytest.raises(ValueError, match="double free"):
        a.free(chain[:1])
    b = PageAllocator(num_pages=32, page_size=4)
    other = b.alloc(20)
    with pytest.raises(ValueError, match="foreign"):
        a.free(other[-1:])  # page id from a bigger pool: a never had it
    with pytest.raises(ValueError, match="foreign"):
        a.free([0])  # the reserved scratch page
    # the guards kept the free list intact: the full pool still allocates
    assert len(a.alloc(7)) == 7


def test_page_allocator_high_water_and_alloc_failures():
    a = PageAllocator(num_pages=6, page_size=4)  # 5 usable (page 0 reserved)
    assert a.high_water == 0 and a.alloc_failures == 0
    c1 = a.alloc(3)
    assert a.high_water == 3
    a.free(c1)
    assert a.high_water == 3  # monotone across frees
    assert not a.can_alloc(6)
    assert a.alloc_failures == 1  # backpressure stall counted
    with pytest.raises(MemoryError):
        a.alloc(6)
    assert a.alloc_failures == 2
    a.alloc(5)
    assert a.high_water == 5


# ---------------------------------------------------------------------------
# engine instrumentation: zero token impact + complete lifecycle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_model():
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    return cfg, params


def _trace_reqs(cfg):
    def prompt(seed, n):
        return np.asarray(jax.random.randint(
            jax.random.fold_in(KEY, seed), (n,), 0, cfg.vocab_size
        ))

    # 5 requests through 2 slots: rids 2-4 are admitted mid-flight into
    # freed slots; rid 3 exceeds the top bucket so it takes the chunked path
    return [
        Request(0, prompt(0, 6), max_new_tokens=4),
        Request(1, prompt(1, 7), max_new_tokens=10),
        Request(2, prompt(2, 8), max_new_tokens=5, arrival=2),
        Request(3, prompt(3, 20), max_new_tokens=3, arrival=4),
        Request(4, prompt(4, 6), max_new_tokens=6, arrival=4),
    ]


def _engine(cfg, params, recorder):
    return ContinuousBatchingEngine(
        cfg, params, num_slots=2, page_size=4, num_pages=32,
        prefill_buckets=(8, 16), chunk_size=8, recorder=recorder,
    )


def test_recorder_changes_zero_sampled_tokens(served_model):
    """THE observability pin: greedy token streams are bitwise identical
    with the recorder off and on — hooks are host-side only."""
    cfg, params = served_model
    reqs = _trace_reqs(cfg)
    off, _ = _engine(cfg, params, None).serve(reqs)
    rec = Recorder()
    on, _ = _engine(cfg, params, rec).serve(reqs)
    assert set(off) == set(on)
    for rid in off:
        assert np.array_equal(off[rid].tokens, on[rid].tokens), rid
        np.testing.assert_array_equal(off[rid].logprobs, on[rid].logprobs)
    assert len(rec.event_list()) > 0  # the instrumented run did record


def test_request_lifecycle_spans_nest_under_midflight_admissions(served_model):
    cfg, params = served_model
    reqs = _trace_reqs(cfg)
    rec = Recorder()
    outs, _ = _engine(cfg, params, rec).serve(reqs)
    evs = rec.event_list()
    rids = set(outs)

    def of(name):
        return [e for e in evs if e.name == name]

    admit = {e.args["rid"]: e for e in of("admit")}
    chunks = {}
    for e in of("chunk"):
        chunks.setdefault(e.args["rid"], []).append(e)
    decode = {e.args["rid"]: e for e in of("decode")}
    retire = {e.args["rid"]: e for e in of("retire")}
    enq = {e.args["rid"] for e in of("enqueue")}

    # complete lifecycle per retired rid; rid 3 chunked, the rest bucketed
    assert set(decode) == set(retire) == enq == rids
    assert set(admit) == rids - {3} and set(chunks) == {3}
    assert len(chunks[3]) == 3  # 20 tokens / chunk_size 8
    assert [c.args["final"] for c in sorted(chunks[3], key=lambda e: e.ts)] \
        == [False, False, True]

    for rid in rids:
        first = admit[rid] if rid in admit else sorted(
            chunks[rid], key=lambda e: e.ts)[-1]
        d = decode[rid]
        # nesting: admission closes before (or exactly when) decode begins,
        # decode closes before the retire instant
        assert first.ts + first.dur <= d.ts + 1e-9, rid
        assert d.ts + d.dur <= retire[rid].ts + 1e-9, rid
        assert d.args["tokens"] == len(outs[rid].tokens)

    # per-slot tracks never overlap: a slot serves one request at a time
    for track in {e.track for e in evs if e.track.startswith("slot")}:
        spans = sorted(
            (e for e in evs if e.track == track and e.kind == "span"
             and e.name in ("admit", "chunk", "decode")),
            key=lambda e: e.ts,
        )
        for a, b in zip(spans, spans[1:]):
            assert a.ts + a.dur <= b.ts + 1e-9, (track, a.name, b.name)

    # dispatch-level spans + pool samples + compile gauges landed too
    assert of("decode_step") and of("serve.end")
    assert any(e.kind == "sample" and e.name == "kv.free_pages" for e in evs)
    assert "serve.compiles.total" in rec.metrics
    # and the whole recording exports to a valid Chrome trace
    assert validate_chrome_trace(chrome_trace(rec)) == []


def test_recorder_histograms_cover_all_requests(served_model):
    cfg, params = served_model
    reqs = _trace_reqs(cfg)
    rec = Recorder()
    outs, stats = _engine(cfg, params, rec).serve(reqs)
    m = rec.summary()["metrics"]
    assert m["serve.ttft_wall_s"]["count"] == len(reqs)
    assert m["serve.queue_wait_steps"]["count"] == len(reqs)
    assert m["serve.requests_retired"]["value"] == len(reqs)
    assert m["serve.tokens_emitted"]["value"] == stats.emitted_tokens
    assert m["serve.decode_step_s"]["count"] == stats.decode_dispatches
    assert not math.isnan(m["serve.ttft_wall_s"]["p99"])


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_obs_cli_check_convert_summary(tmp_path, capsys):
    from repro.launch.obs import main as obs_main

    assert obs_main(["--check"]) == 0
    log = tmp_path / "run.jsonl"
    write_jsonl(str(log), _recorded())
    out = tmp_path / "run.trace.json"
    assert obs_main(["--convert", str(log), "--trace-out", str(out)]) == 0
    assert validate_chrome_trace(str(out)) == []
    capsys.readouterr()  # drain the check/convert chatter
    assert obs_main(["--summary", str(log)]) == 0
    summary = json.loads(capsys.readouterr().out)
    assert summary["events"] == 5
    assert summary["metrics"]["serve.tokens_emitted"]["value"] == 3
