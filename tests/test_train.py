"""Training-substrate tests: checkpoint roundtrip + elastic restore, crash
-recovery resume, straggler detection, optimizer behavior, data pipeline
determinism/seekability, LM trainability on the synthetic stream."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduce_config
from repro.core import from_fault_map, healthy, random_fault_map
from repro.data.synthetic import ClusterData, TokenStream
from repro.models import model as M
from repro.train import checkpoint as C
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import AdamWConfig, adamw_init, cosine_schedule
from repro.train.step import make_eval_step, make_train_step

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_token_stream_deterministic_and_seekable():
    s1 = TokenStream(97, 32, 4, seed=3)
    s2 = TokenStream(97, 32, 4, seed=3)
    b5a = s1.batch_at(5)
    b5b = s2.batch_at(5)
    assert np.array_equal(np.asarray(b5a["tokens"]), np.asarray(b5b["tokens"]))
    b6 = s1.batch_at(6)
    assert not np.array_equal(np.asarray(b5a["tokens"]), np.asarray(b6["tokens"]))
    # labels are next-token targets
    assert np.array_equal(
        np.asarray(b5a["labels"][:, :-1]), np.asarray(b5a["tokens"][:, 1:])
    )


def test_cluster_data_eval_split_differs():
    d = ClusterData(seed=0)
    tr = d.batch_at(0, 64)
    ev = d.batch_at(0, 64, split="eval")
    assert not np.array_equal(np.asarray(tr["x"]), np.asarray(ev["x"]))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    for step in (10, 20, 30, 40):
        C.save_checkpoint(str(tmp_path), step, tree, keep=2)
    assert C.latest_step(str(tmp_path)) == 40
    steps_on_disk = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps_on_disk == [30, 40]  # gc kept last 2
    step, flat, meta = C.load_checkpoint(str(tmp_path))
    restored = C.restore_sharded(tree, flat)
    assert np.array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Restore onto a different device layout (elastic rescale path)."""
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    C.save_checkpoint(str(tmp_path), 1, tree)
    _, flat, _ = C.load_checkpoint(str(tmp_path))
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))}
    restored = C.restore_sharded(tree, flat, sh)
    assert np.array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)


def test_async_checkpointer(tmp_path):
    saver = C.AsyncCheckpointer(str(tmp_path))
    saver.save(7, {"x": jnp.ones(3)})
    saver.wait()
    assert C.latest_step(str(tmp_path)) == 7


# ---------------------------------------------------------------------------
# loop: resume after crash, straggler log
# ---------------------------------------------------------------------------


def test_loop_crash_recovery(tmp_path):
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    ocfg = AdamWConfig(learning_rate=1e-3)
    opt = adamw_init(params, ocfg)
    stream = TokenStream(cfg.vocab_size, 16, 2, seed=0)
    base_step = make_train_step(cfg, ocfg, remat="none")
    crashes = {"armed": True}

    def flaky_step(p, o, b, ctx):
        if crashes["armed"] and int(o["count"]) == 7:
            crashes["armed"] = False
            raise RuntimeError("simulated node failure")
        return base_step(p, o, b, ctx)

    lc = LoopConfig(
        total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=5, eval_every=100,
        log_every=100, max_restarts=2,
    )
    params2, opt2, state = run_training(
        lc, train_step=flaky_step, batch_at=stream.batch_at,
        params=params, opt_state=opt, ctx=healthy(),
    )
    assert state.restarts == 1
    assert state.step == 12
    assert int(opt2["count"]) == 12  # optimizer state restored + continued


def test_loop_resume_from_disk(tmp_path):
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    ocfg = AdamWConfig(learning_rate=1e-3)
    opt = adamw_init(params, ocfg)
    stream = TokenStream(cfg.vocab_size, 16, 2, seed=0)
    step = make_train_step(cfg, ocfg, remat="none")
    lc = LoopConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=3, eval_every=100, log_every=100)
    run_training(lc, train_step=step, batch_at=stream.batch_at, params=params, opt_state=opt, ctx=healthy())
    # second invocation picks up at 6 and continues to 9
    lc2 = LoopConfig(total_steps=9, ckpt_dir=str(tmp_path), ckpt_every=3, eval_every=100, log_every=100)
    _, opt2, state = run_training(
        lc2, train_step=step, batch_at=stream.batch_at, params=params, opt_state=opt, ctx=healthy()
    )
    assert state.step == 9
    assert int(opt2["count"]) == 9


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_cosine_schedule_shape():
    s = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(s(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(s(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(s(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)


def test_bf16_moments_close_to_fp32():
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    stream = TokenStream(cfg.vocab_size, 16, 2, seed=0)
    outs = {}
    for mdt in ("float32", "bfloat16"):
        ocfg = AdamWConfig(learning_rate=1e-3, moment_dtype=mdt)
        step = make_train_step(cfg, ocfg, remat="none")
        p, o = params, adamw_init(params, ocfg)
        for i in range(3):
            p, o, m = step(p, o, stream.batch_at(i), healthy())
        outs[mdt] = float(m["loss"])
    assert outs["bfloat16"] == pytest.approx(outs["float32"], rel=1e-2)


# ---------------------------------------------------------------------------
# FAT actually recovers accuracy (end-to-end learning check)
# ---------------------------------------------------------------------------


def test_lm_fat_recovers_accuracy():
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    ocfg = AdamWConfig(learning_rate=3e-3)
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=1, noise=0.02)
    step = jax.jit(make_train_step(cfg, ocfg, remat="none"))
    ev = jax.jit(make_eval_step(cfg, remat="none"))
    opt = adamw_init(params, ocfg)
    for i in range(120):
        params, opt, m = step(params, opt, stream.batch_at(i), healthy())
    healthy_acc = float(ev(params, stream.batch_at(10_000), healthy())["accuracy"])
    assert healthy_acc > 0.5, f"healthy model failed to learn: {healthy_acc}"
    fm = random_fault_map(5, cfg.array_rows, cfg.array_cols, 0.25)
    ctx = from_fault_map(fm)
    faulty_acc = float(ev(params, stream.batch_at(10_000), ctx)["accuracy"])
    opt = adamw_init(params, ocfg)
    for i in range(60):
        params, opt, m = step(params, opt, stream.batch_at(1000 + i), ctx)
    fat_acc = float(ev(params, stream.batch_at(10_000), ctx)["accuracy"])
    assert fat_acc > faulty_acc + 0.02, (healthy_acc, faulty_acc, fat_acc)
