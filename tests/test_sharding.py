"""Sharding rules engine + (subprocess) small-mesh dry-run integration.

The rules tests run in-process on 1 device (resolution is pure logic); the
mesh tests spawn subprocesses with --xla_force_host_platform_device_count
so the main test process keeps its single-device backend.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_arch
from repro.launch.policy import launch_policy
from repro.configs.base import SHAPES
from repro.launch.sharding import make_rules_for_mesh, resolve_spec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fake_mesh_ctx(cfg, shape=(4, 4), axes=("data", "model"), **kw):
    devs = np.array([jax.devices()[0]] * int(np.prod(shape))).reshape(shape)
    mesh = Mesh(devs, axes)  # single real device repeated: fine for rules logic
    return make_rules_for_mesh(cfg, mesh, **kw)


def test_divisibility_fallback_heads():
    cfg = get_arch("smollm-135m")  # 9 heads, head_dim 64
    ctx = _fake_mesh_ctx(cfg, (4, 4))
    # wq: (embed, qkv) with qkv unit = 64; 9 heads % 4 != 0 -> replicate
    spec = resolve_spec(("embed", "qkv"), (576, 9 * 64), ctx)
    assert spec == P()
    # mlp dim 1536 % 4 == 0 -> sharded
    spec = resolve_spec(("embed", "mlp"), (576, 1536), ctx)
    assert spec == P(None, "model")


def test_heads_shard_when_divisible():
    cfg = get_arch("llama3-405b")  # 128 heads
    ctx = _fake_mesh_ctx(cfg, (4, 4), fsdp=True)
    spec = resolve_spec(("embed", "qkv"), (16384, 128 * 128), ctx)
    assert spec == P("data", "model")
    # kv heads = 8, divisible by 4
    spec = resolve_spec(("embed", "kv"), (16384, 8 * 128), ctx)
    assert spec == P("data", "model")


def test_no_axis_used_twice():
    cfg = get_arch("qwen3-0.6b")
    ctx = _fake_mesh_ctx(cfg, (4, 4))
    # batch takes data; a second data-wanting dim must fall back
    spec = resolve_spec(("batch", "batch"), (8, 8), ctx)
    assert spec in (P("data"), P(("data",)))


def test_kv_cache_seq_fallback():
    """kv_heads indivisible -> the cache shards its seq axis instead."""
    cfg = get_arch("llama3-405b")  # kv=8 vs model=16
    ctx = _fake_mesh_ctx(cfg, (2, 16), ("data", "model"))
    spec = resolve_spec(
        ("layers", "batch", "kv_heads", "kv_seq", None),
        (126, 128, 8, 32768, 128),
        ctx,
    )
    assert spec == P(None, "data", None, "model")


def test_expert_parallelism_when_divisible():
    cfg = get_arch("llama4-maverick-400b-a17b")  # 128 experts
    ctx = _fake_mesh_ctx(cfg, (2, 16), ("data", "model"), fsdp=True)
    spec = resolve_spec(("expert", "embed", "mlp"), (128, 5120, 8192), ctx)
    assert spec == P("model", "data")  # EP + FSDP; mlp falls back (model used)
    cfg2 = get_arch("mixtral-8x22b")  # 8 experts -> TP inside experts
    ctx2 = _fake_mesh_ctx(cfg2, (2, 16), ("data", "model"), fsdp=True)
    spec2 = resolve_spec(("expert", "embed", "mlp"), (8, 6144, 16384), ctx2)
    assert spec2 == P(None, "data", "model")


def test_multi_pod_batch_axes():
    cfg = get_arch("phi3-mini-3.8b")
    ctx = _fake_mesh_ctx(cfg, (2, 2, 4), ("pod", "data", "model"), fsdp=True)
    spec = resolve_spec(("batch", "seq"), (256, 4096), ctx)
    assert spec == P(("pod", "data"))


def test_seq_carry_rule_only_when_enabled():
    cfg = get_arch("llama3-405b")
    on = _fake_mesh_ctx(cfg, (4, 4), fsdp=True, seq_shard=True)
    off = _fake_mesh_ctx(cfg, (4, 4), fsdp=True, seq_shard=False)
    assert resolve_spec(("batch", "seq_carry", "embed"), (256, 4096, 16384), on) == P(
        "data", "model"
    )
    assert resolve_spec(("batch", "seq_carry", "embed"), (256, 4096, 16384), off) == P(
        "data"
    )


def test_fleet_mesh_rules_resolve_inside_pop_slice():
    """On a 2-D ("pop", "model") fleet mesh with the pop axis reserved, the
    model rules resolve per pop slice: 'model' shards within the slice,
    rules naming absent axes ('data') fall back to replication (= broadcast
    along "pop"), and the reserved axis is never assigned even when a rule
    names it explicitly."""
    cfg = get_arch("smollm-135m")
    ctx = _fake_mesh_ctx(
        cfg, (4, 2), ("pop", "model"), fsdp=False, reserved_axes=("pop",)
    )
    assert ctx.reserved_axes == ("pop",)
    # mlp 1536 % 2 == 0 -> sharded over the slice's model axis
    assert resolve_spec(("embed", "mlp"), (576, 1536), ctx) == P(None, "model")
    # 'batch' candidates name only 'data', absent from the fleet mesh ->
    # replicated (broadcast along "pop"), not a KeyError
    assert resolve_spec(("batch", "embed"), (8, 576), ctx) == P()
    # a rule naming the reserved pop axis is skipped, later candidates win
    ctx.rules["mlp"] = ("pop", "model")
    assert resolve_spec(("embed", "mlp"), (576, 1536), ctx) == P(None, "model")
    ctx.rules["mlp"] = ("pop",)
    assert resolve_spec(("embed", "mlp"), (576, 1536), ctx) == P()


def test_classifier_axes_resolve_on_fleet_mesh():
    from repro.models.classifier import classifier_param_axes

    cfg = get_arch("paper-mlp")
    ctx = _fake_mesh_ctx(
        cfg, (4, 2), ("pop", "model"), fsdp=False, reserved_axes=("pop",)
    )
    axes = classifier_param_axes(cfg)
    assert set(axes) == {f"{k}{i}" for k in "wb" for i in range(cfg.num_layers)}
    # hidden weights shard their output dim; the contraction dim stays
    # replicated (full-dot compute, gathered activations)
    assert resolve_spec(axes["w0"], (32, cfg.d_ff), ctx) == P(None, "model")
    assert resolve_spec(axes["b0"], (cfg.d_ff,), ctx) == P("model")
    last = cfg.num_layers - 1
    assert resolve_spec(axes[f"w{last}"], (cfg.d_ff, cfg.vocab_size), ctx) == P(None, "model")


def test_launch_policy_scaling():
    big = launch_policy(get_arch("llama3-405b"), SHAPES["train_4k"])
    assert big.fsdp and big.seq_shard and big.microbatches > 1
    assert big.moment_dtype == "bfloat16"
    small = launch_policy(get_arch("smollm-135m"), SHAPES["train_4k"])
    assert not small.fsdp and small.microbatches == 1
    dec = launch_policy(get_arch("qwen3-0.6b"), SHAPES["decode_32k"])
    assert dec.attn_impl == "dense" and dec.remat == "none"


# ---------------------------------------------------------------------------
# subprocess small-mesh integration (marked slow)
# ---------------------------------------------------------------------------

_SUB = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=16'
import jax, numpy as np, json
from jax.sharding import Mesh
import repro.launch.dryrun_lib as D
def small_mesh(multi_pod=False):
    shape = (2,2,4) if multi_pod else (4,4)
    axes = ('pod','data','model') if multi_pod else ('data','model')
    devs = np.array(jax.devices()[:int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axes)
D.make_production_mesh = small_mesh
info = D.run_cell('%s', '%s', multi_pod=%s)
print('RESULT', json.dumps(dict(status=info['status'], err=info.get('error',''),
      coll=info.get('collectives',{}).get('total_bytes', -1))))
"""


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch,shape,mp",
    [
        ("smollm_135m", "train_4k", False),
        ("qwen3_0_6b", "decode_32k", False),
        ("hymba_1_5b", "long_500k", False),
        ("smollm_135m", "train_4k", True),
    ],
)
def test_small_mesh_cell_compiles(arch, shape, mp):
    code = _SUB % (arch, shape, mp)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=420,
    )
    lines = [l for l in out.stdout.splitlines() if l.startswith("RESULT")]
    assert lines, f"no result: {out.stdout[-800:]} {out.stderr[-800:]}"
    res = json.loads(lines[0][len("RESULT "):])
    assert res["status"] == "ok", res["err"]
    assert res["coll"] >= 0
