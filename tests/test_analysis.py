"""Golden-violation + clean-stack tests for the static program linter
(``repro.analysis``): each pass must fire the right finding code on a
deliberately broken program and stay silent on the shipped entry points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    EntryTraceModel,
    FakeMesh,
    KernelLaunch,
    ProgramSpec,
    ShardingEntry,
    TraceRequest,
    analyze_stack,
    check_launch,
    default_baseline_path,
    lint_donation,
    lint_recompile,
    lint_sharding,
    load_baseline,
    synthetic_trace,
)
from repro.analysis.kernelgeom import (
    decode_attention_launch,
    flash_attention_launch,
    masked_matmul_launch,
)
from repro.analysis.recompile import census
from repro.configs import get_arch, reduce_config
from repro.core.masking import FaultContext
from repro.launch.sharding import MeshContext


def _codes(findings):
    return [f.code for f in findings]


# ---------------------------------------------------------------------------
# donation pass (DON001)
# ---------------------------------------------------------------------------


def _loop_spec(*, donate: bool) -> ProgramSpec:
    """A tiny serve-loop shape: a big carried buffer + a small accumulator."""
    donate_argnums = (0,) if donate else ()
    fn = jax.jit(
        lambda buf, acc: (buf + 1.0, acc + buf.sum()),
        donate_argnums=donate_argnums,
    )
    return ProgramSpec(
        name="golden.loop",
        fn=fn,
        args=(
            jax.ShapeDtypeStruct((256, 256), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        ),
        carried=frozenset({0}),
        arg_names=("buf", "acc"),
    )


def test_donation_flags_undonated_loop_buffer():
    findings, stats = lint_donation(_loop_spec(donate=False))
    assert _codes(findings) == ["DON001"]
    f = findings[0]
    assert f.subject == "buf"
    assert f.bytes == 256 * 256 * 4
    assert stats["hlo_alias_table"]  # verified against the compiled module
    assert stats["donated_fraction"] < 1.0


def test_donation_clean_after_donating():
    findings, stats = lint_donation(_loop_spec(donate=True))
    assert findings == []
    assert stats["donated_fraction"] == 1.0
    # the aliasing is real, not just a jit-level flag: the optimized HLO
    # module's own input_output_alias table covers the carried buffer
    assert stats["hlo_alias_table"]
    assert stats["aliased_params"] >= 1


def test_donation_fix_measurably_reduces_undonated_bytes():
    """The shipped fused decode donates its KV cache; stripping the
    donation (the pre-fix engine) must regress the analyzer report."""
    from repro.launch.specs import cache_struct, param_struct
    from repro.serve.engine import ServeEngine, make_sample_decode

    cfg = reduce_config(get_arch("smollm-135m"))
    eng = ServeEngine(cfg, None, max_len=64)
    params_s, _ = param_struct(cfg)
    cache_s = cache_struct(cfg, 2, 64)
    args = (
        params_s,
        jax.ShapeDtypeStruct((2, cfg.vocab_size), jnp.float32),
        cache_s,
        jax.ShapeDtypeStruct((2,), jnp.uint32),
        FaultContext(ok=None, mode="none"),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    carried = frozenset({1, 2, 3})

    def spec(fn, name):
        return ProgramSpec(name=name, fn=fn, args=args, carried=carried)

    # reduced-config KV leaves are ~32 KiB: lint at the analyze_stack default
    min_bytes = 1 << 14
    pre_fix = jax.jit(make_sample_decode(cfg, pad_id=0))  # no donate_argnums
    f_pre, s_pre = lint_donation(spec(pre_fix, "prefix.sample_decode"),
                                 min_bytes=min_bytes)
    f_now, s_now = lint_donation(spec(eng._sample_decode, "serve.sample_decode"),
                                 min_bytes=min_bytes)

    assert "DON001" in _codes(f_pre)
    cache_bytes = sum(
        int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        for leaf in jax.tree_util.tree_leaves(cache_s)
    )
    assert s_pre["undonated_carried_bytes"] >= cache_bytes
    assert f_now == []
    assert s_now["undonated_carried_bytes"] == 0
    assert s_now["donated_fraction"] == 1.0


# ---------------------------------------------------------------------------
# recompile pass (RCP001/RCP002)
# ---------------------------------------------------------------------------


def test_recompile_flags_length_polymorphic_jit():
    raw = EntryTraceModel(
        "golden.raw_prefill",
        lambda r: ("prefill", r.prompt_len),
        dims=("prompt_len",),
    )
    findings, stats = lint_recompile([raw], synthetic_trace())
    assert "RCP001" in _codes(findings)
    assert findings[0].subject == "prompt_len"
    # the mixed-length trace alone also blows the signature budget
    assert "RCP002" in _codes(findings)


def test_recompile_clean_when_bucketed():
    bucketed = EntryTraceModel(
        "golden.bucketed_prefill",
        lambda r: ("prefill", 64 * -(-r.prompt_len // 64)),
        dims=("prompt_len",),
    )
    findings, stats = lint_recompile([bucketed], synthetic_trace())
    assert findings == []
    assert stats["golden.bucketed_prefill"]["sweep_prompt_len"] < 12


def test_signature_function_matches_real_jit_cache():
    """The analytic census must agree with jax's own compile cache: one
    compile per distinct tokens length, repeats are cache hits."""
    fn = jax.jit(lambda t: t.sum())
    lens = [4, 8, 8, 12, 4, 16]
    for n in lens:
        fn(jnp.zeros((n,), jnp.int32))
    model = EntryTraceModel(
        "golden.cache", lambda r: (r.prompt_len,), dims=("prompt_len",)
    )
    trace = [TraceRequest(prompt_len=n) for n in lens]
    assert census(model, trace)["signatures"] == fn._cache_size()


# ---------------------------------------------------------------------------
# sharding pass (SHD001/SHD002)
# ---------------------------------------------------------------------------


def _entry(rules, axes_leaf, shape, *, reserved=(), engine_axes=(), units=None):
    mctx = MeshContext(
        mesh=FakeMesh.of(pop=2, model=4),
        rules=rules,
        units=units or {},
        reserved_axes=reserved,
    )
    return ShardingEntry(
        name="golden.shard",
        mctx=mctx,
        axes={"w": axes_leaf},
        structs={"w": jax.ShapeDtypeStruct(shape, jnp.float32)},
        engine_axes=engine_axes,
    )


def test_sharding_flags_lost_replication():
    # "model"=4 exists and is live for "qkv", but 1002 % 4 != 0: the rule
    # engine silently replicates 4 MiB — exactly what SHD001 is for
    entry = _entry({"qkv": ("model",)}, ("embed", "qkv"), (1024, 1002))
    findings, stats = lint_sharding([entry])
    assert _codes(findings) == ["SHD001"]
    assert findings[0].subject == "w"
    assert stats["golden.shard"]["replicated"] == 1


def test_sharding_replication_by_design_is_clean():
    # no rule at all for the leaf's axes -> replication is intentional
    entry = _entry({}, ("embed", "qkv"), (1024, 1002))
    findings, _ = lint_sharding([entry])
    assert findings == []


def test_sharding_small_replicated_leaf_below_threshold_is_clean():
    entry = _entry({"qkv": ("model",)}, ("embed", "qkv"), (16, 10))
    findings, _ = lint_sharding([entry])
    assert findings == []


def test_sharding_flags_engine_owned_axis_use():
    # a rule that grabs the fleet's "pop" axis inside a shard_map lane
    entry = _entry(
        {"member": ("pop",)}, ("member", None), (8, 4), engine_axes=("pop",)
    )
    findings, _ = lint_sharding([entry])
    assert _codes(findings) == ["SHD002"]


def test_sharding_reserved_axis_resolves_clean():
    # same rules, but the entry declares "pop" reserved the way
    # fleet/serve.py builds its MeshContext: resolution skips the axis
    entry = _entry(
        {"member": ("pop",)}, ("member", None), (8, 4),
        reserved=("pop",), engine_axes=("pop",),
    )
    findings, _ = lint_sharding([entry])
    assert findings == []


# ---------------------------------------------------------------------------
# kernel geometry pass (KRN001-KRN004)
# ---------------------------------------------------------------------------


def test_kernel_flags_non_dividing_block():
    bad = KernelLaunch(
        kernel="golden.matmul",
        dims=(100, 64),
        blocks=(33, 64),
        vmem_blocks=(((33, 64), jnp.float32),),
    )
    findings = check_launch(bad)
    assert _codes(findings) == ["KRN001"]
    assert findings[0].subject == "axis0"


def test_kernel_flags_mask_period_incompatibility():
    bad = KernelLaunch(
        kernel="golden.masked",
        dims=(96,),
        blocks=(48,),
        vmem_blocks=(((48, 48), jnp.float32),),
        mask_blocks=((48, 32),),  # 48 not a multiple of period 32
    )
    assert _codes(check_launch(bad)) == ["KRN001"]


def test_kernel_flags_vmem_overflow():
    bad = KernelLaunch(
        kernel="golden.fat",
        dims=(4096,),
        blocks=(4096,),
        vmem_blocks=(((4096, 4096), jnp.float32),),  # 64 MiB resident
    )
    findings = check_launch(bad)
    assert _codes(findings) == ["KRN002"]
    assert findings[0].bytes == 4096 * 4096 * 4


def test_kernel_flags_degenerate_grid():
    bad = KernelLaunch(
        kernel="golden.zero",
        dims=(128,),
        blocks=(0,),
        vmem_blocks=(),
    )
    assert _codes(check_launch(bad)) == ["KRN003"]


def test_kernel_flags_batched_context_leak():
    cfg = reduce_config(get_arch("smollm-135m"))
    pop_ctx = FaultContext(
        ok=jax.ShapeDtypeStruct((4, cfg.array_rows, cfg.array_cols), jnp.float32),
        mode="fap",
    )
    launch = masked_matmul_launch(
        256, cfg.d_model, cfg.d_ff, (cfg.array_rows, cfg.array_cols), ctx=pop_ctx
    )
    assert "KRN004" in _codes(check_launch(launch))


def test_kernel_builders_clean_at_stack_shapes():
    cfg = get_arch("smollm-135m")
    mask = (cfg.array_rows, cfg.array_cols)
    chip = FaultContext(
        ok=jax.ShapeDtypeStruct(mask, jnp.float32), mode="pallas"
    )
    launches = [
        masked_matmul_launch(2048, cfg.d_model, cfg.d_ff, mask, ctx=chip),
        flash_attention_launch(8, cfg.num_heads, cfg.num_kv_heads, 2048, 2048,
                               cfg.resolved_head_dim),
        decode_attention_launch(8, cfg.num_heads, cfg.num_kv_heads, 4096,
                                cfg.resolved_head_dim),
        decode_attention_launch(4, cfg.num_heads, cfg.num_kv_heads, 4096,
                                cfg.resolved_head_dim, paged=True, page_size=8),
    ]
    for launch in launches:
        assert check_launch(launch) == [], launch.kernel


# ---------------------------------------------------------------------------
# the shipped stack, end to end
# ---------------------------------------------------------------------------


def test_shipped_stack_cheap_passes_have_only_baselined_findings():
    report = analyze_stack(passes=("recompile", "sharding", "kernels"))
    baseline = load_baseline(default_baseline_path())
    new = report.new_vs_baseline(baseline)
    assert new == [], [f.key for f in new]
    # bucketed prefill closed the recompile hazards (ROADMAP item 1): the
    # census must stay clean — a prompt-length-shaped signature reappearing
    # here is a regression, not a baselining candidate ...
    assert not [k for k in report.keys() if k.startswith("RCP")]
    # ... and every kernel launch is geometrically clean
    assert not [f for f in report.findings if f.code.startswith("KRN")]


def test_shipped_stack_donation_pass_is_fully_donated():
    report = analyze_stack(passes=("donation",))
    assert [f for f in report.findings if f.code == "DON001"] == []
    stats = report.passes["donation"]
    assert stats["donated_fraction"] == 1.0
    for name, entry in stats["entries"].items():
        assert entry["hlo_alias_table"], name
        assert entry["undonated_carried_bytes"] == 0, name
    # the population sweep must NOT donate (params0 is reused by the caller)
    assert stats["entries"]["population.fit_run"]["carried_bytes"] == 0


# ---------------------------------------------------------------------------
# donation regressions: token streams are unchanged under donate_argnums
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_setup():
    from repro.models import model as M

    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_serve_engine_donated_tokens_match_undonated_reference(small_setup):
    from repro.models import model as M
    from repro.serve.engine import ServeEngine, make_sample_decode

    cfg, params = small_setup
    eng = ServeEngine(cfg, params, max_len=32)
    prompts = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0, cfg.vocab_size)
    out = eng.generate(prompts, max_new_tokens=8)

    # reference loop with NO donation anywhere
    ref_step = jax.jit(make_sample_decode(cfg, pad_id=0))
    logits, cache = jax.jit(
        lambda p, b, ctx: M.prefill(p, b, cfg, ctx, cache_len=32)
    )(params, {"tokens": prompts}, eng.ctx)
    cur, key = logits, jax.random.PRNGKey(0)
    toks, lps = [], []
    for _ in range(8):
        nxt, tok_lp, cur, cache, key = ref_step(
            params, cur, cache, key, eng.ctx, jnp.float32(0.0)
        )
        toks.append(np.asarray(nxt))
        lps.append(np.asarray(tok_lp))
    np.testing.assert_array_equal(
        np.asarray(out.tokens[:, 8:]), np.stack(toks, axis=1)
    )
    np.testing.assert_allclose(
        np.asarray(out.logprobs), np.stack(lps, axis=1), rtol=1e-6, atol=1e-6
    )


def test_continuous_engine_donated_tokens_match_undonated_reference(small_setup):
    from repro.serve.continuous import ContinuousBatchingEngine, Request
    from repro.serve.engine import make_sample_decode

    cfg, params = small_setup
    kw = dict(num_slots=2, page_size=8, num_pages=16, max_pages_per_seq=4)
    reqs = [
        Request(0, np.arange(5) % cfg.vocab_size, max_new_tokens=6),
        Request(1, (np.arange(9) * 3) % cfg.vocab_size, max_new_tokens=4),
        Request(2, (np.arange(7) * 5) % cfg.vocab_size, max_new_tokens=8, arrival=2),
    ]

    eng = ContinuousBatchingEngine(cfg, params, **kw)
    outs, _ = eng.serve(reqs)

    ref = ContinuousBatchingEngine(cfg, params, **kw)
    ref._sample_decode = jax.jit(make_sample_decode(cfg, pad_id=0))
    ref._packed_admit = jax.jit(ref._packed_admit_fn)
    ref._prefill_chunk = jax.jit(ref._prefill_chunk_fn)
    ref_outs, _ = ref.serve(reqs)

    assert set(outs) == set(ref_outs) == {0, 1, 2}
    for rid in outs:
        np.testing.assert_array_equal(outs[rid].tokens, ref_outs[rid].tokens)
        np.testing.assert_allclose(
            outs[rid].logprobs, ref_outs[rid].logprobs, rtol=1e-6, atol=1e-6
        )
