"""Serving-path tests: engine determinism, fault-context effect, FAM vs
FAP mitigation quality (the [12] baseline comparison)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduce_config
from repro.core import (
    apply_fam,
    fam_permutation,
    from_fault_map,
    healthy,
    masked_weight,
    random_fault_map,
)
from repro.models import model as M
from repro.models.classifier import classifier_loss
from repro.serve.engine import ServeEngine
from repro.train.fat_trainer import ClassifierFATTrainer

KEY = jax.random.PRNGKey(0)


def test_engine_greedy_deterministic():
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_len=48)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    a = eng.generate(prompts, max_new_tokens=8)
    b = eng.generate(prompts, max_new_tokens=8)
    assert np.array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    assert a.tokens.shape == (2, 16)
    assert bool(jnp.all(jnp.isfinite(a.logprobs)))


def test_engine_fault_context_changes_output():
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    healthy_out = ServeEngine(cfg, params, healthy(), max_len=48).generate(
        prompts, max_new_tokens=8
    )
    fm = random_fault_map(0, cfg.array_rows, cfg.array_cols, 0.3)
    faulty_out = ServeEngine(cfg, params, from_fault_map(fm), max_len=48).generate(
        prompts, max_new_tokens=8
    )
    assert not np.array_equal(np.asarray(healthy_out.tokens), np.asarray(faulty_out.tokens))


def test_engine_fused_greedy_matches_unfused_reference():
    """The fused sample+decode step (one dispatch per token) must reproduce
    the unfused host-side log_softmax/argmax loop token-for-token."""
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_len=48)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    fused = eng.generate(prompts, max_new_tokens=8)

    # unfused reference: separate dispatches for log_softmax/argmax/decode
    logits, cache = eng._prefill(params, {"tokens": prompts}, eng.ctx)
    cur, toks, lps = logits, [prompts], []
    for _ in range(8):
        lp = jax.nn.log_softmax(cur.astype(jnp.float32), axis=-1)
        nxt = jnp.argmax(lp, axis=-1)
        lps.append(jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0])
        toks.append(nxt[:, None])
        step_logits, cache = eng._decode(params, nxt[:, None], cache, eng.ctx)
        cur = step_logits[:, 0]
    ref_tokens = jnp.concatenate(toks, axis=1)
    ref_lps = jnp.stack(lps, axis=1)

    assert np.array_equal(np.asarray(fused.tokens), np.asarray(ref_tokens))
    np.testing.assert_allclose(
        np.asarray(fused.logprobs), np.asarray(ref_lps), rtol=1e-5, atol=1e-5
    )


def test_engine_temperature_sampling_varies_with_key():
    cfg = reduce_config(get_arch("smollm-135m"))
    params, _ = M.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_len=48)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    a = eng.generate(prompts, max_new_tokens=8, temperature=1.0, key=jax.random.PRNGKey(1))
    b = eng.generate(prompts, max_new_tokens=8, temperature=1.0, key=jax.random.PRNGKey(2))
    assert not np.array_equal(np.asarray(a.tokens), np.asarray(b.tokens))


def test_fam_mitigation_not_worse_than_fap():
    """SalvageDNN [12]: saliency-driven mapping should match or beat plain
    FAP *without retraining* on deployed accuracy (averaged over maps)."""
    cfg = get_arch("paper-mlp")
    tr = ClassifierFATTrainer(cfg, pretrain_steps=400, eval_batches=2)
    evals = tr._evals
    fam_wins, n = 0.0, 6
    for seed in range(n):
        fm = random_fault_map(seed, 32, 32, 0.25)
        ok = jnp.asarray(fm.ok_mask)

        def masked_params(use_fam):
            out = {}
            for k, v in tr.base_params.items():
                if k.startswith("w"):
                    if use_fam:
                        perm = fam_permutation(np.asarray(v), fm)
                        out[k] = apply_fam(v, ok, perm)
                    else:
                        out[k] = masked_weight(v, ok)
                else:
                    out[k] = v
            return out

        def acc(params):
            return float(
                np.mean([classifier_loss(params, b, cfg)[1]["accuracy"] for b in evals])
            )

        a_fap = acc(masked_params(False))
        a_fam = acc(masked_params(True))
        fam_wins += a_fam - a_fap
    # mean advantage of FAM over FAP should be non-negative
    assert fam_wins / n > -0.01, f"FAM mean delta {fam_wins / n:.4f}"
