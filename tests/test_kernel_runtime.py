"""Unit tests for the shared kernel-runtime layer (repro.kernels.common):
the JAX-version compiler-params shim, pad/unpad geometry, backend
autodetection, and the per-dtype tolerance table.

``hypothesis`` is optional (same contract as tests/test_core.py): without
it only the ``choose_block`` property tests skip."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised in offline environments

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis is not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.kernels import common


# ---------------------------------------------------------------------------
# compiler-params shim (both JAX API spellings + dict fallback)
# ---------------------------------------------------------------------------


class _NewStyleParams:
    """Stands in for pltpu.CompilerParams (newer JAX)."""

    def __init__(self, dimension_semantics=None, **kw):
        self.dimension_semantics = dimension_semantics
        self.extra = kw


class _OldStyleParams:
    """Stands in for pltpu.TPUCompilerParams (JAX 0.4.x/0.5.x)."""

    def __init__(self, dimension_semantics=None, **kw):
        self.dimension_semantics = dimension_semantics
        self.extra = kw


def test_shim_prefers_new_spelling(monkeypatch):
    fake = types.SimpleNamespace(
        CompilerParams=_NewStyleParams, TPUCompilerParams=_OldStyleParams
    )
    monkeypatch.setattr(common, "pltpu", fake)
    out = common.tpu_compiler_params(dimension_semantics=("parallel", "arbitrary"))
    assert isinstance(out, _NewStyleParams)
    assert out.dimension_semantics == ("parallel", "arbitrary")


def test_shim_falls_back_to_old_spelling(monkeypatch):
    fake = types.SimpleNamespace(TPUCompilerParams=_OldStyleParams)
    monkeypatch.setattr(common, "pltpu", fake)
    out = common.tpu_compiler_params(
        dimension_semantics=("parallel",), vmem_limit_bytes=1 << 20
    )
    assert isinstance(out, _OldStyleParams)
    assert out.dimension_semantics == ("parallel",)
    assert out.extra == {"vmem_limit_bytes": 1 << 20}


def test_shim_dict_fallback_when_neither_exists(monkeypatch):
    monkeypatch.setattr(common, "pltpu", types.SimpleNamespace())
    out = common.tpu_compiler_params(dimension_semantics=("arbitrary",))
    assert out == {"mosaic": {"dimension_semantics": ("arbitrary",)}}


def test_shim_works_against_installed_jax():
    # whatever the installed JAX calls it, the shim must build something
    out = common.tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
    assert out is not None


# ---------------------------------------------------------------------------
# pad / unpad round-trips on non-block-multiple shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,b", [(1, 8), (7, 8), (8, 8), (33, 32), (100, 64)])
def test_pad_to_multiple(n, b):
    p = common.pad_to_multiple(n, b)
    assert p >= n and p % b == 0 and p - n < b
    assert common.pad_amount(n, b) == p - n


@pytest.mark.parametrize("shape,targets", [((33, 100), {0: 64, 1: 128}), ((5, 7, 3), {1: 8})])
def test_pad_axes_round_trip(shape, targets):
    x = jnp.asarray(np.random.default_rng(0).normal(size=shape), jnp.float32)
    xp = common.pad_axes_to(x, targets)
    for axis in range(x.ndim):
        assert xp.shape[axis] == targets.get(axis, x.shape[axis])
    sl = tuple(slice(0, s) for s in shape)
    np.testing.assert_array_equal(np.asarray(xp[sl]), np.asarray(x))
    # padded region is zero
    assert float(jnp.sum(jnp.abs(xp))) == pytest.approx(float(jnp.sum(jnp.abs(x))), rel=1e-6)


def test_pad_axis_rejects_shrinking():
    x = jnp.ones((8, 8))
    with pytest.raises(ValueError):
        common.pad_axis_to(x, 0, 4)


def test_choose_block_respects_period():
    assert common.choose_block(256, 64) == 64
    assert common.choose_block(48, 64) == 48  # clamped to dim
    # block below the mask period that doesn't divide it -> snap to period
    assert common.choose_block(256, 24, multiple_of=32) == 32
    # block that divides the period stays
    assert common.choose_block(256, 16, multiple_of=32) == 16
    # incompatible block above the period -> the period multiple with the
    # least padding of dim (24 pads 100 -> 120; 96 would pad to 192)
    assert common.choose_block(100, 512, multiple_of=24) == 24
    # on equal padding, prefer the largest compatible block
    assert common.choose_block(96, 512, multiple_of=24) == 96


@settings(max_examples=200, deadline=None)
@given(
    dim=st.integers(min_value=1, max_value=4096),
    requested=st.integers(min_value=1, max_value=8192),
    period=st.integers(min_value=1, max_value=256),
)
def test_choose_block_properties(dim, requested, period):
    """The tuner normalizes every lattice point through choose_block, so its
    contract is load-bearing: the result is positive, period-compatible
    (divides the period or is a multiple of it), never exceeds
    max(dim, period), and when it lands on a period multiple it is the
    minimal-padding choice with LARGEST-block tie-breaking."""
    b = common.choose_block(dim, requested, multiple_of=period)
    assert b >= 1
    assert b <= max(dim, period)
    if period > 1:
        assert period % b == 0 or b % period == 0
    # never bigger than asked for, except when snapping up to the period
    assert b <= max(min(requested, dim), period)
    b0 = max(1, min(requested, dim))
    if period > 1 and b0 >= period and b0 % period:
        pad = common.pad_to_multiple(dim, b) - dim
        for c in range(period, b0 + 1, period):
            pad_c = common.pad_to_multiple(dim, c) - dim
            assert (pad_c, -c) >= (pad, -b), (
                f"candidate {c} (pad {pad_c}) beats chosen {b} (pad {pad})"
            )


@settings(max_examples=100, deadline=None)
@given(
    dim=st.integers(min_value=1, max_value=2048),
    requested=st.integers(min_value=1, max_value=4096),
    period=st.integers(min_value=1, max_value=128),
)
def test_choose_block_idempotent(dim, requested, period):
    """Re-normalizing a chosen block is a fixed point (what lets the tuner
    memoize candidates by their normalized key)."""
    b = common.choose_block(dim, requested, multiple_of=period)
    assert common.choose_block(dim, b, multiple_of=period) == b


def test_masked_matmul_dim_exceeds_non_power_of_two_period():
    """dim > mask period but not a period multiple must pad, not raise."""
    from repro.kernels.masked_matmul.ops import masked_matmul
    from repro.kernels.masked_matmul.ref import masked_matmul_ref

    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (64, 96))
    w = jax.random.normal(key, (96, 100))
    ok = (jax.random.uniform(key, (24, 24)) > 0.2).astype(jnp.float32)
    out = masked_matmul(x, w, ok, interpret=True)
    assert out.shape == (64, 100)
    common.assert_close(out, masked_matmul_ref(x, w, ok), jnp.float32)


def test_grid_for():
    assert common.grid_for((64, 128), (32, 32)) == (2, 4)
    with pytest.raises(ValueError):
        common.grid_for((65, 128), (32, 32))
    with pytest.raises(ValueError):
        common.grid_for((64,), (32, 32))


def test_kernel_pad_round_trip_non_multiple_shapes():
    """ops-level check: ragged shapes go through pad -> kernel -> unpad."""
    from repro.kernels.masked_matmul.ops import masked_matmul
    from repro.kernels.masked_matmul.ref import masked_matmul_ref

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (19, 70))
    w = jax.random.normal(key, (70, 45))
    ok = (jax.random.uniform(key, (8, 8)) > 0.2).astype(jnp.float32)
    out = masked_matmul(x, w, ok, bm=16, bn=16, bk=16, interpret=True)
    assert out.shape == (19, 45)
    common.assert_close(out, masked_matmul_ref(x, w, ok), jnp.float32)


def test_mamba_pad_round_trip_non_multiple_shapes():
    from repro.kernels.mamba_scan.ops import selective_scan
    from repro.kernels.mamba_scan.ref import selective_scan_ref

    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    b, l, d, n = 2, 37, 11, 4  # neither l nor d block-multiples
    u = jax.random.normal(ks[0], (b, l, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, d)))
    a = -jnp.exp(jax.random.normal(ks[2], (d, n)))
    bb = jax.random.normal(ks[3], (b, l, n))
    c = jax.random.normal(ks[4], (b, l, n))
    dd = jax.random.normal(ks[5], (d,))
    yr, hr = selective_scan_ref(u, dt, a, bb, c, dd)
    yk, hk = selective_scan(u, dt, a, bb, c, dd, bd=8, bl=16, interpret=True)
    assert yk.shape == yr.shape and hk.shape == hr.shape
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), rtol=2e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# backend autodetection
# ---------------------------------------------------------------------------


def test_resolve_interpret_autodetects_cpu():
    # the test suite pins JAX_PLATFORMS=cpu, so autodetection must pick
    # interpret mode and explicit flags must pass through untouched
    assert common.is_tpu_backend() is False
    assert common.resolve_interpret(None) is True
    assert common.resolve_interpret(True) is True
    assert common.resolve_interpret(False) is False


def test_resolve_interpret_compiles_on_tpu(monkeypatch):
    monkeypatch.setattr(common.jax, "default_backend", lambda: "tpu")
    assert common.is_tpu_backend() is True
    assert common.resolve_interpret(None) is False
    assert common.resolve_interpret(True) is True


def test_kernel_entrypoint_autodetects_interpret_on_cpu():
    """Calling the raw pallas entry point with no interpret flag must run on
    a CPU-only host (previously: hard default interpret=False -> crash)."""
    from repro.kernels.masked_matmul.masked_matmul import masked_matmul_pallas
    from repro.kernels.masked_matmul.ref import masked_matmul_ref

    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (32, 32))
    w = jax.random.normal(key, (32, 32))
    ok = (jax.random.uniform(key, (16, 16)) > 0.3).astype(jnp.float32)
    out = masked_matmul_pallas(x, w, ok, bm=16, bn=16, bk=16)
    common.assert_close(out, masked_matmul_ref(x, w, ok), jnp.float32)


# ---------------------------------------------------------------------------
# analytic VMEM model
# ---------------------------------------------------------------------------


def test_vmem_footprint_single_buffered_default():
    blocks = [((128, 128), jnp.float32), ((128, 128), jnp.bfloat16)]
    assert common.vmem_footprint(blocks) == 128 * 128 * 4 + 128 * 128 * 2


def test_vmem_footprint_double_buffered_doubles_io_blocks_only():
    io = ((64, 64), jnp.float32)  # 2-tuple: DMA'd in/out block
    scratch = ((64, 64), jnp.float32, False)  # accumulator, never DMA'd
    single = common.vmem_footprint([io, scratch])
    double = common.vmem_footprint([io, scratch], double_buffered=True)
    assert single == 2 * 64 * 64 * 4
    # only the io block doubles: 2x io + 1x scratch
    assert double == 3 * 64 * 64 * 4


def test_vmem_footprint_explicit_io_flag_matches_two_tuple():
    a = common.vmem_footprint([((32, 8), jnp.float32)], double_buffered=True)
    b = common.vmem_footprint([((32, 8), jnp.float32, True)], double_buffered=True)
    assert a == b == 2 * 32 * 8 * 4


def test_kernelgeom_launches_mark_scratch_non_io():
    """The launch builders must tag accumulator scratch with is_io=False so
    the tuner's double-buffered bound doesn't double-count it."""
    from repro.analysis.kernelgeom import masked_matmul_launch

    launch = masked_matmul_launch(256, 256, 256, (32, 32), bm=64, bn=64, bk=64)
    flags = [e[2] if len(e) > 2 else True for e in launch.vmem_blocks]
    assert False in flags and True in flags
    assert common.vmem_footprint(
        launch.vmem_blocks, double_buffered=True
    ) < 2 * common.vmem_footprint(launch.vmem_blocks)


# ---------------------------------------------------------------------------
# tolerance table
# ---------------------------------------------------------------------------


def test_dtype_tol_table():
    rtol32, atol32 = common.dtype_tol(jnp.float32)
    rtol16, atol16 = common.dtype_tol(jnp.bfloat16)
    assert rtol16 > rtol32
    assert atol32 == pytest.approx(rtol32 * 10)
    # unknown dtypes fall back to the float32 default
    assert common.dtype_tol(jnp.int8)[0] == rtol32


def test_assert_close_uses_dtype_tolerance():
    a = jnp.ones((4, 4), jnp.bfloat16)
    b = a * (1.0 + 1e-3)  # within bf16 tolerance, outside fp32 tolerance
    common.assert_close(a, b, jnp.bfloat16)
    with pytest.raises(AssertionError):
        common.assert_close(
            jnp.ones((4, 4)), jnp.ones((4, 4)) * 1.01, jnp.float32
        )
