"""Per-kernel correctness: shape/dtype sweeps against the pure-jnp oracles,
executed with interpret=True (Pallas kernel body runs on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.common import dtype_tol
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.masked_matmul.ops import masked_matmul
from repro.kernels.masked_matmul.ref import masked_matmul_ref
from repro.kernels.mamba_scan.mamba_scan import selective_scan_pallas
from repro.kernels.mamba_scan.ref import selective_scan_ref, selective_step_ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dtype_tol(dtype)[0]


# ---------------------------------------------------------------------------
# masked_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n,r,c,bm,bn,bk",
    [
        (64, 128, 96, 16, 16, 32, 32, 32),
        (8, 256, 256, 32, 32, 64, 64, 64),
        (128, 64, 64, 64, 64, 64, 64, 64),  # block == period
        (33, 100, 77, 16, 16, 32, 32, 32),  # ragged -> padding path
        (16, 512, 128, 128, 64, 64, 64, 256),  # block > period rows
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_matmul_sweep(m, k, n, r, c, bm, bn, bk, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (m, k), dtype)
    w = jax.random.normal(k2, (k, n), dtype)
    ok = (jax.random.uniform(k3, (r, c)) > 0.1).astype(jnp.float32)
    ref = masked_matmul_ref(x, w, ok)
    out = masked_matmul(x, w, ok, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref, np.float32),
        rtol=_tol(dtype),
        atol=_tol(dtype) * 10,
    )


def test_masked_matmul_zero_mask_kills_everything():
    x = jax.random.normal(KEY, (32, 64))
    w = jax.random.normal(KEY, (64, 32))
    ok = jnp.zeros((16, 16), jnp.float32)
    out = masked_matmul(x, w, ok, bm=32, bn=32, bk=32, interpret=True)
    assert float(jnp.max(jnp.abs(out))) == 0.0


def test_masked_matmul_batch_dims():
    x = jax.random.normal(KEY, (2, 3, 64))
    w = jax.random.normal(KEY, (64, 32))
    ok = (jax.random.uniform(KEY, (16, 16)) > 0.2).astype(jnp.float32)
    out = masked_matmul(x, w, ok, bm=32, bn=32, bk=32, interpret=True)
    ref = masked_matmul_ref(x, w, ok)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,d,causal,window,off",
    [
        (2, 4, 2, 128, 128, 32, True, None, 0),
        (1, 8, 2, 256, 256, 64, True, 64, 0),  # sliding window
        (2, 2, 2, 128, 128, 32, False, None, 0),  # encoder
        (1, 4, 4, 1, 256, 32, True, None, 255),  # decode
        (2, 4, 2, 100, 100, 32, True, None, 0),  # padding path
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, sq, skv, d, causal, window, off, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=window, q_offset=off)
    out = flash_attention(
        q, k, v, causal=causal, window=window, q_offset=off, bq=64, bkv=64,
        interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref, np.float32),
        rtol=_tol(dtype),
        atol=_tol(dtype) * 5,
    )


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,l,d,n,bd,bl",
    [(2, 64, 32, 8, 16, 16), (1, 128, 64, 16, 64, 32), (3, 32, 16, 4, 16, 32)],
)
def test_selective_scan_sweep(b, l, d, n, bd, bl):
    ks = jax.random.split(KEY, 6)
    u = jax.random.normal(ks[0], (b, l, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, d)))
    a = -jnp.exp(jax.random.normal(ks[2], (d, n)))
    bb = jax.random.normal(ks[3], (b, l, n))
    c = jax.random.normal(ks[4], (b, l, n))
    dd = jax.random.normal(ks[5], (d,))
    yr, hr = selective_scan_ref(u, dt, a, bb, c, dd)
    yk, hk = selective_scan_pallas(u, dt, a, bb, c, dd, bd=bd, bl=bl, interpret=True)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), rtol=2e-5, atol=1e-4)


def test_selective_step_matches_scan():
    b, l, d, n = 2, 16, 8, 4
    ks = jax.random.split(KEY, 6)
    u = jax.random.normal(ks[0], (b, l, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, d)))
    a = -jnp.exp(jax.random.normal(ks[2], (d, n)))
    bb = jax.random.normal(ks[3], (b, l, n))
    c = jax.random.normal(ks[4], (b, l, n))
    dd = jax.random.normal(ks[5], (d,))
    yr, hr = selective_scan_ref(u, dt, a, bb, c, dd)
    h = jnp.zeros((b, d, n))
    for i in range(l):
        y, h = selective_step_ref(h, u[:, i], dt[:, i], a, bb[:, i], c[:, i], dd)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr[:, i]), rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# int8-KV decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,hq,hkv,skv,d,valid",
    [(2, 4, 2, 256, 32, 256), (1, 8, 2, 256, 64, 200), (2, 2, 2, 128, 32, 1),
     (1, 4, 4, 192, 32, 100)],
)
def test_decode_attention_int8kv(b, hq, hkv, skv, d, valid):
    from repro.kernels.decode_attention.ops import decode_attention, quantize_kv
    from repro.kernels.decode_attention.ref import decode_attention_ref

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d))
    k = jax.random.normal(ks[1], (b, hkv, skv, d))
    v = jax.random.normal(ks[2], (b, hkv, skv, d))
    ki, ksc = quantize_kv(k)
    vi, vsc = quantize_kv(v)
    ref = decode_attention_ref(q, ki, ksc, vi, vsc, kv_valid_len=valid)
    out = decode_attention(q, ki, ksc, vi, vsc, valid, bkv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
    # quantization error vs fp attention over the valid prefix stays small
    fp = attention_ref(q, k[:, :, :valid], v[:, :, :valid], causal=False, window=None)
    assert float(jnp.max(jnp.abs(out - fp))) < 5e-2


def test_quantize_kv_roundtrip_error():
    from repro.kernels.decode_attention.ops import dequantize_kv, quantize_kv

    k = jax.random.normal(KEY, (2, 2, 64, 32))
    ki, sc = quantize_kv(k)
    assert ki.dtype == jnp.int8
    back = dequantize_kv(ki, sc)
    rel = float(jnp.max(jnp.abs(back - k)) / jnp.max(jnp.abs(k)))
    assert rel < 0.01
