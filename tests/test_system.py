"""End-to-end system tests: the full eFAT pipeline (Steps 1-4) over a small
fleet, exercising resilience measurement, Algo-2 grouping, consolidated FAT
and per-chip deployment evaluation — the paper's Fig. 7 flow."""
import pytest

from repro.configs import get_arch
from repro.core import EFAT, EFATConfig, correlated_family
from repro.train.fat_trainer import ClassifierFATTrainer


@pytest.fixture(scope="module")
def trainer():
    return ClassifierFATTrainer(get_arch("paper-mlp"), pretrain_steps=400, eval_batches=2)


def test_efat_end_to_end(trainer):
    constraint = trainer.baseline_accuracy - 0.05
    fleet = correlated_family(5, 8, 32, 32, base_rate=0.06, idio_rate=0.02)
    ef = EFAT(
        trainer,
        EFATConfig(
            constraint=constraint, max_fr=0.25, max_interval=0.06, step_ratio=0.8,
            repeats=2, max_steps=250, m_comparisons=4, k_iterations=2,
        ),
    )
    result = ef.run(fleet)
    # every chip served exactly once
    chips = sorted(c for link in result.plan.links for c in link)
    assert chips == list(range(8))
    # correlated fleet -> Step 3 actually fused some maps
    assert result.plan.num_jobs < 8
    # most chips meet the constraint after consolidated FAT
    assert result.satisfied_fraction >= 0.6, result.summary()
    # eFAT cost never exceeds individual per-chip selection (Algo 2 invariant)
    indiv = ef.run_baseline(fleet, "individual")
    assert result.total_retraining_steps <= indiv.total_retraining_steps + 1e-6


def test_relaxed_constraint_cheaper(trainer):
    """Paper Fig. 3: relaxing the constraint reduces selected amounts."""
    from repro.core import fault_rate_list
    from repro.core.resilience import measure_resilience

    rates = fault_rate_list([0.05], max_fr=0.3, max_interval=0.08, step=0.9)
    tight = measure_resilience(
        trainer, rates, trainer.baseline_accuracy - 0.02,
        array_shape=(32, 32), repeats=2, max_steps=250, seed=1,
    )
    loose = measure_resilience(
        trainer, rates, trainer.baseline_accuracy - 0.10,
        array_shape=(32, 32), repeats=2, max_steps=250, seed=1,
    )
    assert loose.max_steps_stat.sum() <= tight.max_steps_stat.sum()
