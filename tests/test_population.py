"""Population FAT engine tests: serial-vs-population numerical equivalence
(same fault maps + seeds -> identical steps-to-constraint and matching
final metrics/params within the shared per-dtype tolerance), population
chunking invariance, batched-context pytree behavior under jit, Step-1
population submission, and the resilience-table cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduce_config
from repro.core import (
    EFAT,
    EFATConfig,
    FaultContext,
    correlated_family,
    from_fault_map,
    healthy,
    random_fault_map,
    stack_contexts,
)
from repro.core.resilience import measure_resilience
from repro.kernels.common import dtype_tol
from repro.train.fat_trainer import ClassifierFATTrainer, LMFATTrainer
from repro.train.population import PopulationFATEngine, SerialFATEngine, make_fat_engine

CFG = get_arch("paper-mlp")


@pytest.fixture(scope="module")
def trainers():
    """(population, serial) trainers sharing identical base params so any
    divergence comes from the engines, not from pretraining noise."""
    pop = ClassifierFATTrainer(CFG, pretrain_steps=300, eval_batches=2)
    ser = ClassifierFATTrainer(CFG, pretrain_steps=0, eval_batches=2, engine="serial")
    ser.base_params = pop.base_params
    ser.baseline_accuracy = ser.evaluate_params(ser.base_params, healthy())
    return pop, ser


@pytest.fixture(scope="module")
def fleet():
    rng = np.random.default_rng(0)
    rates = [0.02, 0.08, 0.12, 0.18, 0.22]
    return [random_fault_map(rng, 32, 32, r) for r in rates]


# ---------------------------------------------------------------------------
# batched FaultContext
# ---------------------------------------------------------------------------


def test_stack_contexts_batched_pytree_roundtrip_under_jit():
    maps = [random_fault_map(i, 8, 8, 0.2) for i in range(3)]
    stacked = stack_contexts([from_fault_map(fm) for fm in maps])
    assert stacked.population == 3
    assert stacked.ok.shape == (3, 8, 8)
    assert stacked.mode == "fap"
    # flatten/unflatten keeps the mask leaf + static mode
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    assert len(leaves) == 1
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.mode == "fap" and rebuilt.population == 3
    # crosses a jit boundary as a pytree argument
    total = jax.jit(lambda c: c.ok.sum())(stacked)
    assert float(total) == pytest.approx(sum(fm.ok_mask.sum() for fm in maps))
    # vmap over the population axis sees per-chip (R, C) members
    rates = jax.jit(jax.vmap(lambda c: 1.0 - c.ok.mean()))(stacked)
    assert np.allclose(np.asarray(rates), [fm.fault_rate for fm in maps], atol=1e-6)


def test_stack_contexts_empty_population_raises():
    with pytest.raises(ValueError, match="empty population"):
        stack_contexts([])


def test_single_member_population(trainers):
    """A population of ONE is a legal fleet: stacks to population=1 and runs
    through the population engine identically to the serial reference."""
    fm = random_fault_map(5, 32, 32, 0.15)
    stacked = stack_contexts([from_fault_map(fm)])
    assert stacked.population == 1
    assert stacked.ok.shape == (1, 32, 32)
    pop, ser = trainers
    constraint = pop.baseline_accuracy - 0.05
    assert pop.steps_to_constraint_batch([fm], constraint, 100) == (
        ser.steps_to_constraint_batch([fm], constraint, 100)
    )
    p_pop = pop.train_batch([fm], [10])[0]
    p_ser = ser.train_batch([fm], [10])[0]
    rtol, atol = dtype_tol(jnp.float32, atol_scale=100)
    for x, y in zip(jax.tree_util.tree_leaves(p_pop), jax.tree_util.tree_leaves(p_ser)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)


def test_stack_contexts_upcasts_healthy_and_rejects_mixed_modes():
    fm = random_fault_map(0, 8, 8, 0.25)
    stacked = stack_contexts([from_fault_map(fm), healthy()])
    assert stacked.population == 2
    assert float(stacked.ok[1].min()) == 1.0  # healthy member = all-ones mask
    assert stack_contexts([healthy(), healthy()]).ok is None
    with pytest.raises(ValueError):
        stack_contexts([from_fault_map(fm, mode="fap"), from_fault_map(fm, mode="pallas")])
    with pytest.raises(ValueError):
        stack_contexts([from_fault_map(fm), stacked])  # no re-stacking


def test_batched_context_rejected_outside_vmap():
    from repro.core import fault_linear

    stacked = stack_contexts([from_fault_map(random_fault_map(i, 8, 8, 0.2)) for i in range(2)])
    with pytest.raises(ValueError, match="vmap"):
        fault_linear(jnp.ones((1, 8)), jnp.ones((8, 8)), stacked)


# ---------------------------------------------------------------------------
# serial vs population equivalence
# ---------------------------------------------------------------------------


def test_steps_to_constraint_population_matches_serial(trainers, fleet):
    pop, ser = trainers
    constraint = pop.baseline_accuracy - 0.05
    got_pop = pop.steps_to_constraint_batch(fleet, constraint, 200)
    got_ser = ser.steps_to_constraint_batch(fleet, constraint, 200)
    assert got_pop == got_ser
    # sanity: the sweep actually spans the interesting regimes
    assert got_pop[0] == 0  # low rate needs no retraining
    assert any(s not in (0, None) for s in got_pop)


def test_train_batch_population_matches_serial(trainers, fleet):
    pop, ser = trainers
    budgets = [25, 40, 10]
    p_pop = pop.train_batch(fleet[:3], budgets)
    p_ser = ser.train_batch(fleet[:3], budgets)
    rtol, atol = dtype_tol(jnp.float32, atol_scale=100)
    for a, b in zip(p_pop, p_ser):
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
    m_pop = pop.evaluate_batch(p_pop, fleet[:3])
    m_ser = ser.evaluate_batch(p_ser, fleet[:3])
    assert m_pop == pytest.approx(m_ser, abs=2e-3)


def test_population_chunking_invariant(trainers, fleet):
    """Chunk size changes how work is submitted, never per-member results."""
    pop, _ = trainers
    constraint = pop.baseline_accuracy - 0.05
    wide = pop.steps_to_constraint_batch(fleet, constraint, 150)
    narrow_engine = make_fat_engine(
        "population",
        loss_fn=pop.engine.loss_fn,
        opt_cfg=pop.opt_cfg,
        eval_batches=pop._evals,
        metric="accuracy",
        eval_every=pop.eval_every,
        population_size=2,
    )
    ctxs = [from_fault_map(fm) for fm in fleet]
    narrow = narrow_engine.steps_to_constraint_batch(
        pop.base_params, ctxs, constraint, 150, pop._probe_batch_fn
    )
    assert wide == narrow
    # fit_batch chunking: padded members never leak into results
    trained = narrow_engine.fit_batch(pop.base_params, ctxs, [8] * len(ctxs), pop._train_batch_fn)
    assert len(trained) == len(fleet)
    ref = pop.engine.fit_batch(pop.base_params, ctxs, [8] * len(ctxs), pop._train_batch_fn)
    for a, b in zip(trained, ref):
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6, atol=1e-6)


def test_measure_resilience_engines_agree(trainers):
    """Acceptance: both engines produce the SAME resilience table on
    identical seeds (identical fault-map grid, identical crossings)."""
    pop, ser = trainers
    constraint = pop.baseline_accuracy - 0.06
    rates = [0.05, 0.12, 0.2]
    kw = dict(array_shape=(32, 32), repeats=3, max_steps=150, seed=11)
    t_pop = measure_resilience(pop, rates, constraint, **kw)
    t_ser = measure_resilience(ser, rates, constraint, engine="serial", **kw)
    assert np.array_equal(t_pop.rates, t_ser.rates)
    assert np.array_equal(t_pop.min_steps, t_ser.min_steps)
    assert np.array_equal(t_pop.mean_steps, t_ser.mean_steps)
    assert np.array_equal(t_pop.max_steps_stat, t_ser.max_steps_stat)


def test_execute_plan_population_path(trainers):
    """Step-4 on the batch path: all jobs as one population, all chips
    evaluated in one batch, same bookkeeping as the serial loop."""
    pop, _ = trainers
    fleet = correlated_family(7, 6, 32, 32, base_rate=0.05, idio_rate=0.02)
    ef = EFAT(
        pop,
        EFATConfig(
            constraint=pop.baseline_accuracy - 0.06, max_fr=0.2, max_interval=0.06,
            step_ratio=0.8, repeats=2, max_steps=150, m_comparisons=4, k_iterations=2,
        ),
    )
    result = ef.run(fleet)
    assert sorted(c for link in result.plan.links for c in link) == list(range(6))
    assert set(result.chip_metrics) == set(range(6))
    assert result.satisfied_fraction >= 0.5, result.summary()


# ---------------------------------------------------------------------------
# pallas-mode fault contexts under vmap (reduced-LM population smoke)
# ---------------------------------------------------------------------------


def test_pallas_mode_population_contexts_under_vmap():
    """A population of mode='pallas' contexts runs through the vmap engine:
    on CPU backends the masked GEMM falls back to the fap math, so the
    population eval must equal both the serial reference and the fap-mode
    population bit for bit — pinning that batched pallas contexts are legal
    under vmap (the accelerator path swaps only the GEMM kernel)."""
    cfg = reduce_config(get_arch("qwen3-0.6b"))
    tr = LMFATTrainer(
        cfg, pretrain_steps=5, eval_batches=1, population_size=4,
        batch_size=2, seq_len=16,
    )
    fms = [random_fault_map(i, cfg.array_rows, cfg.array_cols, 0.2) for i in range(3)]
    pallas_ctxs = [from_fault_map(fm, mode="pallas") for fm in fms]
    fap_ctxs = [from_fault_map(fm) for fm in fms]
    stacked = stack_contexts(pallas_ctxs)
    assert stacked.mode == "pallas" and stacked.population == 3

    params = [tr.base_params] * 3
    ev_pallas = tr.engine.evaluate_batch(params, pallas_ctxs)
    ev_fap = tr.engine.evaluate_batch(params, fap_ctxs)
    assert ev_pallas == ev_fap  # same math, different static mode
    ser = SerialFATEngine(
        loss_fn=tr.engine.loss_fn, opt_cfg=tr.opt_cfg,
        eval_batches=tr._evals, metric=tr.metric, eval_every=tr.eval_every,
    )
    ev_ser = ser.evaluate_batch(params, pallas_ctxs)
    assert ev_pallas == pytest.approx(ev_ser, abs=1e-6)
    # a short pallas-mode population fit matches the serial trajectories
    p_pop = tr.engine.fit_batch(tr.base_params, pallas_ctxs, [2, 2, 2], tr._train_batch_fn)
    p_ser = ser.fit_batch(tr.base_params, pallas_ctxs, [2, 2, 2], tr._train_batch_fn)
    rtol, atol = dtype_tol(jnp.float32, atol_scale=100)
    for a, b in zip(p_pop, p_ser):
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)


def test_masked_matmul_interpret_kernel_under_vmap():
    """The Pallas masked-matmul kernel itself (interpret backend) accepts a
    vmapped mask axis — the exact shape the population engine feeds it on
    accelerator backends."""
    from repro.kernels.masked_matmul.ops import masked_matmul
    from repro.kernels.masked_matmul.ref import masked_matmul_ref

    key = jax.random.PRNGKey(0)
    kx, kw, km = jax.random.split(key, 3)
    x = jax.random.normal(kx, (4, 16))
    w = jax.random.normal(kw, (16, 24))
    oks = (jax.random.uniform(km, (3, 8, 8)) > 0.25).astype(jnp.float32)
    got = jax.vmap(lambda ok: masked_matmul(x, w, ok, interpret=True))(oks)
    want = jax.vmap(lambda ok: masked_matmul_ref(x, w, ok))(oks)
    rtol, atol = dtype_tol(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# resilience-table cache
# ---------------------------------------------------------------------------


class _StubTrainer:
    """Analytic steps-to-constraint; counts invocations to prove caching."""

    def __init__(self):
        self.calls = 0

    def steps_to_constraint(self, fault_map, constraint, max_steps):
        self.calls += 1
        return min(int(1 + 1000 * fault_map.fault_rate), max_steps)


def test_build_resilience_table_cache_roundtrip(tmp_path):
    fleet = [random_fault_map(i, 16, 16, 0.1) for i in range(3)]
    cache = str(tmp_path / "table.json")
    cfg = EFATConfig(constraint=0.9, repeats=2, max_steps=100, max_fr=0.2)
    tr = _StubTrainer()
    t1 = EFAT(tr, cfg).build_resilience_table(fleet, cache_path=cache)
    assert tr.calls > 0
    first_calls = tr.calls
    # identical config -> served from cache, no new measurements
    t2 = EFAT(tr, cfg).build_resilience_table(fleet, cache_path=cache)
    assert tr.calls == first_calls
    assert np.array_equal(t2.rates, t1.rates)
    assert np.array_equal(t2.max_steps_stat, t1.max_steps_stat)
    assert t2.meta["config"] == t1.meta["config"]
    # config mismatch (different repeats) -> re-measured + cache rewritten
    cfg3 = EFATConfig(constraint=0.9, repeats=3, max_steps=100, max_fr=0.2)
    EFAT(tr, cfg3).build_resilience_table(fleet, cache_path=cache)
    assert tr.calls > first_calls
    t4 = EFAT(_StubTrainer(), cfg3).build_resilience_table(fleet, cache_path=cache)
    assert t4.meta["config"]["repeats"] == 3


# ---------------------------------------------------------------------------
# engine factory
# ---------------------------------------------------------------------------


def test_make_fat_engine_kinds(trainers):
    pop, ser = trainers
    assert isinstance(pop.engine, PopulationFATEngine)
    assert isinstance(ser.engine, SerialFATEngine)
    with pytest.raises(ValueError):
        make_fat_engine("bogus", loss_fn=None, opt_cfg=None, eval_batches=[])
